//! Quickstart: the EAGL → knapsack pipeline in ~30 lines, no training.
//!
//! Loads the qresnet20 artifacts, scores every layer with the EAGL entropy
//! metric (Algorithm 2 — needs only the checkpoint), and solves the 0-1
//! knapsack at a 70% compute budget to choose per-layer 2/4-bit precisions.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use mpq::eagl;
use mpq::graph::Graph;
use mpq::knapsack;
use mpq::quant::{self, BitsConfig};
use mpq::runtime::Runtime;

fn main() -> mpq::Result<()> {
    let artifacts = mpq::artifacts_dir();
    let model = "qresnet20";

    // The layer table (costs, link groups, fixed-precision rules).
    let graph = Graph::load(&artifacts, model)?;
    let rt = Runtime::load(&artifacts, model)?;
    let ckpt = rt.init_checkpoint()?; // or any trained checkpoint

    // 1. EAGL gains: entropy of each layer's quantized weight distribution.
    let gains = eagl::checkpoint_entropies(&graph, &ckpt, 4)?;

    // 2. Knapsack at 70% of the all-4-bit budget.
    let budget = graph.budget_at(0.70, 4);
    let group_gains = graph.aggregate_by_group(&gains);
    let weights = graph.group_weights(4, 2);
    let sel = knapsack::select_layers(&group_gains, &weights, budget - graph.base_bmacs(2));
    let bits = BitsConfig::from_selection(&graph, &sel.selected, 4, 2);

    // 3. Inspect the result.
    println!("{model} @ 70% budget — EAGL selection:\n");
    println!("{:<16} {:>8} {:>6}", "layer", "H(bits)", "bits");
    for l in &graph.layers {
        println!(
            "{:<16} {:>8.3} {:>6}",
            l.name,
            gains[l.qindex],
            if l.fixed_bits.is_some() {
                format!("{}*", bits.bits[l.qindex])
            } else {
                bits.bits[l.qindex].to_string()
            }
        );
    }
    println!("\n(* = fixed by §3.4.1 rules; not selectable)");
    println!(
        "compression {:.2}x  |  {:.4} GBOPs  |  {} of {} groups at 2-bit",
        quant::compression_ratio(&graph, &bits),
        quant::gbops(&graph, &bits),
        bits.count_at(&graph, 2),
        graph.groups.len(),
    );
    println!("\nNext: `mpq run --model {model} --method eagl --budget 0.7` fine-tunes this network.");
    Ok(())
}
