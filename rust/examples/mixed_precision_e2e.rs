//! End-to-end driver (the EXPERIMENTS.md validation run): exercises every
//! layer of the stack on a real workload — hermetically on the sim
//! backend by default, or on AOT artifacts with a `--features pjrt` build
//! and `MPQ_E2E_MODEL=qsegnet`.
//!
//! 1. trains the 4-bit base + 8-bit reference through the backend's fused
//!    train_step;
//! 2. estimates gains with EAGL, ALPS, and HAWQ-v3;
//! 3. knapsack-selects at two budgets, fine-tunes each mixed-precision
//!    network, evaluates the task metric;
//! 4. prints the mini-frontier and the per-layer choices.
//!
//! Env knobs: `MPQ_E2E_MODEL` (default sim_skew), `MPQ_E2E_STEPS` (base
//! training steps), `MPQ_BACKEND` (sim|pjrt|auto).

use mpq::backend::{self, Backend, TrainState, Task};
use mpq::coordinator::{Coordinator, ResultStore};
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let model = std::env::var("MPQ_E2E_MODEL").unwrap_or_else(|_| "sim_skew".into());
    let base_steps: usize = std::env::var("MPQ_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    let backend_flag = std::env::var("MPQ_BACKEND").ok();
    let kind = backend::resolve(backend_flag.as_deref(), &model)?;
    let mut co = Coordinator::open(kind, &model, 7)?;
    co.base_steps = base_steps;
    co.ft_steps = base_steps / 10;
    co.eval_batches = 4;
    co.mcfg.alps_steps = 15;
    co.mcfg.hawq_samples = 2;
    co.mcfg.hawq_batches = 2;

    let metric = match co.rt.manifest().task {
        Task::Cls => "top-1",
        Task::Seg => "mIoU",
        Task::Span => "F1",
    };

    println!("== 1. base checkpoints ({base_steps} steps, {} backend) ==", co.rt.kind());
    let t0 = std::time::Instant::now();
    let ck4 = co.base_checkpoint()?;
    let e4 = co.eval_uniform(&ck4, 4)?;
    let ck8 = co.reference_checkpoint()?;
    let e8 = co.eval_uniform(&ck8, 8)?;
    let b2 = co.select(MethodKind::Uniform, 0.5)?; // all-2-bit
    let e2 = {
        let ck2 = mpq::methods::prepare_mp_checkpoint(&ck4, &co.graph, &b2, 4)?;
        let mut state = TrainState::new(ck2);
        let tcfg = mpq::train::TrainConfig {
            steps: co.ft_steps,
            lr0: 0.005,
            ..Default::default()
        };
        mpq::train::finetune(&mut co.rt, &mut state, &co.data, &b2.to_f32(), &tcfg)?;
        mpq::train::evaluate(&mut co.rt, &state.params, &co.data, &b2.to_f32(), co.eval_batches)?
    };
    println!("8-bit reference : {metric} {:.4}", e8.metric);
    println!("4-bit uniform   : {metric} {:.4}", e4.metric);
    println!("2-bit uniform   : {metric} {:.4}  <- the gap mixed precision must close", e2.metric);

    println!("\n== 2. gain estimation ==");
    for kind in [MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3] {
        let est = co.gains(kind)?;
        println!("{:<8} estimated in {:>8.3}s", kind.name(), est.wall_seconds);
    }

    println!("\n== 3. budget sweep ==");
    let store_path = co.results_dir.join("e2e.jsonl");
    let mut store = ResultStore::open(&store_path)?;
    let kinds = [MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3, MethodKind::FirstToLast];
    let budgets = [0.92, 0.75];
    let records = co.sweep(&kinds, &budgets, &[0], &mut store)?;
    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, metric));

    println!("== 4. per-layer choices @ 75% ==");
    let mut choices = Vec::new();
    for kind in kinds {
        choices.push((kind.name().to_string(), co.select(kind, 0.75)?));
    }
    println!("{}", report::layer_selection_map(&co.graph, &choices));
    println!("total wall time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
