//! Frontier sweep driver: the paper's Fig. 3/4/5 protocol as a
//! configurable batch job with resume — backend-agnostic, hermetic by
//! default on the sim backend.
//!
//! Runs (methods × budgets × seeds) fine-tune+eval experiments for one
//! model, appending to the JSONL store so interrupted sweeps pick up where
//! they left off, then prints the frontier table, ASCII plot, and Wilcoxon
//! significance of EAGL/ALPS vs the comparators.
//!
//! ```bash
//! cargo run --release --example frontier_sweep -- \
//!     --model sim_skew --budgets 0.95,0.92,0.85 --seeds 3 \
//!     --methods eagl,alps,hawq_v3,first_to_last --ft-steps 20
//! ```

use mpq::backend::{self, Backend, Task};
use mpq::cli::Args;
use mpq::coordinator::{Coordinator, ResultStore};
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let model = args.str("model", "sim_skew");
    let kind = backend::resolve(args.opt_str("backend"), &model)?;
    let mut co = Coordinator::open(kind, &model, args.u64("data-seed", 7)?)?;
    co.base_steps = args.usize("base-steps", 300)?;
    co.ft_steps = args.usize("ft-steps", 20)?;
    co.eval_batches = args.usize("eval-batches", 4)?;
    co.mcfg.alps_steps = args.usize("alps-steps", 15)?;
    co.mcfg.hawq_samples = args.usize("hawq-samples", 2)?;
    co.mcfg.hawq_batches = args.usize("hawq-batches", 2)?;

    let kinds: Vec<MethodKind> = args
        .list("methods", &["eagl", "alps", "hawq_v3", "uniform", "first_to_last"])
        .iter()
        .map(|s| MethodKind::parse(s))
        .collect::<mpq::Result<_>>()?;
    let budgets = args.f64_list("budgets", &[0.95, 0.92, 0.85, 0.75])?;
    let seeds: Vec<u64> = (0..args.u64("seeds", 3)?).collect();

    let metric = match co.rt.manifest().task {
        Task::Cls => "top-1",
        Task::Seg => "mIoU",
        Task::Span => "F1",
    };

    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path)?;
    let records = co.sweep(&kinds, &budgets, &seeds, &mut store)?;

    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, metric));
    println!("{}", report::frontier_plot(&cells, 64, 16));
    for (a, b) in [("eagl", "hawq_v3"), ("alps", "hawq_v3"), ("eagl", "first_to_last")] {
        let sig = report::significance(&cells, a, b);
        for (budget, p) in sig {
            println!("Wilcoxon {a} vs {b} @ {:>3.0}%: p = {:.4}", budget * 100.0, p);
        }
    }
    report::write_csv(&cells, &co.results_dir.join("frontier.csv"))?;
    Ok(())
}
