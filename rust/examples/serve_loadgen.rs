//! Serve a mixed-precision sim model and drive it with the deterministic
//! load generator:
//!
//! ```text
//! cargo run --release --example serve_loadgen
//! ```
//!
//! Hermetic end-to-end tour of the serving subsystem: build an engine
//! over per-worker sim backends, pick a mixed 4/2-bit assignment, fire a
//! closed-loop load run, and print the throughput/latency report.  The
//! CLI equivalent (which also resolves bits from a sweep store and
//! fine-tunes the checkpoint) is `mpq serve`.

use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, SimBackend};
use mpq::data::Dataset;
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::report;
use mpq::serve::{loadgen, Engine, LoadMode, LoadSpec, ServeConfig, Spawner};

fn main() -> mpq::Result<()> {
    let model = "sim_skew";
    let be = SimBackend::new(model)?;
    let graph = Graph::from_manifest(&be.manifest().raw)?;
    let ck = be.init_checkpoint()?;
    // The assignment a mid-budget knapsack picks on sim_skew: the small
    // residual branches drop to 2-bit, the load-bearing wide layer stays.
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() && l.name != "wide" {
            bits.bits[l.qindex] = 2;
        }
    }
    println!(
        "serving {model}: {} group(s) at 2-bit, compression {:.2}x",
        bits.count_at(&graph, 2),
        mpq::quant::compression_ratio(&graph, &bits)
    );
    let data = Dataset::for_task(be.manifest().task, 7);
    let spawner: Spawner = Arc::new(move || Ok(Box::new(SimBackend::new(model)?) as Box<dyn Backend>));
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 16,
        batch_timeout: Duration::from_millis(1),
        ..ServeConfig::default()
    };
    let engine = Engine::start(spawner, ck, bits.to_f32(), cfg)?;
    let spec = LoadSpec {
        requests: 96,
        max_request_samples: 4,
        seed: 42,
        mode: LoadMode::Closed { concurrency: 6 },
    };
    let load = loadgen::run(&engine, &data, &spec)?;
    let snap = engine.drain()?;
    print!("{}", report::serve_table(&snap, &load));
    println!(
        "first response: id {}, {} sample(s), loss {:.4}",
        load.responses[0].id, load.responses[0].samples, load.responses[0].loss
    );
    Ok(())
}
