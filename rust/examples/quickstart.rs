//! Quickstart: the EAGL → knapsack pipeline in ~30 lines, no training —
//! and no artifacts: the default backend is the hermetic pure-Rust sim
//! executor, so this runs in a clean checkout with zero external steps.
//!
//! Scores every layer with the EAGL entropy metric (Algorithm 2 — needs
//! only the checkpoint), and solves the 0-1 knapsack at a 70% compute
//! budget to choose per-layer 2/4-bit precisions.
//!
//! ```bash
//! cargo run --release --example quickstart                 # sim backend
//! MPQ_MODEL=qresnet20 cargo run ... --features pjrt        # AOT artifacts
//! ```

use mpq::backend::{self, Backend};
use mpq::eagl;
use mpq::graph::Graph;
use mpq::knapsack;
use mpq::quant::{self, BitsConfig};

fn main() -> mpq::Result<()> {
    let model = std::env::var("MPQ_MODEL").unwrap_or_else(|_| "sim_skew".into());
    let backend_flag = std::env::var("MPQ_BACKEND").ok();
    let kind = backend::resolve(backend_flag.as_deref(), &model)?;
    let rt = backend::open(kind, &model)?;

    // The layer table (costs, link groups, fixed-precision rules) comes
    // from the backend's manifest.
    let graph = Graph::from_manifest(&rt.manifest().raw)?;
    let ckpt = rt.init_checkpoint()?; // or any trained checkpoint

    // 1. EAGL gains: entropy of each layer's quantized weight distribution.
    let gains = eagl::checkpoint_entropies(&graph, &ckpt, 4)?;

    // 2. Knapsack at 70% of the all-4-bit budget.
    let budget = graph.budget_at(0.70, 4);
    let group_gains = graph.aggregate_by_group(&gains);
    let weights = graph.group_weights(4, 2);
    let sel = knapsack::select_layers(&group_gains, &weights, budget - graph.base_bmacs(2));
    let bits = BitsConfig::from_selection(&graph, &sel.selected, 4, 2);

    // 3. Inspect the result.
    println!("{model} ({} backend) @ 70% budget — EAGL selection:\n", rt.kind());
    println!("{:<16} {:>8} {:>6}", "layer", "H(bits)", "bits");
    for l in &graph.layers {
        println!(
            "{:<16} {:>8.3} {:>6}",
            l.name,
            gains[l.qindex],
            if l.fixed_bits.is_some() {
                format!("{}*", bits.bits[l.qindex])
            } else {
                bits.bits[l.qindex].to_string()
            }
        );
    }
    println!("\n(* = fixed by §3.4.1 rules; not selectable)");
    println!(
        "compression {:.2}x  |  {:.4} GBOPs  |  {} of {} groups at 2-bit",
        quant::compression_ratio(&graph, &bits),
        quant::gbops(&graph, &bits),
        bits.count_at(&graph, 2),
        graph.groups.len(),
    );
    println!("\nNext: `mpq run --model {model} --method eagl --budget 0.7` fine-tunes this network.");
    Ok(())
}
