//! EAGL offline (paper Fig. 2): weight-code histograms and entropies for
//! three layers of a checkpoint — the "which layers compress further?"
//! picture, computed without any training data (EAGL's headline property).
//!
//! Hermetic by default (sim backend); point it at artifacts with
//! `MPQ_MODEL=qresnet20` + a `--features pjrt` build.
//!
//! ```bash
//! cargo run --release --example eagl_offline             # init checkpoint
//! MPQ_CKPT=results/sim_skew/base4.ckpt cargo run ...     # trained one
//! ```

use mpq::backend::{self, Backend};
use mpq::ckpt::Checkpoint;
use mpq::eagl;
use mpq::graph::Graph;
use mpq::quant::weight_codes;

fn ascii_hist(codes: &[i32], bits: u32) -> String {
    let n_bins = 1usize << bits;
    let qn = -(1i64 << (bits - 1)) as i32;
    let mut hist = vec![0usize; n_bins];
    for &c in codes {
        hist[(c - qn) as usize] += 1;
    }
    let max = *hist.iter().max().unwrap_or(&1);
    let mut s = String::new();
    for (i, &h) in hist.iter().enumerate() {
        let bar = "#".repeat((h * 40 / max.max(1)).max(usize::from(h > 0)));
        s += &format!("  {:>4} | {:<40} {}\n", qn + i as i32, bar, h);
    }
    s
}

fn main() -> mpq::Result<()> {
    let model = std::env::var("MPQ_MODEL").unwrap_or_else(|_| "sim_skew".into());
    let backend_flag = std::env::var("MPQ_BACKEND").ok();
    let kind = backend::resolve(backend_flag.as_deref(), &model)?;
    let rt = backend::open(kind, &model)?;
    let graph = Graph::from_manifest(&rt.manifest().raw)?;
    let ck = match std::env::var("MPQ_CKPT") {
        Ok(p) => Checkpoint::load(std::path::Path::new(&p))?,
        Err(_) => rt.init_checkpoint()?,
    };

    let t0 = std::time::Instant::now();
    let ents = eagl::checkpoint_entropies(&graph, &ck, 4)?;
    let dt = t0.elapsed().as_secs_f64();

    // Fig. 2 shows three layers spanning the entropy range: pick min,
    // median, max among selectable layers.
    let mut sel: Vec<&mpq::graph::Layer> =
        graph.layers.iter().filter(|l| l.fixed_bits.is_none()).collect();
    sel.sort_by(|a, b| ents[a.qindex].partial_cmp(&ents[b.qindex]).unwrap());
    let picks = [sel[0], sel[sel.len() / 2], sel[sel.len() - 1]];

    println!(
        "EAGL on {model}: {} layers scored in {:.3} ms (Table 3's 'CPU seconds' scale)\n",
        graph.layers.len(),
        dt * 1e3
    );
    for layer in picks {
        let base = layer.name.replace('.', "/");
        let w = ck.get(&format!("{base}/w")).unwrap();
        let s = ck.get(&format!("{base}/sw")).unwrap().item();
        let codes = weight_codes(w.f32s(), s.abs().max(1e-8), 4);
        println!(
            "layer {}  —  H = {:.4} bits (allocated 4)  →  {}",
            layer.name,
            ents[layer.qindex],
            if ents[layer.qindex] < 2.5 { "good candidate for 2-bit" } else { "keep at 4-bit" }
        );
        print!("{}", ascii_hist(&codes, 4));
        println!();
    }
    println!("EAGL prediction: quantize low-entropy layers first (paper §3.3).");
    Ok(())
}
