//! Integration tests for the serving engine (`mpq serve` path).
//!
//! The central contract under test: **every response is bit-identical to
//! a direct single-request `eval_step`** on that request's samples — at
//! any worker count, `max_batch`, batch composition, and in both the
//! fused and the per-request execution modes.  Alongside it: batcher
//! behaviors (empty-queue flush, oversized-request splitting with
//! in-order reassembly, deadline-triggered partial batches), monotone
//! response ids, loadgen determinism, and clean drains.
//!
//! Hermetic: everything runs on the sim backend's seeded init checkpoint
//! — no training, no artifacts, no filesystem state.

use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, SimBackend};
use mpq::ckpt::Checkpoint;
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::serve::{loadgen, Engine, LoadMode, LoadSpec, Response, ServeConfig, Spawner};
use mpq::tensor::Tensor;

const MODEL: &str = "sim_tiny";

fn spawner() -> Spawner {
    Arc::new(|| Ok(Box::new(SimBackend::new(MODEL)?) as Box<dyn Backend>))
}

/// (checkpoint, mixed-precision bits, dataset) for the test model.
fn setup() -> (Checkpoint, Vec<f32>, Dataset) {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    // Mixed precisions (one selectable layer at 2-bit) so the served
    // assignment is a real mixed-precision config, not uniform.
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            bits.bits[l.qindex] = 2;
            break;
        }
    }
    (ck, bits.to_f32(), Dataset::for_task(be.manifest().task, 11))
}

fn engine(workers: usize, max_batch: usize, timeout: Duration, per_request: bool) -> Engine {
    let (ck, bits, _) = setup();
    Engine::start(
        spawner(),
        ck,
        bits,
        ServeConfig {
            workers,
            max_batch,
            batch_timeout: timeout,
            force_per_request: per_request,
            warmup: true,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

/// The reference computation: a direct single-request eval_step on a
/// fresh backend.
fn direct_eval(ck: &Checkpoint, bits: &[f32], x: &Tensor, y: &Tensor) -> (f32, Tensor) {
    let mut be = SimBackend::new(MODEL).unwrap();
    be.eval_step(ck, x, y, bits).unwrap()
}

fn assert_bit_identical(r: &Response, reference: (f32, Tensor)) {
    assert_eq!(
        r.loss.to_bits(),
        reference.0.to_bits(),
        "response loss must be bit-identical to direct eval_step"
    );
    assert_eq!(
        r.evalout, reference.1,
        "response evalout must be identical to direct eval_step"
    );
}

#[test]
fn responses_bit_identical_to_direct_eval_at_any_workers_and_max_batch() {
    let (ck, bits, data) = setup();
    // Sizes straddle every batching regime: sub-batch, exactly max_batch,
    // and oversized (splitting) requests, interleaved.
    let sizes = [1usize, 3, 8, 20, 2, 5, 1, 16, 7];
    let requests: Vec<(Tensor, Tensor)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| data.batch(Split::Eval, 100 + i as u64, s))
        .collect();
    for &workers in &[1usize, 4] {
        for &max_batch in &[1usize, 8] {
            let eng = engine(workers, max_batch, Duration::from_millis(1), false);
            let tickets: Vec<_> = requests
                .iter()
                .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
                .collect();
            let responses: Vec<Response> =
                tickets.into_iter().map(|t| t.wait().unwrap()).collect();
            for (resp, (x, y)) in responses.iter().zip(&requests) {
                assert_eq!(resp.samples, x.shape[0]);
                assert_bit_identical(resp, direct_eval(&ck, &bits, x, y));
            }
            let snap = eng.drain().unwrap();
            assert_eq!(snap.completed, sizes.len() as u64);
            assert_eq!(snap.failed, 0);
            assert_eq!(snap.samples as usize, sizes.iter().sum::<usize>());
        }
    }
}

#[test]
fn oversized_request_is_split_and_reassembled_in_order() {
    let (ck, bits, data) = setup();
    // 19 samples at max_batch 4 → 5 chunks, potentially spread over both
    // workers and several micro-batches; the response must still equal
    // ONE direct eval_step over all 19 samples.
    let (x, y) = data.batch(Split::Eval, 500, 19);
    let eng = engine(2, 4, Duration::from_millis(1), false);
    let r = eng.submit(x.clone(), y.clone()).unwrap().wait().unwrap();
    assert_eq!(r.samples, 19);
    assert_bit_identical(&r, direct_eval(&ck, &bits, &x, &y));
    let snap = eng.drain().unwrap();
    assert_eq!(snap.batch_chunks, 5, "19 samples / max_batch 4 = 5 chunks");
    assert_eq!(snap.batch_samples, 19);
}

#[test]
fn empty_queue_flushes_clean_on_drain() {
    // Nothing submitted: drain must return immediately with zero counts,
    // leaving workers (possibly mid-wait) cleanly joined.
    let eng = engine(3, 16, Duration::from_secs(5), false);
    let snap = eng.drain().unwrap();
    assert_eq!(snap.submitted, 0);
    assert_eq!(snap.completed, 0);
    assert_eq!(snap.batches, 0);
}

#[test]
fn drain_flushes_requests_still_waiting_on_the_deadline() {
    // A request parked behind a long batch deadline must be served by the
    // drain, not dropped.
    let (ck, bits, data) = setup();
    let (x, y) = data.batch(Split::Eval, 600, 2);
    let eng = engine(1, 64, Duration::from_secs(30), false);
    let ticket = eng.submit(x.clone(), y.clone()).unwrap();
    let snap = eng.drain().unwrap();
    let r = ticket.wait().unwrap();
    assert_bit_identical(&r, direct_eval(&ck, &bits, &x, &y));
    assert_eq!(snap.completed, 1);
}

#[test]
fn deadline_triggers_partial_batch() {
    let (ck, bits, data) = setup();
    // max_batch 64 with only 3 single-sample requests: the size trigger
    // can never fire, so completion proves the deadline path dispatched a
    // partial batch.
    let eng = engine(1, 64, Duration::from_millis(40), false);
    let reqs: Vec<(Tensor, Tensor)> = (0..3)
        .map(|i| data.batch(Split::Eval, 700 + i, 1))
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    for (t, (x, y)) in tickets.into_iter().zip(&reqs) {
        let r = t.wait().unwrap();
        assert_bit_identical(&r, direct_eval(&ck, &bits, x, y));
    }
    let snap = eng.drain().unwrap();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.batch_samples, 3);
    assert!(
        snap.batches >= 1 && snap.batches <= 3,
        "expected deadline-dispatched partial batch(es), got {}",
        snap.batches
    );
}

#[test]
fn per_request_fallback_mode_is_also_bit_identical() {
    let (ck, bits, data) = setup();
    let sizes = [1usize, 6, 40, 3]; // 40 > max_batch: rides alone, unsplit
    let reqs: Vec<(Tensor, Tensor)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| data.batch(Split::Eval, 800 + i as u64, s))
        .collect();
    let eng = engine(2, 8, Duration::from_millis(1), true);
    assert!(!eng.fused(), "force_per_request must disable fused batching");
    let tickets: Vec<_> = reqs
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    for (t, (x, y)) in tickets.into_iter().zip(&reqs) {
        assert_bit_identical(&t.wait().unwrap(), direct_eval(&ck, &bits, x, y));
    }
    eng.drain().unwrap();
}

#[test]
fn loadgen_is_deterministic_across_worker_counts() {
    // Same spec against differently-parallel engines: the (sorted)
    // response streams must be bit-identical — the combined determinism
    // of the loadgen's request content and the engine's batching.
    let (ck, bits, data) = setup();
    let spec = LoadSpec {
        requests: 24,
        max_request_samples: 5,
        seed: 42,
        mode: LoadMode::Closed { concurrency: 4 },
    };
    let mut streams: Vec<Vec<Response>> = Vec::new();
    for &workers in &[1usize, 4] {
        let eng = Engine::start(
            spawner(),
            ck.clone(),
            bits.clone(),
            ServeConfig {
                workers,
                max_batch: 8,
                batch_timeout: Duration::from_millis(1),
                force_per_request: false,
                warmup: true,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let load = loadgen::run(&eng, &data, &spec).unwrap();
        assert!(load.throughput_rps > 0.0);
        eng.drain().unwrap();
        streams.push(load.responses);
    }
    let (a, b) = (&streams[0], &streams[1]);
    assert_eq!(a.len(), b.len());
    // Request-ordered streams: position k answers request k in both runs
    // (engine ids can interleave differently — content must not).
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.samples, rb.samples);
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        assert_eq!(ra.evalout, rb.evalout);
    }
}

#[test]
fn response_ids_are_monotone_and_contiguous_under_load() {
    let (_, _, data) = setup();
    let eng = engine(4, 8, Duration::from_millis(1), false);
    let spec = LoadSpec {
        requests: 40,
        max_request_samples: 3,
        seed: 7,
        mode: LoadMode::Closed { concurrency: 6 },
    };
    // run() itself enforces completeness + monotone, contiguous ids.
    let load = loadgen::run(&eng, &data, &spec).unwrap();
    assert_eq!(load.responses.len(), 40);
    // The loadgen was the engine's only client: the id set is exactly
    // 0..40 (a permutation across racing closed-loop clients).
    let mut ids: Vec<u64> = load.responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..40u64).collect::<Vec<_>>());
    let snap = eng.drain().unwrap();
    assert_eq!(snap.completed, 40);
    assert!(snap.p50_s <= snap.p95_s + 1e-12);
    assert!(snap.p95_s <= snap.p99_s + 1e-12);
    assert!(snap.mean_occupancy() >= 1.0);
}

#[test]
fn open_loop_mode_completes_and_matches_direct_eval() {
    let (ck, bits, data) = setup();
    let eng = engine(2, 8, Duration::from_millis(1), false);
    let spec = LoadSpec {
        requests: 10,
        max_request_samples: 2,
        seed: 9,
        // High rate: effectively submit-as-fast-as-possible.
        mode: LoadMode::Open { rate_hz: 100_000.0 },
    };
    let load = loadgen::run(&eng, &data, &spec).unwrap();
    let inputs = loadgen::request_set(&data, &spec);
    for (r, (x, y)) in load.responses.iter().zip(&inputs) {
        assert_bit_identical(r, direct_eval(&ck, &bits, x, y));
    }
    eng.drain().unwrap();
}

/// A second, distinct serving config over the same checkpoint (every
/// selectable layer at 2-bit) for hot-swap tests.
fn alt_bits() -> Vec<f32> {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            bits.bits[l.qindex] = 2;
        }
    }
    bits.to_f32()
}

#[test]
fn hot_swap_under_load_is_epoch_pure_and_bit_identical() {
    let (ck, bits_a, data) = setup();
    let bits_b = alt_bits();
    assert_ne!(bits_a, bits_b, "swap test needs two distinct configs");
    let eng = engine(2, 8, Duration::from_millis(1), false);
    let reqs: Vec<(Tensor, Tensor)> = (0..12)
        .map(|i| data.batch(Split::Eval, 1000 + i, 1 + (i as usize % 4)))
        .collect();
    // First half admitted under epoch 0, then an atomic swap, second half
    // under epoch 1 — the submitter is single-threaded, so the admission
    // epoch of every request is deterministic.
    let first: Vec<_> = reqs[..6]
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    let epoch = eng
        .swap(ck.clone(), bits_b.clone(), 0.6, "alt@0.60")
        .unwrap();
    assert_eq!(epoch, 1);
    assert_eq!(eng.current_epoch(), 1);
    let second: Vec<_> = reqs[6..]
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    // Every response is answered under exactly the config that admitted
    // it: old-epoch requests on the OLD bits, new-epoch on the NEW.
    for (t, (x, y)) in first.into_iter().zip(&reqs[..6]) {
        let r = t.wait().unwrap();
        assert_eq!(r.epoch, 0, "pre-swap request must finish on its admission epoch");
        assert_bit_identical(&r, direct_eval(&ck, &bits_a, x, y));
    }
    for (t, (x, y)) in second.into_iter().zip(&reqs[6..]) {
        let r = t.wait().unwrap();
        assert_eq!(r.epoch, 1, "post-swap request must serve the new config");
        assert_bit_identical(&r, direct_eval(&ck, &bits_b, x, y));
    }
    let info = eng.epoch_info();
    assert_eq!((info.epoch, info.swap_total), (1, 1));
    assert_eq!(info.label, "alt@0.60");
    let snap = eng.drain().unwrap();
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.failed, 0, "a swap must drop zero requests");
}

#[test]
fn failed_swap_fails_closed_and_the_old_config_keeps_serving() {
    let (ck, bits_a, data) = setup();
    let eng = engine(1, 8, Duration::from_millis(1), false);
    // Materialization failure: a bits vector of the wrong length can
    // never be published.
    let err = eng
        .swap(ck.clone(), vec![4.0; 3], 0.5, "bogus")
        .unwrap_err()
        .to_string();
    assert!(err.contains("bits"), "unexpected error: {err}");
    assert_eq!(eng.current_epoch(), 0, "failed swap must leave the old epoch live");
    assert_eq!(eng.epoch_info().swap_total, 0);
    // And the old config still serves, bit-identically.
    let (x, y) = data.batch(Split::Eval, 2000, 3);
    let r = eng.submit(x.clone(), y.clone()).unwrap().wait().unwrap();
    assert_eq!(r.epoch, 0);
    assert_bit_identical(&r, direct_eval(&ck, &bits_a, &x, &y));
    eng.drain().unwrap();
}

/// Regression test for the drain/swap race: a swap that lands while the
/// engine is draining must be rejected outright — before the fix it
/// could publish a new epoch into a queue the drain was about to flush,
/// waking workers against a dead config.
#[test]
fn swap_during_drain_is_rejected() {
    let (ck, bits_a, data) = setup();
    let bits_b = alt_bits();
    // A parked request (long deadline) keeps the queue non-empty while
    // the drain begins, so the rejection window is actually exercised.
    let eng = engine(1, 64, Duration::from_secs(30), false);
    let (x, y) = data.batch(Split::Eval, 3000, 2);
    let ticket = eng.submit(x.clone(), y.clone()).unwrap();
    eng.begin_drain();
    let err = eng
        .swap(ck.clone(), bits_b, 0.6, "late")
        .unwrap_err()
        .to_string();
    assert!(err.contains("draining"), "unexpected error: {err}");
    assert_eq!(eng.current_epoch(), 0);
    let snap = eng.drain().unwrap();
    // The parked request was flushed by the drain, on the original epoch.
    let r = ticket.wait().unwrap();
    assert_eq!(r.epoch, 0);
    assert_bit_identical(&r, direct_eval(&ck, &bits_a, &x, &y));
    assert_eq!(snap.completed, 1);
}

#[test]
fn submit_validates_requests_and_rejects_after_fatal_shapes() {
    let (_, _, data) = setup();
    let eng = engine(1, 8, Duration::from_millis(1), false);
    // Empty request.
    assert!(eng
        .submit(Tensor::zeros(&[0, 32, 32, 3]), Tensor::zeros_i32(&[0]))
        .is_err());
    // Wrong per-sample dims.
    assert!(eng
        .submit(Tensor::zeros(&[1, 16, 16, 3]), Tensor::zeros_i32(&[1]))
        .is_err());
    // y/x sample-count mismatch.
    let (x, _) = data.batch(Split::Eval, 900, 2);
    assert!(eng.submit(x, Tensor::zeros_i32(&[3])).is_err());
    // Wrong label dtype (f32 labels would panic deep in a worker).
    let (x, _) = data.batch(Split::Eval, 902, 1);
    assert!(eng.submit(x, Tensor::zeros(&[1])).is_err());
    // A valid request still goes through after the rejections.
    let (x, y) = data.batch(Split::Eval, 901, 2);
    let r = eng.submit(x, y).unwrap().wait().unwrap();
    assert_eq!(r.samples, 2);
    eng.drain().unwrap();
}

/// A backend that mimics a buggy accelerator: it delegates everything to
/// the sim backend but truncates `infer_step`'s logit tensor by one row,
/// so the engine receives fewer logits than the batch has samples.
struct TruncatingBackend(SimBackend);

impl Backend for TruncatingBackend {
    fn kind(&self) -> &'static str {
        self.0.kind()
    }

    fn manifest(&self) -> &mpq::backend::Manifest {
        self.0.manifest()
    }

    fn init_checkpoint(&self) -> mpq::Result<Checkpoint> {
        self.0.init_checkpoint()
    }

    fn execute(&mut self, entry: &str, args: &[&Tensor]) -> mpq::Result<Vec<Tensor>> {
        let mut out = self.0.execute(entry, args)?;
        if entry == "infer_step" {
            if let Some(logits) = out.pop() {
                let classes = logits.shape.get(1).copied().unwrap_or(1);
                let rows = logits.shape.first().copied().unwrap_or(0);
                let keep = rows.saturating_sub(1);
                let vals = logits.f32s()[..keep * classes].to_vec();
                out.push(Tensor::from_f32(&[keep, classes], vals));
            }
        }
        Ok(out)
    }
}

#[test]
fn short_logit_tensor_from_backend_fails_requests_instead_of_panicking() {
    // Pre-fix, a wrong-sized logit tensor panicked the per-chunk slice in
    // execute_fused on a worker thread, stranding every ticket in the
    // batch behind a wait() that never resolves.  Now the whole batch
    // fails cleanly and the engine keeps serving.
    let (ck, bits, data) = setup();
    let eng = Engine::start(
        Arc::new(|| {
            Ok(Box::new(TruncatingBackend(SimBackend::new(MODEL)?)) as Box<dyn Backend>)
        }),
        ck,
        bits,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            warmup: false,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let (x, y) = data.batch(Split::Eval, 700, 3);
    let err = eng.submit(x, y).unwrap().wait().unwrap_err().to_string();
    assert!(
        err.contains("infer_step returned"),
        "expected the short-logits error, got: {err}"
    );
    let snap = eng.drain().unwrap();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 0);
}
