//! End-to-end tests for graceful degradation (`mpq serve --degrade`):
//! the SLO controller walking a frontier of pre-materialized configs
//! while the real engine hot-swaps under a seeded overload profile with
//! deterministic fault injection.
//!
//! The central contracts:
//!
//! * **Determinism** — the controller's decision log derives only from
//!   the sim-time queue model, so it is byte-identical across reruns,
//!   worker counts, and kernel paths.
//! * **Zero drops, epoch purity** — every request submitted during the
//!   drill is answered exactly once, under precisely the config that
//!   admitted it, bit-identical to a direct `eval_step` with that
//!   epoch's bits.
//!
//! Hermetic: sim backend, seeded init checkpoint — no training, no
//! artifacts, no sockets, no wall-clock dependence in any assertion.

use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, KernelChoice, SimBackend};
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::serve::{
    run_degrade, DegradeConfig, Engine, FaultPlan, FrontierStep, LoadMode, LoadSpec, ServeConfig,
    SimProfile, SloThresholds, Spawner,
};

const MODEL: &str = "sim_tiny";

fn data() -> Dataset {
    let be = SimBackend::new(MODEL).unwrap();
    Dataset::for_task(be.manifest().task, 11)
}

/// Three frontier levels over the same seeded checkpoint: level 0 serves
/// everything at 4-bit, level 1 drops one selectable layer to 2-bit,
/// level 2 drops both.  The `gbops` ratios (1 : 2 : 4 speedup) are what
/// the sim queue model's capacity scaling keys off.
fn frontier() -> Vec<FrontierStep> {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    let selectable: Vec<usize> = graph
        .layers
        .iter()
        .filter(|l| l.fixed_bits.is_none())
        .map(|l| l.qindex)
        .collect();
    assert!(selectable.len() >= 2, "test model needs >= 2 selectable layers");
    let mut levels = Vec::new();
    for (i, &(budget, gbops)) in [(0.95, 1.0), (0.70, 0.5), (0.50, 0.25)].iter().enumerate() {
        let mut bits = BitsConfig::uniform(&graph, 4);
        for &q in selectable.iter().take(i) {
            bits.bits[q] = 2;
        }
        levels.push(FrontierStep {
            budget_frac: budget,
            method: "eagl".to_string(),
            metric: 0.9 - 0.05 * i as f64,
            gbops,
            ckpt: ck.clone(),
            bits: bits.to_f32(),
        });
    }
    levels
}

/// The fault plan every drill uses: stalls and spikes are pure functions
/// of (seed, request index), so both the sim model's extra work and the
/// real engine's worker stalls hit the same requests every run.
fn drill_fault() -> FaultPlan {
    FaultPlan {
        seed: 1,
        stall_every: 7,
        stall_wall: Duration::from_millis(1),
        stall_work: 16.0,
        spike_every: 5,
        spike_work: 12.0,
    }
}

/// Engine freshly started on frontier level 0 (epoch 0), with the drill's
/// fault plan live in the workers (real wall-clock stalls).
fn degrade_engine(workers: usize, kernel: KernelChoice, frontier: &[FrontierStep]) -> Engine {
    let spawner: Spawner = Arc::new(move || {
        Ok(Box::new(SimBackend::with_kernel(MODEL, kernel)?) as Box<dyn Backend>)
    });
    Engine::start(
        spawner,
        frontier[0].ckpt.clone(),
        frontier[0].bits.clone(),
        ServeConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
            fault: Some(drill_fault()),
            initial_budget: frontier[0].budget_frac,
            initial_label: frontier[0].label(),
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

/// The drill every test runs: a seeded spike profile with the shared
/// fault plan feeding the sim queue model.
fn drill_config() -> DegradeConfig {
    let mut cfg = DegradeConfig::new(SimProfile::named("spike").unwrap());
    cfg.thresholds = SloThresholds::default();
    cfg.fault = drill_fault();
    cfg
}

#[test]
fn decision_log_is_byte_identical_across_workers_kernels_and_reruns() {
    let data = data();
    let frontier = frontier();
    let cfg = drill_config();
    let mut logs: Vec<(String, String)> = Vec::new();
    for &workers in &[1usize, 4] {
        for &kernel in &[KernelChoice::Reference, KernelChoice::Packed] {
            let eng = degrade_engine(workers, kernel, &frontier);
            let out = run_degrade(&eng, &data, &frontier, &cfg).unwrap();
            eng.drain().unwrap();
            assert!(!out.log_text.is_empty());
            logs.push((format!("w={workers} k={}", kernel.name()), out.log_text));
        }
    }
    // Rerun of the first combination: reruns are also byte-identical.
    let eng = degrade_engine(1, KernelChoice::Reference, &frontier);
    let out = run_degrade(&eng, &data, &frontier, &cfg).unwrap();
    eng.drain().unwrap();
    logs.push(("rerun w=1 k=reference".to_string(), out.log_text));
    let (ref_name, ref_log) = &logs[0];
    for (name, log) in &logs[1..] {
        assert_eq!(
            log, ref_log,
            "decision log diverged: {name} vs {ref_name} — the controller must be \
             a pure function of (profile, faults, seed), never of scheduling"
        );
    }
}

#[test]
fn spike_overload_degrades_recovers_and_drops_nothing() {
    let data = data();
    let frontier = frontier();
    let cfg = drill_config();
    let eng = degrade_engine(2, KernelChoice::Reference, &frontier);
    let out = run_degrade(&eng, &data, &frontier, &cfg).unwrap();
    eng.drain().unwrap();

    // The drill exercised both directions of the frontier walk...
    assert!(out.swaps_down >= 1, "spike must force a downgrade:\n{}", out.log_text);
    assert!(out.swaps_up >= 1, "quiet tail must recover:\n{}", out.log_text);
    // ...one level at a time.
    for w in out.epoch_levels.windows(2) {
        assert_eq!(
            (w[0] as i64 - w[1] as i64).abs(),
            1,
            "frontier is walked in single steps, got {:?}",
            out.epoch_levels
        );
    }

    // Zero drops: every submitted request answered exactly once
    // (run_degrade already verified answer-under-admission-epoch).
    assert_eq!(out.responses.len(), out.requests);

    // Epoch-tagged bit-identity: each response equals a direct eval_step
    // under the bits of the config that admitted it.
    let spec = LoadSpec {
        requests: out.requests,
        max_request_samples: cfg.max_request_samples,
        seed: cfg.seed,
        mode: LoadMode::Closed { concurrency: 1 },
    };
    let sizes = mpq::serve::loadgen::request_sizes(&spec);
    let mut be = SimBackend::new(MODEL).unwrap();
    for (i, (admitted, r)) in out.responses.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert_eq!(r.epoch, *admitted);
        let step = &frontier[out.epoch_levels[*admitted as usize]];
        let (x, y) = data.batch(
            Split::Eval,
            mpq::serve::loadgen::request_index(i),
            sizes[i],
        );
        let (loss, evalout) = be.eval_step(&step.ckpt, &x, &y, &step.bits).unwrap();
        assert_eq!(
            r.loss.to_bits(),
            loss.to_bits(),
            "request {i} (epoch {admitted}): loss must be bit-identical to direct \
             eval under its admission epoch's bits"
        );
        assert_eq!(r.evalout, evalout, "request {i} (epoch {admitted})");
    }
}
