//! Integration tests for `mpq lint`: every rule fires on the seeded
//! negative fixtures, the clean fixtures stay quiet, waivers suppress
//! and fail closed, the `--json` report is byte-stable, the binary's
//! exit codes are pinned (0 clean / 1 findings / 2 config error) — and
//! the linter self-hosts: the shipped tree plus the shipped waiver file
//! must come back finding-free.

use std::path::{Path, PathBuf};
use std::process::Command;

fn fixture(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("lint_fixtures")
        .join(rel)
}

#[test]
fn firing_fixtures_trip_every_rule_exactly_once() {
    let report = mpq::analysis::run_with(&fixture("firing"), None).unwrap();
    assert_eq!(report.files_scanned, 5);
    assert_eq!(report.waived, 0);
    let mut rules: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    rules.sort_unstable();
    assert_eq!(
        rules,
        vec![
            "fail-closed-flags",
            "float-reassoc",
            "hot-path-panic",
            "relaxed-audit",
            "stdout-discipline",
            "wall-clock",
        ],
        "each rule must fire exactly once on the firing tree: {:#?}",
        report.findings
    );
    // Findings are sorted by (file, line, rule) for stable output.
    let keys: Vec<(String, usize, &str)> = report
        .findings
        .iter()
        .map(|f| (f.file.clone(), f.line, f.rule))
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
    // Spot-check anchors: the ghost subcommand and the bare unwrap.
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "fail-closed-flags" && f.note.contains("ghost")));
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "hot-path-panic"
            && f.file == "serve/engine.rs"
            && f.excerpt.contains("pop_front().unwrap()")));
}

#[test]
fn clean_fixtures_produce_no_findings() {
    let report = mpq::analysis::run_with(&fixture("clean"), None).unwrap();
    assert_eq!(report.files_scanned, 2);
    assert!(
        report.findings.is_empty(),
        "false positives on the clean tree: {:#?}",
        report.findings
    );
}

#[test]
fn waiver_suppresses_its_finding_and_counts_it() {
    let report =
        mpq::analysis::run_with(&fixture("firing"), Some(&fixture("waive-wall-clock.json")))
            .unwrap();
    assert_eq!(report.waived, 1);
    assert_eq!(report.findings.len(), 5);
    assert!(report.findings.iter().all(|f| f.rule != "wall-clock"));
}

#[test]
fn stale_waiver_is_a_config_error() {
    let err = mpq::analysis::run_with(&fixture("firing"), Some(&fixture("waive-stale.json")))
        .expect_err("a waiver matching nothing must fail closed");
    let msg = format!("{err:#}");
    assert!(msg.contains("stale waiver"), "unexpected error: {msg}");
    assert!(msg.contains("SystemTime::now"), "unexpected error: {msg}");
}

#[test]
fn unknown_waiver_key_is_a_config_error() {
    let err =
        mpq::analysis::run_with(&fixture("firing"), Some(&fixture("waive-unknown-key.json")))
            .expect_err("unknown waiver keys must fail closed");
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown key"), "unexpected error: {msg}");
    assert!(msg.contains("waivers[0].line"), "unexpected error: {msg}");
}

#[test]
fn empty_root_is_a_config_error() {
    let dir = std::env::temp_dir().join("mpq_lint_empty_root_fixture");
    std::fs::create_dir_all(&dir).unwrap();
    let err = mpq::analysis::run_with(&dir, None).expect_err("no .rs files must fail closed");
    assert!(format!("{err:#}").contains("wrong --root?"));
}

/// The machine-readable report is part of the CLI contract: sorted
/// keys, integer counts, the full rule list.  CI consumers parse this.
#[test]
fn json_report_format_is_pinned() {
    let report = mpq::analysis::run_with(&fixture("clean"), None).unwrap();
    assert_eq!(
        report.to_json().to_string_compact(),
        "{\"files_scanned\":2,\"findings\":[],\"rules\":[\"fail-closed-flags\",\
         \"float-reassoc\",\"hot-path-panic\",\"relaxed-audit\",\"stdout-discipline\",\
         \"wall-clock\"],\"version\":1,\"waived\":0}"
    );
    let report = mpq::analysis::run_with(&fixture("firing"), None).unwrap();
    let js = report.to_json().to_string_compact();
    assert!(js.contains("\"findings\":[{\""), "findings must serialize as objects: {js}");
    assert!(js.contains("\"rule\":\"wall-clock\""));
    assert!(js.contains("\"file\":\"serve/controller.rs\""));
}

/// Self-hosting gate: the shipped source tree plus the shipped waiver
/// allowlist must be finding-free, and every shipped waiver must still
/// be live (run_with fails closed on stale ones).
#[test]
fn shipped_tree_is_lint_clean_under_shipped_waivers() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let waivers = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-waivers.json");
    let report = mpq::analysis::run_with(&src, Some(&waivers)).unwrap();
    assert!(
        report.findings.is_empty(),
        "shipped tree has unwaived findings: {:#?}",
        report.findings
    );
    assert!(report.waived > 0, "the shipped waiver file should be doing work");
    assert!(report.files_scanned > 30, "suspiciously small scan: {}", report.files_scanned);
}

fn lint_cmd(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_mpq"))
        .arg("lint")
        .args(args)
        .output()
        .expect("spawn mpq lint")
}

#[test]
fn binary_exit_codes_are_pinned() {
    let firing = fixture("firing");
    let clean = fixture("clean");
    let firing = firing.to_str().unwrap();
    let clean = clean.to_str().unwrap();

    // 0: clean tree, human output ends with the OK line.
    let out = lint_cmd(&["--root", clean]);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("lint OK (2 files"));

    // 1: findings present; --json puts the report on stdout.
    let out = lint_cmd(&["--root", firing, "--json"]);
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.starts_with("{\"files_scanned\":5,"), "stdout: {stdout}");

    // 2: config error (stale waiver), reported on stderr.
    let stale = fixture("waive-stale.json");
    let out = lint_cmd(&["--root", firing, "--waivers", stale.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("lint: config error"));

    // 2: unknown flags fail closed at the CLI layer too.
    let out = lint_cmd(&["--root", clean, "--bogus-flag", "1"]);
    assert_ne!(out.status.code(), Some(0));
}
