//! Kernel-core invariants: the caches introduced in `rust/src/kernels/`
//! must be semantically invisible (bit-identical results, invalidated
//! exactly when their inputs change), and the `job_pool`-parallel
//! ALPS/HAWQ gain estimation must equal the sequential path exactly at
//! any worker count.  These are the acceptance assertions of the
//! kernel-core overhaul — claimed speedups mean nothing if the fast
//! path drifts from the reference math.

use mpq::backend::{Backend, SimBackend, TrainState};
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::methods::{self, MethodConfig, MethodKind};
use mpq::quant::BitsConfig;

fn setup(model: &str) -> (SimBackend, Graph, Dataset) {
    let be = SimBackend::new(model).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let data = Dataset::for_task(be.manifest().task, 11);
    (be, graph, data)
}

#[test]
fn featurizer_cache_returns_bit_identical_evals() {
    let (mut warm, graph, data) = setup("sim_tiny");
    let mut cold = SimBackend::new("sim_tiny").unwrap();
    let ck = warm.init_checkpoint().unwrap();
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let (x, y) = data.batch(Split::Eval, 0, warm.manifest().eval_batch);
    let (l1, c1) = warm.eval_step(&ck, &x, &y, &bits).unwrap();
    // Second call on the warm backend takes the cache-hit path...
    let (l2, c2) = warm.eval_step(&ck, &x, &y, &bits).unwrap();
    // ...a cold backend takes the miss path; all three must agree bitwise.
    let (l3, c3) = cold.eval_step(&ck, &x, &y, &bits).unwrap();
    assert_eq!(l1, l2, "cache-hit eval loss drifted");
    assert_eq!(c1.f32s(), c2.f32s());
    assert_eq!(l1, l3, "warm and cold backends disagree");
    assert_eq!(c1.f32s(), c3.f32s());
    let (feat_hits, feat_misses, w_hits, _) = warm.cache_stats();
    assert_eq!(feat_misses, 1, "second eval must hit the featurizer cache");
    assert!(feat_hits >= 1);
    assert!(w_hits >= 1, "frozen checkpoint must hit the weight cache");
}

#[test]
fn weight_cache_invalidated_after_train_step() {
    // Warm a backend's caches with an eval, run a train step (weights
    // change), then compare its post-step eval against a fresh backend
    // replaying the identical step: a stale cached weight code would
    // surface as differing loss/correct-count bits.
    let (mut warm, graph, data) = setup("sim_tiny");
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let (xt, yt) = data.batch(Split::Train, 0, warm.manifest().train_batch);
    let (xe, ye) = data.batch(Split::Eval, 0, warm.manifest().eval_batch);
    let mut state = TrainState::new(warm.init_checkpoint().unwrap());
    warm.eval_step(&state.params, &xe, &ye, &bits).unwrap(); // populate caches
    warm.train_step(&mut state, &xt, &yt, 0.05, 1e-4, &bits).unwrap();
    let (lw, cw) = warm.eval_step(&state.params, &xe, &ye, &bits).unwrap();

    let mut fresh = SimBackend::new("sim_tiny").unwrap();
    let mut state2 = TrainState::new(fresh.init_checkpoint().unwrap());
    fresh.train_step(&mut state2, &xt, &yt, 0.05, 1e-4, &bits).unwrap();
    let (lf, cf) = fresh.eval_step(&state2.params, &xe, &ye, &bits).unwrap();

    for (a, b) in state.params.tensors.iter().zip(&state2.params.tensors) {
        assert_eq!(a, b, "replayed train step must produce identical params");
    }
    assert_eq!(lw, lf, "stale weight-quant cache changed the eval loss");
    assert_eq!(cw.f32s(), cf.f32s());
}

#[test]
fn consecutive_train_steps_match_fresh_backend() {
    // Several steps in a row: every step invalidates the previous step's
    // cached weight codes; the whole trajectory must match a backend
    // without any warm state.
    let (mut warm, graph, data) = setup("sim_skew");
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let mut s1 = TrainState::new(warm.init_checkpoint().unwrap());
    let mut losses1 = Vec::new();
    for i in 0..4 {
        let (x, y) = data.batch(Split::Train, i, warm.manifest().train_batch);
        let (l, _) = warm.train_step(&mut s1, &x, &y, 0.02, 1e-4, &bits).unwrap();
        losses1.push(l);
    }
    let mut fresh = SimBackend::new("sim_skew").unwrap();
    let mut s2 = TrainState::new(fresh.init_checkpoint().unwrap());
    let mut losses2 = Vec::new();
    for i in 0..4 {
        let (x, y) = data.batch(Split::Train, i, fresh.manifest().train_batch);
        let (l, _) = fresh.train_step(&mut s2, &x, &y, 0.02, 1e-4, &bits).unwrap();
        losses2.push(l);
    }
    assert_eq!(losses1, losses2, "training trajectories diverged");
    for (a, b) in s1.params.tensors.iter().zip(&s2.params.tensors) {
        assert_eq!(a, b);
    }
}

#[test]
fn parallel_alps_gains_bit_identical_to_sequential() {
    let (mut rt, graph, data) = setup("sim_tiny");
    let ck = rt.init_checkpoint().unwrap();
    let cfg = MethodConfig {
        alps_steps: 3,
        ..MethodConfig::default()
    };
    let task = rt.manifest().task;
    let seq = methods::estimate_gains(MethodKind::Alps, &mut rt, &graph, &ck, &data, &cfg)
        .unwrap();
    let factory = || SimBackend::new("sim_tiny");
    let p1 = methods::estimate_gains_parallel(
        MethodKind::Alps, &factory, task, &graph, &ck, &data, &cfg, 1,
    )
    .unwrap();
    let p4 = methods::estimate_gains_parallel(
        MethodKind::Alps, &factory, task, &graph, &ck, &data, &cfg, 4,
    )
    .unwrap();
    assert_eq!(seq.per_layer, p1.per_layer, "workers=1 drifted from sequential");
    assert_eq!(seq.per_layer, p4.per_layer, "workers=4 drifted from sequential");
}

#[test]
fn parallel_hawq_gains_bit_identical_to_sequential() {
    let (mut rt, graph, data) = setup("sim_tiny");
    let ck = rt.init_checkpoint().unwrap();
    let cfg = MethodConfig {
        hawq_samples: 2,
        hawq_batches: 2,
        ..MethodConfig::default()
    };
    let task = rt.manifest().task;
    let seq = methods::estimate_gains(MethodKind::HawqV3, &mut rt, &graph, &ck, &data, &cfg)
        .unwrap();
    let factory = || SimBackend::new("sim_tiny");
    let p1 = methods::estimate_gains_parallel(
        MethodKind::HawqV3, &factory, task, &graph, &ck, &data, &cfg, 1,
    )
    .unwrap();
    let p4 = methods::estimate_gains_parallel(
        MethodKind::HawqV3, &factory, task, &graph, &ck, &data, &cfg, 4,
    )
    .unwrap();
    assert_eq!(seq.per_layer, p1.per_layer, "workers=1 drifted from sequential");
    assert_eq!(seq.per_layer, p4.per_layer, "workers=4 drifted from sequential");
}

#[test]
fn vhv_probe_unaffected_by_cache_state() {
    // The vHv finite-difference probe quantizes two weight sets per call
    // (base + perturbed); per-layer cache slots must not leak between
    // them or across calls.
    let (mut warm, graph, data) = setup("sim_tiny");
    let ck = warm.init_checkpoint().unwrap();
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let (x, y) = data.batch(Split::Train, 5, warm.manifest().train_batch);
    warm.eval_step(&ck, &x, &y, &bits).unwrap(); // warm the caches
    let v_warm = warm.vhv_step(&ck, &x, &y, &bits, 7).unwrap();
    let v_warm2 = warm.vhv_step(&ck, &x, &y, &bits, 7).unwrap();
    let mut cold = SimBackend::new("sim_tiny").unwrap();
    let v_cold = cold.vhv_step(&ck, &x, &y, &bits, 7).unwrap();
    assert_eq!(v_warm, v_warm2);
    assert_eq!(v_warm, v_cold);
}
