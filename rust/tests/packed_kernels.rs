//! Packed-kernel equivalence tests — the accuracy contract of the
//! bit-packed integer execution path (`--kernel packed`).
//!
//! Contract under test (see `rust/src/kernels/packed.rs`):
//!
//! * `eval_step` with packed kernels is **bit-identical** to the
//!   reference fake-quant path (the LUT kernel preserves the reference
//!   accumulation order), on every sim model, at 2/4/8-bit and mixed
//!   precisions — so EAGL/ALPS gains, frontier selections, and anything
//!   else built on evaluation are unchanged by construction;
//! * `infer_step` with packed kernels applies the LSQ scale once in the
//!   logits epilogue: per-logit agreement within the documented
//!   `PACKED_LOGIT_EPS`, identical argmax;
//! * serving with packed kernels produces responses epsilon-equal to a
//!   reference-kernel engine at workers ∈ {1, 4} × max-batch ∈ {1, 8},
//!   with identical per-request correct counts.
//!
//! Hermetic: sim backend, seeded init checkpoints, isolated results
//! directories for the selection sweeps.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, KernelChoice, KernelTuning, PackedVariant, SimBackend};
use mpq::ckpt::Checkpoint;
use mpq::coordinator::Coordinator;
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::kernels::packed::PACKED_LOGIT_EPS;
use mpq::methods::MethodKind;
use mpq::quant::BitsConfig;
use mpq::serve::{Engine, Response, ServeConfig, Spawner};
use mpq::tensor::Tensor;

fn spawner(model: &'static str, kernel: KernelChoice) -> Spawner {
    Arc::new(move || Ok(Box::new(SimBackend::with_kernel(model, kernel)?) as Box<dyn Backend>))
}

/// (checkpoint, graph, dataset) for a sim model's seeded init state.
fn setup(model: &str) -> (Checkpoint, Graph, Dataset) {
    let be = SimBackend::new(model).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    (ck, graph, Dataset::for_task(be.manifest().task, 13))
}

/// Precision vectors spanning the paper's range plus a mixed assignment,
/// including row lengths that are not multiples of the packing factor
/// (sim fan-ins of 10/12/16 at 4 codes/byte and 2 codes/byte).
fn bits_configs(graph: &Graph) -> Vec<Vec<f32>> {
    let mut out: Vec<Vec<f32>> = [2u32, 4, 8]
        .iter()
        .map(|&b| BitsConfig::uniform(graph, b).to_f32())
        .collect();
    let mut mixed = BitsConfig::uniform(graph, 4);
    let mut lo = true;
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            mixed.bits[l.qindex] = if lo { 2 } else { 8 };
            lo = !lo;
        }
    }
    out.push(mixed.to_f32());
    out
}

#[test]
fn packed_eval_is_bit_identical_across_models_and_precisions() {
    for model in ["sim_tiny", "sim_skew"] {
        let (ck, graph, data) = setup(model);
        let mut rbe = SimBackend::new(model).unwrap();
        let mut pbe = SimBackend::with_kernel(model, KernelChoice::Packed).unwrap();
        for bits in bits_configs(&graph) {
            for idx in 0..2u64 {
                let (x, y) = data.batch(Split::Eval, idx, 48);
                let (lr, cr) = rbe.eval_step(&ck, &x, &y, &bits).unwrap();
                let (lp, cp) = pbe.eval_step(&ck, &x, &y, &bits).unwrap();
                assert_eq!(
                    lp.to_bits(),
                    lr.to_bits(),
                    "{model} bits={bits:?}: packed eval loss must be bit-identical"
                );
                assert_eq!(cp, cr, "{model} bits={bits:?}: correct count must be identical");
            }
        }
    }
}

#[test]
fn packed_infer_logits_within_epsilon_with_identical_argmax() {
    for model in ["sim_tiny", "sim_skew"] {
        let (ck, graph, data) = setup(model);
        let mut rbe = SimBackend::new(model).unwrap();
        let mut pbe = SimBackend::with_kernel(model, KernelChoice::Packed).unwrap();
        for bits in bits_configs(&graph) {
            let (x, _) = data.batch(Split::Eval, 5, 32);
            let lr = rbe.infer_step(&ck, &x, &bits).unwrap();
            let lp = pbe.infer_step(&ck, &x, &bits).unwrap();
            assert_eq!(lp.shape, lr.shape);
            let (rs, ps) = (lr.f32s(), lp.f32s());
            let classes = lr.shape[1];
            for (i, (p, r)) in ps.iter().zip(rs).enumerate() {
                assert!(
                    (p - r).abs() <= PACKED_LOGIT_EPS,
                    "{model} bits={bits:?} logit {i}: packed {p} vs reference {r}"
                );
            }
            for b in 0..lr.shape[0] {
                let arg = |xs: &[f32]| {
                    xs[b * classes..(b + 1) * classes]
                        .iter()
                        .enumerate()
                        .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                        .unwrap()
                        .0
                };
                assert_eq!(arg(ps), arg(rs), "{model} bits={bits:?} sample {b}: argmax flip");
            }
        }
    }
}

/// Frontier selections must be identical with either kernel: EAGL never
/// evaluates, and ALPS's probe evaluations run the bit-identical packed
/// eval path, so gains — and therefore every knapsack selection at every
/// swept budget — agree exactly.
#[test]
fn selections_are_identical_with_either_kernel() {
    let scratch = std::env::temp_dir().join(format!("mpq_packed_sel_{}", std::process::id()));
    let co_for = |model: &str, kernel: KernelChoice, tag: &str| -> Coordinator<SimBackend> {
        let dir: PathBuf = scratch.join(format!("{model}_{tag}"));
        let mut co = Coordinator::with_backend(
            SimBackend::with_kernel(model, kernel).unwrap(),
            7,
            dir,
        )
        .unwrap();
        co.base_steps = 40;
        co.workers = 1;
        co
    };
    for model in ["sim_tiny", "sim_skew"] {
        let mut ref_co = co_for(model, KernelChoice::Reference, "reference");
        let mut pk_co = co_for(model, KernelChoice::Packed, "packed");
        for method in [MethodKind::Eagl, MethodKind::Alps] {
            for budget in [0.6, 0.8, 0.95] {
                let a = ref_co.select(method, budget).unwrap();
                let b = pk_co.select(method, budget).unwrap();
                assert_eq!(
                    a, b,
                    "{model} {} @ {budget}: selection must not depend on the kernel",
                    method.name()
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&scratch);
}

/// Tile variants and intra-layer row-parallelism are result-invisible on
/// the packed eval path: the ε = 0 LUT kernel carries every layer, and
/// its wide variants accelerate only the decode while row bands scatter
/// untouched arithmetic — so eval is bit-identical across
/// scalar/unrolled(/simd) and any gemm-threads, on every model and
/// precision mix.
#[test]
fn packed_variants_and_gemm_threads_leave_eval_bit_identical() {
    let tunings = [
        KernelTuning { variant: PackedVariant::Scalar, gemm_threads: 1 },
        KernelTuning { variant: PackedVariant::Unrolled, gemm_threads: 1 },
        // `Simd` falls back to `Unrolled` without the feature — the
        // identity must hold either way.
        KernelTuning { variant: PackedVariant::Simd, gemm_threads: 1 },
        KernelTuning { variant: PackedVariant::Unrolled, gemm_threads: 2 },
        KernelTuning { variant: PackedVariant::Scalar, gemm_threads: 4 },
    ];
    for model in ["sim_tiny", "sim_skew"] {
        let (ck, graph, data) = setup(model);
        let mut base = SimBackend::with_kernel(model, KernelChoice::Packed).unwrap();
        for bits in bits_configs(&graph) {
            let (x, y) = data.batch(Split::Eval, 3, 32);
            let (l0, c0) = base.eval_step(&ck, &x, &y, &bits).unwrap();
            for t in tunings {
                let mut be =
                    SimBackend::with_tuning(model, KernelChoice::Packed, t).unwrap();
                let (l, c) = be.eval_step(&ck, &x, &y, &bits).unwrap();
                assert_eq!(
                    l.to_bits(),
                    l0.to_bits(),
                    "{model} bits={bits:?} variant={:?} threads={}: eval loss drifted",
                    t.variant,
                    t.gemm_threads
                );
                assert_eq!(c, c0, "{model} bits={bits:?} {t:?}: correct count drifted");
            }
        }
    }
}

fn run_requests(
    model: &'static str,
    kernel: KernelChoice,
    workers: usize,
    max_batch: usize,
    ck: &Checkpoint,
    bits: &[f32],
    requests: &[(Tensor, Tensor)],
) -> Vec<Response> {
    let eng = Engine::start(
        spawner(model, kernel),
        ck.clone(),
        bits.to_vec(),
        ServeConfig {
            workers,
            max_batch,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert!(eng.fused());
    let tickets: Vec<_> = requests
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    let responses: Vec<Response> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let snap = eng.drain().unwrap();
    assert_eq!(snap.completed, requests.len() as u64);
    assert_eq!(snap.failed, 0);
    responses
}

#[test]
fn serve_packed_responses_epsilon_equal_to_reference() {
    const MODEL: &str = "sim_tiny";
    let (ck, graph, data) = setup(MODEL);
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            bits.bits[l.qindex] = 2;
            break;
        }
    }
    let bits = bits.to_f32();
    // Sizes straddle sub-batch, exact-batch, and oversized (split) requests.
    let sizes = [1usize, 3, 8, 20, 2, 5];
    let requests: Vec<(Tensor, Tensor)> = sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| data.batch(Split::Eval, 300 + i as u64, s))
        .collect();
    for &workers in &[1usize, 4] {
        for &max_batch in &[1usize, 8] {
            let rref =
                run_requests(MODEL, KernelChoice::Reference, workers, max_batch, &ck, &bits, &requests);
            let rpk =
                run_requests(MODEL, KernelChoice::Packed, workers, max_batch, &ck, &bits, &requests);
            for ((p, r), (x, _)) in rpk.iter().zip(&rref).zip(&requests) {
                assert_eq!(p.samples, x.shape[0]);
                assert!(
                    (p.loss - r.loss).abs() <= PACKED_LOGIT_EPS,
                    "w={workers} mb={max_batch}: packed loss {} vs reference {}",
                    p.loss,
                    r.loss
                );
                assert_eq!(
                    p.evalout, r.evalout,
                    "w={workers} mb={max_batch}: correct counts must match"
                );
            }
        }
    }
}

/// In per-request mode the engine executes `eval_step`, and packed eval
/// is bit-identical — so even the kernel switch disappears from served
/// results there.
#[test]
fn packed_per_request_serving_is_bit_identical_to_reference_eval() {
    const MODEL: &str = "sim_tiny";
    let (ck, graph, data) = setup(MODEL);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let eng = Engine::start(
        spawner(MODEL, KernelChoice::Packed),
        ck.clone(),
        bits.clone(),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            force_per_request: true,
            warmup: true,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert!(!eng.fused());
    let reqs: Vec<(Tensor, Tensor)> = (0..4)
        .map(|i| data.batch(Split::Eval, 400 + i, 3))
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap())
        .collect();
    let mut rbe = SimBackend::new(MODEL).unwrap();
    for (t, (x, y)) in tickets.into_iter().zip(&reqs) {
        let resp = t.wait().unwrap();
        let (loss, evalout) = rbe.eval_step(&ck, x, y, &bits).unwrap();
        assert_eq!(resp.loss.to_bits(), loss.to_bits());
        assert_eq!(resp.evalout, evalout);
    }
    eng.drain().unwrap();
}
