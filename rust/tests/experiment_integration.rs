//! Integration: the declarative experiment layer — manifest parse /
//! validation, deterministic plan expansion, registry resume, and the
//! scheduler's worker-count bit-identity — all hermetic on the pure-Rust
//! [`SimBackend`] with isolated results roots (no env vars, no artifacts).

use std::path::PathBuf;

use mpq::experiment::{self, plan, ExecOptions, ExperimentSpec};
use mpq::jsonio;

/// Fresh isolated results root per test.
fn tmp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_expit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Toy-scale spec on the sim backend (pipeline semantics, not quality).
fn spec(models: &str, methods: &str, budgets: &str, seeds: &str) -> ExperimentSpec {
    let text = format!(
        r#"{{
            "version": 1,
            "name": "it",
            "backend": "sim",
            "models": [{models}],
            "methods": [{methods}],
            "budgets": [{budgets}],
            "seeds": {seeds},
            "defaults": {{"base_steps": 30, "ft_steps": 3, "eval_batches": 1, "alps_steps": 2}}
        }}"#
    );
    ExperimentSpec::from_json(&jsonio::parse(&text).unwrap()).unwrap()
}

fn opts(root: &PathBuf, workers: usize) -> ExecOptions {
    ExecOptions {
        workers,
        persist: true,
        results_root: Some(root.clone()),
        progress: false,
    }
}

#[test]
fn manifest_file_errors_name_file_and_key() {
    let dir = tmp_root("badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.json");
    std::fs::write(
        &path,
        r#"{"version":1,"models":["sim_tiny"],"methods":["eagl"],"budgets":[2.0],"seeds":1}"#,
    )
    .unwrap();
    let err = ExperimentSpec::from_file(&path).unwrap_err().to_string();
    assert!(err.contains("bad.json"), "{err}");
    assert!(err.contains("budgets[0]"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_expansion_is_stable_across_parses() {
    let a = plan::expand(&spec(r#""sim_tiny","sim_skew""#, r#""eagl","uniform""#, "0.9,0.7", "2"));
    let b = plan::expand(&spec(r#""sim_tiny","sim_skew""#, r#""eagl","uniform""#, "0.9,0.7", "2"));
    assert_eq!(a.runs.len(), 16);
    assert_eq!(a.runs, b.runs);
    let fps: Vec<String> = a.runs.iter().map(|k| k.hex()).collect();
    assert_eq!(fps, b.runs.iter().map(|k| k.hex()).collect::<Vec<_>>());
    let mut uniq = fps.clone();
    uniq.sort();
    uniq.dedup();
    assert_eq!(uniq.len(), 16, "fingerprints must be unique");
}

#[test]
fn resume_skips_completed_cells() {
    let root = tmp_root("resume");
    // first_to_last needs no gain estimation — the fastest full run.
    let s = spec(r#""sim_tiny""#, r#""first_to_last""#, "0.85", "[0, 1]");
    let out1 = experiment::execute(&s, &opts(&root, 1)).unwrap();
    assert_eq!((out1.executed, out1.skipped), (2, 0));
    // Re-invoking the identical manifest re-runs nothing.
    let out2 = experiment::execute(&s, &opts(&root, 1)).unwrap();
    assert_eq!((out2.executed, out2.skipped), (0, 2));
    assert_eq!(out1.records.len(), out2.records.len());
    for (a, b) in out1.records.iter().zip(&out2.records) {
        assert_eq!(a.metric, b.metric, "resumed record must be the stored one");
        assert_eq!(a.seed, b.seed);
    }
    // A grown manifest only runs the new cells (key-level dedup, not
    // whole-sweep dedup).
    let s3 = spec(r#""sim_tiny""#, r#""first_to_last""#, "0.85", "[0, 1, 2]");
    let out3 = experiment::execute(&s3, &opts(&root, 1)).unwrap();
    assert_eq!((out3.executed, out3.skipped), (1, 2));
    let store_text =
        std::fs::read_to_string(root.join("sim_tiny").join("sweep.jsonl")).unwrap();
    assert_eq!(store_text.lines().count(), 3);
    let _ = std::fs::remove_dir_all(&root);
}

/// The acceptance-criteria invariant: the persisted JSONL is *byte*
/// identical between `--workers 1` and `--workers 4`.
#[test]
fn store_bytes_identical_at_any_worker_count() {
    let s = spec(r#""sim_tiny""#, r#""eagl","uniform""#, "0.85,0.7", "2");
    let root1 = tmp_root("w1");
    let root4 = tmp_root("w4");
    let out1 = experiment::execute(&s, &opts(&root1, 1)).unwrap();
    let out4 = experiment::execute(&s, &opts(&root4, 4)).unwrap();
    assert_eq!(out1.executed, 8);
    assert_eq!(out4.executed, 8);
    let b1 = std::fs::read(root1.join("sim_tiny").join("sweep.jsonl")).unwrap();
    let b4 = std::fs::read(root4.join("sim_tiny").join("sweep.jsonl")).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, "workers=1 and workers=4 stores must be bit-identical");
    // Stored records are schedule-invariant: wall time lives on the
    // progress line, not in the store.
    for line in String::from_utf8(b1).unwrap().lines() {
        let v = jsonio::parse(line).unwrap();
        assert_eq!(v.at(&["wall_s"]).as_f64(), Some(0.0), "{line}");
    }
    let _ = std::fs::remove_dir_all(&root1);
    let _ = std::fs::remove_dir_all(&root4);
}

/// Ephemeral execution (`mpq run` path): no registry is written.
#[test]
fn non_persistent_execution_leaves_no_store() {
    let root = tmp_root("ephemeral");
    let s = spec(r#""sim_tiny""#, r#""first_to_last""#, "0.85", "1");
    let out = experiment::execute(
        &s,
        &ExecOptions {
            workers: 1,
            persist: false,
            results_root: Some(root.clone()),
            progress: false,
        },
    )
    .unwrap();
    assert_eq!(out.records.len(), 1);
    assert!(out.records[0].wall_s > 0.0, "ephemeral records keep real wall time");
    assert!(!root.join("sim_tiny").join("sweep.jsonl").exists());
    let _ = std::fs::remove_dir_all(&root);
}
