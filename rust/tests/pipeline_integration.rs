//! Integration: the full evaluation-framework pipeline (Fig. 1) at toy
//! scale — gain estimation → knapsack → checkpoint transform → fine-tune →
//! eval, with the result store and resume semantics.

use mpq::coordinator::{Coordinator, ResultStore};
use mpq::methods::{self, MethodKind};
use mpq::quant::{self, BitsConfig};

fn coord() -> Option<Coordinator> {
    let dir = mpq::artifacts_dir();
    if !dir.join("qsegnet.manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut co = Coordinator::new(&dir, "qsegnet", 1).unwrap();
    // Toy scale: the goal is pipeline semantics, not task quality.
    co.base_steps = 8;
    co.ft_steps = 4;
    co.eval_batches = 1;
    co.mcfg.alps_steps = 3;
    co.mcfg.hawq_samples = 1;
    co.mcfg.hawq_batches = 1;
    // Isolated results dir so CLI/bench caches don't interfere.
    co.results_dir = std::env::temp_dir().join(format!("mpq_it_{}", std::process::id()));
    std::fs::create_dir_all(&co.results_dir).unwrap();
    Some(co)
}

#[test]
fn full_pipeline_all_methods() {
    let Some(mut co) = coord() else { return };
    let ck4 = co.base_checkpoint().unwrap();
    assert!(ck4.total_params() > 0);

    // Every gain-based method produces finite per-layer gains.
    for kind in [MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3, MethodKind::Uniform] {
        let est = co.gains(kind).unwrap();
        assert_eq!(est.per_layer.len(), co.graph.layers.len(), "{kind:?}");
        assert!(
            est.per_layer.iter().all(|g| g.is_finite()),
            "{kind:?}: {:?}",
            est.per_layer
        );
        // Gain cache on disk: second call must be instant and identical.
        let again = co.gains(kind).unwrap();
        assert_eq!(est.per_layer, again.per_layer);
    }

    // Selection respects budgets: higher budget → no fewer groups at hi.
    let mut prev_hi = 0;
    for frac in [0.55, 0.7, 0.85, 1.0] {
        let bits = co.select(MethodKind::Eagl, frac).unwrap();
        let n_hi = co.graph.groups.len() - bits.count_at(&co.graph, 2);
        assert!(n_hi >= prev_hi, "budget {frac}: {n_hi} < {prev_hi}");
        prev_hi = n_hi;
        // Budget actually met.
        let cost: u64 = co
            .graph
            .groups
            .iter()
            .map(|g| {
                let qi = co.graph.layers[g.layer_idx[0]].qindex;
                g.macs * bits.bits[qi] as u64
            })
            .sum();
        assert!(cost <= co.graph.budget_at(frac, 4) + 1);
    }

    // One end-to-end run records a sane metric.
    let rec = co.run_one(MethodKind::Eagl, 0.75, 0).unwrap();
    assert!((0.0..=1.0).contains(&rec.metric), "{rec:?}");
    assert!(rec.compression > 1.0);
    assert!(rec.gbops > 0.0);
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn sweep_resumes_from_store() {
    let Some(mut co) = coord() else { return };
    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path).unwrap();
    let kinds = [MethodKind::FirstToLast];
    let recs = co.sweep(&kinds, &[0.7], &[0, 1], &mut store).unwrap();
    assert_eq!(recs.len(), 2);
    // Second sweep over the same grid touches nothing new.
    let n_before = store.records().len();
    let recs2 = co.sweep(&kinds, &[0.7], &[0, 1], &mut store).unwrap();
    assert_eq!(recs2.len(), 2);
    assert_eq!(store.records().len(), n_before);
    assert_eq!(recs2[0].metric, recs[0].metric);
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn mp_checkpoint_transform_rescales_only_dropped() {
    let Some(mut co) = coord() else { return };
    let ck4 = co.base_checkpoint().unwrap();
    // Drop exactly the first selectable group.
    let mut selected = vec![true; co.graph.groups.len()];
    selected[0] = false;
    let bits = BitsConfig::from_selection(&co.graph, &selected, 4, 2);
    let ck = methods::prepare_mp_checkpoint(&ck4, &co.graph, &bits, 4).unwrap();
    let dropped = &co.graph.groups[0];
    for (gi, group) in co.graph.groups.iter().enumerate() {
        for &li in &group.layer_idx {
            let name = co.graph.layers[li].name.replace('.', "/");
            let s_old = ck4.get(&format!("{name}/sw")).unwrap().item();
            let s_new = ck.get(&format!("{name}/sw")).unwrap().item();
            if gi == 0 {
                assert!((s_new / s_old - 4.0).abs() < 1e-5, "{name} not rescaled");
            } else {
                assert_eq!(s_old, s_new, "{name} wrongly rescaled");
            }
        }
    }
    let _ = dropped;
    // Weights untouched everywhere.
    for (n, t) in ck4.names.iter().zip(&ck4.tensors) {
        if n.ends_with("/w") {
            assert_eq!(t.f32s(), ck.get(n).unwrap().f32s(), "{n}");
        }
    }
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn compression_and_bops_track_bits() {
    let Some(co) = coord() else { return };
    let g = &co.graph;
    let b4 = BitsConfig::uniform(g, 4);
    let b2 = BitsConfig::uniform(g, 2);
    assert!(quant::compression_ratio(g, &b2) > quant::compression_ratio(g, &b4));
    assert!(quant::gbops(g, &b2) < quant::gbops(g, &b4));
    let _ = std::fs::remove_dir_all(&co.results_dir);
}
