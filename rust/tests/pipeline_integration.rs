//! Integration: the full evaluation-framework pipeline (Fig. 1) running
//! hermetically on the pure-Rust [`SimBackend`] — gain estimation →
//! knapsack → checkpoint transform → fine-tune → eval, with the result
//! store and resume semantics.  No `artifacts/` directory is needed; every
//! test here runs (not skips) in a clean checkout and is deterministic.

use mpq::backend::SimBackend;
use mpq::coordinator::{Coordinator, ResultStore, RunRecord};
use mpq::jsonio;
use mpq::methods::{self, MethodKind};
use mpq::quant::{self, BitsConfig};

/// A sim coordinator with an isolated results dir (each test gets its own
/// so on-disk caches never interfere across tests or runs).
fn coord(model: &str, tag: &str) -> Coordinator<SimBackend> {
    let dir = std::env::temp_dir().join(format!("mpq_it_{}_{}_{}", model, tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut co = Coordinator::with_backend(SimBackend::new(model).unwrap(), 1, dir).unwrap();
    // Toy scale: the goal is pipeline semantics, not task quality.
    co.base_steps = 30;
    co.ft_steps = 3;
    co.eval_batches = 1;
    co.mcfg.alps_steps = 2;
    co.mcfg.hawq_samples = 1;
    co.mcfg.hawq_batches = 1;
    co
}

#[test]
fn full_pipeline_all_methods() {
    let mut co = coord("sim_tiny", "allm");
    let ck4 = co.base_checkpoint().unwrap();
    assert!(ck4.total_params() > 0);

    // Every gain-based method produces finite per-layer gains.
    for kind in [MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3, MethodKind::Uniform] {
        let est = co.gains(kind).unwrap();
        assert_eq!(est.per_layer.len(), co.graph.layers.len(), "{kind:?}");
        assert!(
            est.per_layer.iter().all(|g| g.is_finite()),
            "{kind:?}: {:?}",
            est.per_layer
        );
        // Gain cache on disk: second call must be instant and identical.
        let again = co.gains(kind).unwrap();
        assert_eq!(est.per_layer, again.per_layer);
    }

    // Selection respects budgets: higher budget → no fewer groups at hi.
    let mut prev_hi = 0;
    for frac in [0.55, 0.7, 0.85, 1.0] {
        let bits = co.select(MethodKind::Eagl, frac).unwrap();
        let n_hi = co.graph.groups.len() - bits.count_at(&co.graph, 2);
        assert!(n_hi >= prev_hi, "budget {frac}: {n_hi} < {prev_hi}");
        prev_hi = n_hi;
        // Budget actually met.
        let cost: u64 = co
            .graph
            .groups
            .iter()
            .map(|g| {
                let qi = co.graph.layers[g.layer_idx[0]].qindex;
                g.macs * bits.bits[qi] as u64
            })
            .sum();
        assert!(cost <= co.graph.budget_at(frac, 4) + 1);
    }

    // One end-to-end run records a sane metric.
    let rec = co.run_one(MethodKind::Eagl, 0.85, 0).unwrap();
    assert!((0.0..=1.0).contains(&rec.metric), "{rec:?}");
    assert!(rec.compression > 1.0);
    assert!(rec.gbops > 0.0);
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn run_record_appends_parseable_jsonl() {
    let mut co = coord("sim_tiny", "jsonl");
    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path).unwrap();
    let rec = co.run_one(MethodKind::Eagl, 0.85, 0).unwrap();
    store.append(&rec).unwrap();
    // The appended line must be parseable JSON that round-trips into an
    // identical RunRecord.
    let text = std::fs::read_to_string(&store_path).unwrap();
    let line = text.lines().next().unwrap();
    let parsed = RunRecord::from_json(&jsonio::parse(line).unwrap()).unwrap();
    assert_eq!(parsed.model, "sim_tiny");
    assert_eq!(parsed.method, "eagl");
    assert_eq!(parsed.seed, 0);
    assert!((parsed.metric - rec.metric).abs() < 1e-12);
    assert!((parsed.budget_frac - 0.85).abs() < 1e-12);
    // And the store resumes from it.
    let store2 = ResultStore::open(&store_path).unwrap();
    assert!(store2.find("sim_tiny", "eagl", 0.85, 0).is_some());
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn sweep_resumes_from_store() {
    let mut co = coord("sim_tiny", "sweep");
    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path).unwrap();
    let kinds = [MethodKind::FirstToLast];
    let recs = co.sweep(&kinds, &[0.85], &[0, 1], &mut store).unwrap();
    assert_eq!(recs.len(), 2);
    // Second sweep over the same grid touches nothing new.
    let n_before = store.records().len();
    let recs2 = co.sweep(&kinds, &[0.85], &[0, 1], &mut store).unwrap();
    assert_eq!(recs2.len(), 2);
    assert_eq!(store.records().len(), n_before);
    assert_eq!(recs2[0].metric, recs[0].metric);
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn mp_checkpoint_transform_rescales_only_dropped() {
    let mut co = coord("sim_tiny", "rescale");
    let ck4 = co.base_checkpoint().unwrap();
    // Drop exactly the first selectable group.
    let mut selected = vec![true; co.graph.groups.len()];
    selected[0] = false;
    let bits = BitsConfig::from_selection(&co.graph, &selected, 4, 2);
    let ck = methods::prepare_mp_checkpoint(&ck4, &co.graph, &bits, 4).unwrap();
    for (gi, group) in co.graph.groups.iter().enumerate() {
        for &li in &group.layer_idx {
            let name = co.graph.layers[li].name.replace('.', "/");
            let s_old = ck4.get(&format!("{name}/sw")).unwrap().item();
            let s_new = ck.get(&format!("{name}/sw")).unwrap().item();
            if gi == 0 {
                assert!((s_new / s_old - 4.0).abs() < 1e-5, "{name} not rescaled");
            } else {
                assert_eq!(s_old, s_new, "{name} wrongly rescaled");
            }
        }
    }
    // Weights untouched everywhere.
    for (n, t) in ck4.names.iter().zip(&ck4.tensors) {
        if n.ends_with("/w") {
            assert_eq!(t.f32s(), ck.get(n).unwrap().f32s(), "{n}");
        }
    }
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn compression_and_bops_track_bits() {
    let co = coord("sim_tiny", "bops");
    let g = &co.graph;
    let b4 = BitsConfig::uniform(g, 4);
    let b2 = BitsConfig::uniform(g, 2);
    assert!(quant::compression_ratio(g, &b2) > quant::compression_ratio(g, &b4));
    assert!(quant::gbops(g, &b2) < quant::gbops(g, &b4));
    let _ = std::fs::remove_dir_all(&co.results_dir);
}

#[test]
fn deterministic_across_consecutive_runs() {
    // Two coordinators in fresh dirs (no cache sharing) must reproduce the
    // exact same record for the same (model, method, budget, seed).
    let mut a = coord("sim_tiny", "det_a");
    let mut b = coord("sim_tiny", "det_b");
    let ra = a.run_one(MethodKind::Eagl, 0.85, 0).unwrap();
    let rb = b.run_one(MethodKind::Eagl, 0.85, 0).unwrap();
    assert_eq!(ra.metric, rb.metric, "metric must be bit-identical");
    assert_eq!(ra.loss, rb.loss, "loss must be bit-identical");
    assert_eq!(ra.groups_at_lo, rb.groups_at_lo);
    let _ = std::fs::remove_dir_all(&a.results_dir);
    let _ = std::fs::remove_dir_all(&b.results_dir);
}

/// The headline hermetic test: on `sim_skew` — a model with a deliberately
/// low-entropy (but compute-light) residual stack and a high-entropy,
/// compute-heavy main layer — EAGL keeps the fragile high-entropy layer at
/// 4-bit while the uniform-gain baseline (which optimizes group count
/// alone) drops it, and EAGL's frontier point dominates.
#[test]
fn eagl_beats_uniform_on_skewed_model() {
    let dir = std::env::temp_dir().join(format!("mpq_it_skew_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut co =
        Coordinator::with_backend(SimBackend::new("sim_skew").unwrap(), 1, dir).unwrap();
    co.base_steps = 250;
    co.ft_steps = 4;
    co.eval_batches = 4;
    let budget = 0.92;

    let qi = |co: &Coordinator<SimBackend>, name: &str| {
        co.graph.layers.iter().find(|l| l.name == name).unwrap().qindex
    };

    // Selection shape is fully determined by the engineered entropies:
    // EAGL spends the budget on the high-entropy `wide` group; uniform
    // gains maximize group count and keep the cheap low-entropy groups.
    let bits_e = co.select(MethodKind::Eagl, budget).unwrap();
    assert_eq!(bits_e.bits[qi(&co, "wide")], 4, "eagl must keep wide at 4-bit");
    assert_eq!(bits_e.bits[qi(&co, "idty")], 2);
    assert_eq!(bits_e.bits[qi(&co, "mix_a")], 2);
    let bits_u = co.select(MethodKind::Uniform, budget).unwrap();
    assert_eq!(bits_u.bits[qi(&co, "wide")], 2, "uniform must drop wide to 2-bit");
    assert_eq!(bits_u.bits[qi(&co, "idty")], 4);

    // And the frontier point: EAGL's choice preserves the task while the
    // uniform baseline destroys the precision-critical main path.
    let rec_e = co.run_one(MethodKind::Eagl, budget, 0).unwrap();
    let rec_u = co.run_one(MethodKind::Uniform, budget, 0).unwrap();
    assert!(
        rec_e.metric >= rec_u.metric,
        "eagl {} must be at least uniform {}",
        rec_e.metric,
        rec_u.metric
    );
    assert!(rec_e.metric >= 0.85, "eagl config must stay near-lossless: {}", rec_e.metric);
    assert!(
        rec_e.loss + 0.05 < rec_u.loss,
        "eagl loss {} must clearly beat uniform loss {}",
        rec_e.loss,
        rec_u.loss
    );
    let _ = std::fs::remove_dir_all(&co.results_dir);
}
