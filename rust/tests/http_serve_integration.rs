//! End-to-end tests for the HTTP/1.1 front door (`mpq serve --listen`).
//!
//! The central contract: **the socket path changes nothing** — a loadgen
//! run over real loopback TCP returns responses bit-identical to an
//! in-process engine run for the same (seed, index) request stream, at
//! any worker count and on both kernel paths (the exact-f32 `*_bits`
//! JSON transport is what makes this possible).  Around it: the
//! documented status-code contract for malformed input with the
//! connection left in a defined state, admission control that fails fast
//! without ever losing accepted work, graceful drain mid-burst, the
//! pinned `/metrics` text format, and keep-alive limits.
//!
//! Hermetic: sim backend, seeded init checkpoint, loopback sockets on
//! port 0 — no training, no artifacts, no fixed ports.

use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, KernelChoice, SimBackend};
use mpq::ckpt::Checkpoint;
use mpq::data::Dataset;
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::serve::http::client::HttpClient;
use mpq::serve::{
    check_trace_text, loadgen, Engine, FrontierStep, HttpConfig, HttpServer, LoadMode, LoadSpec,
    ServeConfig, Spawner, SwapRegistry, TraceConfig, TraceSink,
};

const MODEL: &str = "sim_tiny";

/// (checkpoint, mixed-precision bits, dataset) for the test model —
/// deterministic, so two calls build bit-identical engines.
fn setup() -> (Checkpoint, Vec<f32>, Dataset) {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            bits.bits[l.qindex] = 2;
            break;
        }
    }
    (ck, bits.to_f32(), Dataset::for_task(be.manifest().task, 11))
}

fn engine_with(
    workers: usize,
    kernel: KernelChoice,
    max_batch: usize,
    timeout: Duration,
    trace: Option<std::sync::Arc<TraceSink>>,
) -> Engine {
    let (ck, bits, _) = setup();
    let spawner: Spawner = Arc::new(move || {
        Ok(Box::new(SimBackend::with_kernel(MODEL, kernel)?) as Box<dyn Backend>)
    });
    Engine::start(
        spawner,
        ck,
        bits,
        ServeConfig {
            workers,
            max_batch,
            batch_timeout: timeout,
            force_per_request: false,
            warmup: true,
            trace,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

/// Every front door in this file runs with tracing ON (sample=1): the
/// bit-identity, robustness and drain contracts must all hold unchanged
/// while every request is being traced.
fn engine(workers: usize, kernel: KernelChoice, max_batch: usize, timeout: Duration) -> Engine {
    engine_with(
        workers,
        kernel,
        max_batch,
        timeout,
        Some(TraceSink::new(TraceConfig::default())),
    )
}

/// A served front door over a fresh engine; `addr` is the picked port.
fn server(
    workers: usize,
    kernel: KernelChoice,
    max_batch: usize,
    timeout: Duration,
    hcfg: HttpConfig,
) -> (HttpServer, String) {
    let (_, _, data) = setup();
    let eng = engine(workers, kernel, max_batch, timeout);
    let srv = HttpServer::start(eng, data, hcfg).unwrap();
    let addr = srv.local_addr().to_string();
    (srv, addr)
}

fn default_server(workers: usize, kernel: KernelChoice) -> (HttpServer, String) {
    server(
        workers,
        kernel,
        8,
        Duration::from_millis(1),
        HttpConfig::default(),
    )
}

// ---------------------------------------------------------------------------
// Bit-identity: socket loadgen == in-process engine
// ---------------------------------------------------------------------------

#[test]
fn socket_loadgen_bit_identical_to_in_process_engine() {
    let spec = LoadSpec {
        requests: 24,
        max_request_samples: 3,
        seed: 42,
        mode: LoadMode::Closed { concurrency: 4 },
    };
    for &workers in &[1usize, 4] {
        for &kernel in &[KernelChoice::Reference, KernelChoice::Packed] {
            // In-process reference run.
            let (_, _, data) = setup();
            let eng = engine(workers, kernel, 8, Duration::from_millis(1));
            let local = loadgen::run(&eng, &data, &spec).unwrap();
            eng.drain().unwrap();
            // The same stream over a real loopback socket.
            let (srv, addr) = default_server(workers, kernel);
            let remote = loadgen::run_http(&addr, &spec).unwrap();
            let (snap, hstats) = srv.shutdown().unwrap();
            // Every request answered exactly once...
            assert_eq!(remote.responses.len(), spec.requests);
            assert_eq!(snap.completed, spec.requests as u64);
            assert_eq!(hstats.admitted, spec.requests as u64);
            assert_eq!(hstats.answered, spec.requests as u64);
            assert_eq!((hstats.failed, hstats.aborted), (0, 0));
            // ...with monotone contiguous ids (run_http also asserts this
            // internally; re-check here so the contract is visible).
            let mut ids: Vec<u64> = remote.responses.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), spec.requests);
            assert_eq!(ids[ids.len() - 1] - ids[0] + 1, spec.requests as u64);
            // ...and bit-identical to the in-process run, request by
            // request.  Holds on the packed path too: the engine's
            // responses are bit-identical at any batch composition; only
            // direct unbatched eval is epsilon-distant.
            for (i, (a, b)) in local.responses.iter().zip(&remote.responses).enumerate() {
                assert_eq!(a.samples, b.samples, "request {i} samples (w={workers})");
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "request {i} loss bits (w={workers}, {} kernels)",
                    kernel.name()
                );
                assert_eq!(
                    a.evalout, b.evalout,
                    "request {i} evalout (w={workers}, {} kernels)",
                    kernel.name()
                );
            }
            assert_eq!(local.total_samples, remote.total_samples);
        }
    }
}

#[test]
fn open_loop_over_sockets_answers_every_request() {
    let (srv, addr) = default_server(2, KernelChoice::Packed);
    let spec = LoadSpec {
        requests: 20,
        max_request_samples: 2,
        seed: 7,
        mode: LoadMode::Open { rate_hz: 500.0 },
    };
    let load = loadgen::run_http(&addr, &spec).unwrap();
    assert_eq!(load.responses.len(), 20);
    assert!(load.throughput_rps > 0.0);
    let (snap, hstats) = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 20);
    assert_eq!(hstats.admitted, hstats.answered);
}

// ---------------------------------------------------------------------------
// Malformed input: documented status, defined connection state, no hangs
// ---------------------------------------------------------------------------

/// Table-driven socket-level robustness: each raw byte blob must yield
/// the documented status code, and the advertised connection state must
/// be real (close → recv of a follow-up fails; keep-alive → a follow-up
/// `/healthz` still answers 200).
#[test]
fn malformed_requests_get_documented_status_and_connection_state() {
    let (srv, addr) = default_server(1, KernelChoice::Reference);
    struct Case {
        name: &'static str,
        raw: Vec<u8>,
        status: u16,
        closes: bool,
    }
    let cases = vec![
        Case {
            name: "lowercase method",
            raw: b"get /healthz HTTP/1.1\r\n\r\n".to_vec(),
            status: 400,
            closes: true,
        },
        Case {
            name: "unsupported version",
            raw: b"GET /healthz HTTP/2.0\r\n\r\n".to_vec(),
            status: 505,
            closes: true,
        },
        Case {
            name: "header without colon",
            raw: b"GET /healthz HTTP/1.1\r\nbogus line\r\n\r\n".to_vec(),
            status: 400,
            closes: true,
        },
        Case {
            name: "unparseable content-length",
            raw: b"POST /infer HTTP/1.1\r\ncontent-length: many\r\n\r\n".to_vec(),
            status: 400,
            closes: true,
        },
        Case {
            name: "oversized headers",
            raw: {
                let mut r = b"GET /healthz HTTP/1.1\r\nx-pad: ".to_vec();
                r.extend(std::iter::repeat(b'a').take(9 * 1024));
                r.extend_from_slice(b"\r\n\r\n");
                r
            },
            status: 431,
            closes: true,
        },
        Case {
            name: "transfer-encoding",
            raw: b"POST /infer HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n".to_vec(),
            status: 501,
            closes: true,
        },
        Case {
            name: "body over limit",
            raw: b"POST /infer HTTP/1.1\r\ncontent-length: 9999999\r\n\r\n".to_vec(),
            status: 413,
            closes: true,
        },
        Case {
            name: "unknown path keeps the connection",
            raw: b"GET /nope HTTP/1.1\r\n\r\n".to_vec(),
            status: 404,
            closes: false,
        },
        Case {
            name: "wrong method on a known path keeps the connection",
            raw: b"GET /infer HTTP/1.1\r\n\r\n".to_vec(),
            status: 405,
            closes: false,
        },
        Case {
            name: "well-framed bad JSON keeps the connection",
            raw: b"POST /infer HTTP/1.1\r\ncontent-length: 9\r\n\r\nnot json!".to_vec(),
            status: 400,
            closes: false,
        },
        Case {
            name: "missing samples field keeps the connection",
            raw: b"POST /infer HTTP/1.1\r\ncontent-length: 12\r\n\r\n{\"index\": 3}".to_vec(),
            status: 400,
            closes: false,
        },
        Case {
            name: "zero samples rejected",
            raw: b"POST /infer HTTP/1.1\r\ncontent-length: 25\r\n\r\n{\"index\":1,\"samples\":0}  ".to_vec(),
            status: 400,
            closes: false,
        },
    ];
    for case in cases {
        let mut c = HttpClient::connect(&addr).unwrap();
        c.send_raw(&case.raw).unwrap();
        let resp = c.recv().unwrap_or_else(|e| panic!("{}: {e}", case.name));
        assert_eq!(resp.status, case.status, "{}", case.name);
        if case.closes {
            assert_eq!(
                resp.header("connection"),
                Some("close"),
                "{}: must advertise close",
                case.name
            );
            c.send_raw(b"GET /healthz HTTP/1.1\r\n\r\n").ok();
            assert!(
                c.recv().is_err(),
                "{}: connection must actually be closed",
                case.name
            );
        } else {
            let follow = c.get("/healthz").unwrap_or_else(|e| {
                panic!("{}: keep-alive connection must stay usable: {e}", case.name)
            });
            assert_eq!(follow.status, 200, "{}", case.name);
            assert_eq!(follow.body, b"ok\n", "{}", case.name);
        }
    }
    srv.shutdown().unwrap();
}

/// A valid request dribbled in across several writes parses exactly like
/// a single write (the parser's own unit tests split at *every* byte
/// boundary; this re-checks the path through a real socket).
#[test]
fn split_writes_across_the_socket_still_parse() {
    let (srv, addr) = default_server(1, KernelChoice::Reference);
    let raw: &[u8] = b"POST /infer HTTP/1.1\r\ncontent-length: 23\r\n\r\n{\"index\":5,\"samples\":2}";
    let mut c = HttpClient::connect(&addr).unwrap();
    for chunk in raw.chunks(7) {
        c.send_raw(chunk).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let resp = c.recv().unwrap();
    assert_eq!(resp.status, 200);
    let r = mpq::serve::http::parse_infer_response(&resp.body).unwrap();
    assert_eq!(r.samples, 2);
    srv.shutdown().unwrap();
}

/// A truncated body followed by a client half-close never produces a
/// response — the partial request was never admitted, and the server
/// closes without panicking or hanging.
#[test]
fn truncated_body_then_eof_closes_without_a_response() {
    let (srv, addr) = default_server(1, KernelChoice::Reference);
    let mut c = HttpClient::connect(&addr).unwrap();
    c.send_raw(b"POST /infer HTTP/1.1\r\ncontent-length: 23\r\n\r\n{\"index\":")
        .unwrap();
    c.shutdown_write();
    assert!(c.recv().is_err(), "no response for a request that never completed");
    let (snap, hstats) = srv.shutdown().unwrap();
    assert_eq!(hstats.admitted, 0);
    assert_eq!(snap.submitted, 0);
}

/// Pipelined requests on one connection are answered in order.
#[test]
fn pipelined_requests_answered_in_order() {
    let (srv, addr) = default_server(2, KernelChoice::Reference);
    let mut c = HttpClient::connect(&addr).unwrap();
    for i in 0..3u64 {
        let body = format!("{{\"index\":{i},\"samples\":{}}}", i + 1);
        c.send("POST", "/infer", Some(body.as_bytes())).unwrap();
    }
    for i in 0..3u64 {
        let resp = c.recv().unwrap();
        assert_eq!(resp.status, 200);
        let r = mpq::serve::http::parse_infer_response(&resp.body).unwrap();
        assert_eq!(r.samples as u64, i + 1, "responses must come back in request order");
    }
    srv.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Admission control and graceful drain
// ---------------------------------------------------------------------------

/// Overload answers 503 + `Retry-After` immediately, and no *accepted*
/// request is ever lost: admitted == answered exactly, rejects answered
/// on live keep-alive connections.
#[test]
fn queue_full_is_503_with_zero_accepted_request_loss() {
    // workers=1 with a huge batch size and a long deadline parks admitted
    // requests deterministically; capacity 2 makes the third admission
    // fail fast.
    let (srv, addr) = server(
        1,
        KernelChoice::Reference,
        64,
        Duration::from_millis(700),
        HttpConfig {
            queue_capacity: 2,
            ..HttpConfig::default()
        },
    );
    let mut held: Vec<HttpClient> = Vec::new();
    for i in 0..2 {
        let mut c = HttpClient::connect(&addr).unwrap();
        let body = format!("{{\"index\":{i},\"samples\":1}}");
        c.send("POST", "/infer", Some(body.as_bytes())).unwrap();
        held.push(c);
    }
    // Let the server parse + admit both before the overload probes.
    std::thread::sleep(Duration::from_millis(250));
    for i in 0..4 {
        let mut c = HttpClient::connect(&addr).unwrap();
        let resp = c.post("/infer", b"{\"index\":9,\"samples\":1}").unwrap();
        assert_eq!(resp.status, 503, "overload probe {i}");
        assert!(
            resp.header("retry-after").is_some(),
            "503 must carry Retry-After"
        );
        // Queue-full keeps the connection: the client may retry here.
        let follow = c.get("/healthz").unwrap();
        assert_eq!(follow.status, 200);
    }
    // The two admitted requests complete once the batch deadline fires.
    for mut c in held {
        let resp = c.recv().unwrap();
        assert_eq!(resp.status, 200, "admitted request must complete");
    }
    let (snap, hstats) = srv.shutdown().unwrap();
    assert_eq!(hstats.admitted, 2);
    assert_eq!(hstats.answered, 2, "accepted count must equal answered count");
    assert_eq!(hstats.rejected, 4);
    assert_eq!((hstats.failed, hstats.aborted), (0, 0));
    assert_eq!(snap.completed, 2);
}

/// Shutdown mid-burst: every admitted request drains to a written
/// response before sockets close, and the listener stops accepting.
#[test]
fn shutdown_mid_burst_drains_all_accepted_work() {
    let (srv, addr) = server(
        1,
        KernelChoice::Reference,
        64,
        Duration::from_millis(300),
        HttpConfig::default(),
    );
    // 3 connections × 2 pipelined requests, all parked at the batch
    // deadline when shutdown lands.
    let mut clients: Vec<HttpClient> = Vec::new();
    for ci in 0..3 {
        let mut c = HttpClient::connect(&addr).unwrap();
        for rj in 0..2 {
            let body = format!("{{\"index\":{},\"samples\":1}}", ci * 2 + rj);
            c.send("POST", "/infer", Some(body.as_bytes())).unwrap();
        }
        clients.push(c);
    }
    std::thread::sleep(Duration::from_millis(100));
    let (snap, hstats) = srv.shutdown().unwrap();
    assert_eq!(hstats.admitted, 6);
    assert_eq!(hstats.answered, 6, "drain must flush every admitted request");
    assert_eq!((hstats.failed, hstats.aborted), (0, 0));
    assert_eq!(snap.completed, 6);
    // The responses were written before the sockets closed.
    for (ci, c) in clients.iter_mut().enumerate() {
        for rj in 0..2 {
            let resp = c.recv().unwrap_or_else(|e| panic!("conn {ci} resp {rj}: {e}"));
            assert_eq!(resp.status, 200);
        }
        assert!(c.recv().is_err(), "socket must be closed after the drain");
    }
    // And the front door is gone: a new connection cannot be served.
    match HttpClient::connect(&addr) {
        Err(_) => {}
        Ok(mut c) => assert!(c.post("/infer", b"{\"index\":0,\"samples\":1}").is_err()),
    }
}

// ---------------------------------------------------------------------------
// Keep-alive limits
// ---------------------------------------------------------------------------

#[test]
fn keepalive_budget_closes_after_the_limit_with_explicit_header() {
    let (srv, addr) = server(
        1,
        KernelChoice::Reference,
        8,
        Duration::from_millis(1),
        HttpConfig {
            max_requests_per_conn: 3,
            ..HttpConfig::default()
        },
    );
    let mut c = HttpClient::connect(&addr).unwrap();
    for _ in 0..4 {
        c.send("GET", "/healthz", None).unwrap();
    }
    for i in 0..3 {
        let resp = c.recv().unwrap();
        assert_eq!(resp.status, 200);
        let expect_close = i == 2;
        assert_eq!(
            resp.header("connection") == Some("close"),
            expect_close,
            "response {i}: close exactly on the budget boundary"
        );
    }
    assert!(c.recv().is_err(), "4th request is past the budget: closed");
    // The loadgen reconnects transparently across the budget.
    srv.shutdown().unwrap();
    let (srv, addr) = server(
        2,
        KernelChoice::Reference,
        8,
        Duration::from_millis(1),
        HttpConfig {
            max_requests_per_conn: 3,
            ..HttpConfig::default()
        },
    );
    let spec = LoadSpec {
        requests: 10,
        max_request_samples: 2,
        seed: 42,
        mode: LoadMode::Closed { concurrency: 2 },
    };
    let load = loadgen::run_http(&addr, &spec).unwrap();
    assert_eq!(load.responses.len(), 10);
    let (_, hstats) = srv.shutdown().unwrap();
    assert_eq!(hstats.admitted, 10);
    assert!(
        hstats.connections > 2,
        "budget 3 over 10 requests forces reconnects (got {} connections)",
        hstats.connections
    );
}

// ---------------------------------------------------------------------------
// /metrics golden format
// ---------------------------------------------------------------------------

/// The pinned `/metrics` text line sequence with tracing ON: the
/// comment header, a `# HELP`/`# TYPE` pair ahead of every family, the
/// value lines in order, and the `mpq_stage_*` section appended last.
/// The tracing-off rendering is this list minus [`STAGE_LINES`] tail
/// entries (a strict prefix — see
/// `stage_section_appears_only_while_tracing`).
const GOLDEN: &[&str] = &[
    "# mpq serve /metrics v1",
    "# HELP mpq_http_connections_total Connections accepted by the front door.",
    "# TYPE mpq_http_connections_total counter",
    "mpq_http_connections_total",
    "# HELP mpq_http_requests_admitted_total Requests admitted to the engine.",
    "# TYPE mpq_http_requests_admitted_total counter",
    "mpq_http_requests_admitted_total",
    "# HELP mpq_http_requests_rejected_total Requests rejected with 503.",
    "# TYPE mpq_http_requests_rejected_total counter",
    "mpq_http_requests_rejected_total",
    "# HELP mpq_http_requests_answered_total Admitted requests answered 200.",
    "# TYPE mpq_http_requests_answered_total counter",
    "mpq_http_requests_answered_total",
    "# HELP mpq_http_requests_failed_total Admitted requests answered 500.",
    "# TYPE mpq_http_requests_failed_total counter",
    "mpq_http_requests_failed_total",
    "# HELP mpq_http_requests_aborted_total Admitted requests whose connection died first.",
    "# TYPE mpq_http_requests_aborted_total counter",
    "mpq_http_requests_aborted_total",
    "# HELP mpq_http_bad_requests_total Non-2xx, non-503 responses.",
    "# TYPE mpq_http_bad_requests_total counter",
    "mpq_http_bad_requests_total",
    "# HELP mpq_http_metrics_scrapes_total GET /metrics requests served.",
    "# TYPE mpq_http_metrics_scrapes_total counter",
    "mpq_http_metrics_scrapes_total",
    "# HELP mpq_http_inflight_requests Admitted requests awaiting their response.",
    "# TYPE mpq_http_inflight_requests gauge",
    "mpq_http_inflight_requests",
    "# HELP mpq_engine_queue_samples Samples queued and not yet claimed by a worker.",
    "# TYPE mpq_engine_queue_samples gauge",
    "mpq_engine_queue_samples",
    "# HELP mpq_ctl_epoch Current serving epoch.",
    "# TYPE mpq_ctl_epoch gauge",
    "mpq_ctl_epoch",
    "# HELP mpq_ctl_swap_total Successful hot-swaps since startup.",
    "# TYPE mpq_ctl_swap_total counter",
    "mpq_ctl_swap_total",
    "# HELP mpq_ctl_active_budget Budget fraction of the active config.",
    "# TYPE mpq_ctl_active_budget gauge",
    "mpq_ctl_active_budget",
    "# HELP mpq_ctl_frontier_levels Pre-materialized frontier levels available to /swap.",
    "# TYPE mpq_ctl_frontier_levels gauge",
    "mpq_ctl_frontier_levels",
    "# HELP mpq_engine_requests_submitted_total Requests accepted into the batch queue.",
    "# TYPE mpq_engine_requests_submitted_total counter",
    "mpq_engine_requests_submitted_total",
    "# HELP mpq_engine_requests_completed_total Requests completed successfully.",
    "# TYPE mpq_engine_requests_completed_total counter",
    "mpq_engine_requests_completed_total",
    "# HELP mpq_engine_requests_failed_total Requests that failed inside the engine.",
    "# TYPE mpq_engine_requests_failed_total counter",
    "mpq_engine_requests_failed_total",
    "# HELP mpq_engine_samples_total Samples across completed requests.",
    "# TYPE mpq_engine_samples_total counter",
    "mpq_engine_samples_total",
    "# HELP mpq_engine_batches_total Micro-batches dispatched to workers.",
    "# TYPE mpq_engine_batches_total counter",
    "mpq_engine_batches_total",
    "# HELP mpq_engine_batch_chunks_total Request chunks across all dispatched batches.",
    "# TYPE mpq_engine_batch_chunks_total counter",
    "mpq_engine_batch_chunks_total",
    "# HELP mpq_engine_batch_samples_total Samples across all dispatched batches.",
    "# TYPE mpq_engine_batch_samples_total counter",
    "mpq_engine_batch_samples_total",
    "# HELP mpq_engine_batch_occupancy_mean Mean samples per dispatched micro-batch.",
    "# TYPE mpq_engine_batch_occupancy_mean gauge",
    "mpq_engine_batch_occupancy_mean",
    "# HELP mpq_engine_throughput_rps Completed requests per second of uptime.",
    "# TYPE mpq_engine_throughput_rps gauge",
    "mpq_engine_throughput_rps",
    "# HELP mpq_engine_latency_seconds_mean Mean request latency.",
    "# TYPE mpq_engine_latency_seconds_mean gauge",
    "mpq_engine_latency_seconds_mean",
    "# HELP mpq_engine_latency_seconds_min Minimum request latency.",
    "# TYPE mpq_engine_latency_seconds_min gauge",
    "mpq_engine_latency_seconds_min",
    "# HELP mpq_engine_latency_seconds_max Maximum request latency.",
    "# TYPE mpq_engine_latency_seconds_max gauge",
    "mpq_engine_latency_seconds_max",
    "# HELP mpq_engine_latency_seconds Request latency quantiles from the lock-free histogram.",
    "# TYPE mpq_engine_latency_seconds summary",
    "mpq_engine_latency_seconds{quantile=\"0.5\"}",
    "mpq_engine_latency_seconds{quantile=\"0.95\"}",
    "mpq_engine_latency_seconds{quantile=\"0.99\"}",
    "# HELP mpq_engine_uptime_seconds Seconds since the engine metrics window opened.",
    "# TYPE mpq_engine_uptime_seconds gauge",
    "mpq_engine_uptime_seconds",
    "# HELP mpq_stage_latency_seconds Per-stage latency over sampled traced requests.",
    "# TYPE mpq_stage_latency_seconds summary",
    "mpq_stage_latency_seconds{stage=\"http_parse\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"http_parse\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"http_parse\"}",
    "mpq_stage_latency_seconds_sum{stage=\"http_parse\"}",
    "mpq_stage_latency_seconds{stage=\"admission\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"admission\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"admission\"}",
    "mpq_stage_latency_seconds_sum{stage=\"admission\"}",
    "mpq_stage_latency_seconds{stage=\"queue_wait\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"queue_wait\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"queue_wait\"}",
    "mpq_stage_latency_seconds_sum{stage=\"queue_wait\"}",
    "mpq_stage_latency_seconds{stage=\"batch_assembly\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"batch_assembly\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"batch_assembly\"}",
    "mpq_stage_latency_seconds_sum{stage=\"batch_assembly\"}",
    "mpq_stage_latency_seconds{stage=\"layer_gemm\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"layer_gemm\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"layer_gemm\"}",
    "mpq_stage_latency_seconds_sum{stage=\"layer_gemm\"}",
    "mpq_stage_latency_seconds{stage=\"reassembly\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"reassembly\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"reassembly\"}",
    "mpq_stage_latency_seconds_sum{stage=\"reassembly\"}",
    "mpq_stage_latency_seconds{stage=\"epilogue\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"epilogue\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"epilogue\"}",
    "mpq_stage_latency_seconds_sum{stage=\"epilogue\"}",
    "mpq_stage_latency_seconds{stage=\"serialize\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"serialize\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"serialize\"}",
    "mpq_stage_latency_seconds_sum{stage=\"serialize\"}",
    "mpq_stage_latency_seconds{stage=\"socket_write\",quantile=\"0.5\"}",
    "mpq_stage_latency_seconds{stage=\"socket_write\",quantile=\"0.99\"}",
    "mpq_stage_latency_seconds_count{stage=\"socket_write\"}",
    "mpq_stage_latency_seconds_sum{stage=\"socket_write\"}",
];

/// Trailing GOLDEN entries that exist only while tracing is on:
/// the stage family header pair + 4 lines for each of the 9 stages.
const STAGE_LINES: usize = 2 + 9 * 4;

fn parse_scrape(text: &str) -> Vec<(String, f64)> {
    text.lines()
        .map(|line| {
            if line.starts_with('#') {
                return (line.to_string(), 0.0);
            }
            let (name, value) = line
                .rsplit_once(' ')
                .unwrap_or_else(|| panic!("metrics line without value: '{line}'"));
            let v: f64 = value
                .parse()
                .unwrap_or_else(|_| panic!("non-numeric metrics value: '{line}'"));
            (name.to_string(), v)
        })
        .collect()
}

#[test]
fn metrics_text_format_is_pinned_and_counters_monotone() {
    let (srv, addr) = default_server(2, KernelChoice::Packed);
    let mut c = HttpClient::connect(&addr).unwrap();
    for i in 0..4u64 {
        let body = format!("{{\"index\":{i},\"samples\":2}}");
        let resp = c.post("/infer", body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200);
    }
    let scrape1 = c.get("/metrics").unwrap();
    assert_eq!(scrape1.status, 200);
    assert!(scrape1
        .header("content-type")
        .is_some_and(|ct| ct.starts_with("text/plain")));
    let m1 = parse_scrape(&scrape1.body_str());
    let names: Vec<&str> = m1.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(
        names, GOLDEN,
        "/metrics field names/order changed — this format is pinned; \
         dashboards parse it.  Only append new lines (and update GOLDEN)."
    );
    // The scrape accounts for the traffic so far.
    let get = |m: &[(String, f64)], n: &str| {
        m.iter().find(|(k, _)| k == n).map(|(_, v)| *v).unwrap()
    };
    assert_eq!(get(&m1, "mpq_http_requests_answered_total"), 4.0);
    assert_eq!(get(&m1, "mpq_engine_requests_completed_total"), 4.0);
    assert_eq!(get(&m1, "mpq_http_metrics_scrapes_total"), 1.0);
    assert!(get(&m1, "mpq_engine_latency_seconds{quantile=\"0.5\"}") > 0.0);
    assert!(
        get(&m1, "mpq_engine_latency_seconds{quantile=\"0.99\"}")
            >= get(&m1, "mpq_engine_latency_seconds{quantile=\"0.5\"}")
    );
    // Tracing is on (sample=1): every request so far hit both the engine
    // epilogue and the socket-side parse window.
    assert_eq!(get(&m1, "mpq_stage_latency_seconds_count{stage=\"epilogue\"}"), 4.0);
    assert_eq!(get(&m1, "mpq_stage_latency_seconds_count{stage=\"http_parse\"}"), 4.0);
    assert!(get(&m1, "mpq_stage_latency_seconds_sum{stage=\"layer_gemm\"}") > 0.0);
    // More traffic, second scrape: counters are monotone.
    for i in 0..3u64 {
        let body = format!("{{\"index\":{},\"samples\":1}}", 100 + i);
        assert_eq!(c.post("/infer", body.as_bytes()).unwrap().status, 200);
    }
    let m2 = parse_scrape(&c.get("/metrics").unwrap().body_str());
    for (name, v1) in &m1 {
        if name.ends_with("_total") {
            let v2 = get(&m2, name);
            assert!(
                v2 >= *v1,
                "counter {name} went backwards across scrapes: {v1} -> {v2}"
            );
        }
    }
    assert_eq!(get(&m2, "mpq_http_requests_answered_total"), 7.0);
    assert_eq!(get(&m2, "mpq_http_metrics_scrapes_total"), 2.0);
    srv.shutdown().unwrap();
}

/// Tracing off: `/metrics` is exactly the GOLDEN list minus the
/// `mpq_stage_*` tail — a strict prefix, so dashboards written against
/// either mode parse both.
#[test]
fn stage_section_appears_only_while_tracing() {
    let (_, _, data) = setup();
    let eng = engine_with(1, KernelChoice::Reference, 8, Duration::from_millis(1), None);
    let srv = HttpServer::start(eng, data, HttpConfig::default()).unwrap();
    let addr = srv.local_addr().to_string();
    let mut c = HttpClient::connect(&addr).unwrap();
    assert_eq!(c.post("/infer", b"{\"index\":0,\"samples\":1}").unwrap().status, 200);
    let names: Vec<String> = parse_scrape(&c.get("/metrics").unwrap().body_str())
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    assert_eq!(
        names,
        &GOLDEN[..GOLDEN.len() - STAGE_LINES],
        "tracing-off /metrics must be the tracing-on rendering minus the stage tail"
    );
    // And `GET /trace` refuses cleanly: tracing was never enabled.
    let resp = c.get("/trace").unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    srv.shutdown().unwrap();
}

/// `GET /trace` over the live front door returns Chrome trace-event
/// JSON the `mpq trace` validator accepts, with all nine stages present
/// (the HTTP stages exist because the requests came over a real socket).
#[test]
fn trace_endpoint_serves_validated_chrome_json_with_http_stages() {
    let (srv, addr) = default_server(2, KernelChoice::Packed);
    let mut c = HttpClient::connect(&addr).unwrap();
    for i in 0..5u64 {
        let body = format!("{{\"index\":{i},\"samples\":{}}}", 1 + i % 3);
        assert_eq!(c.post("/infer", body.as_bytes()).unwrap().status, 200);
    }
    // Same connection: the 5th response's socket_write span was recorded
    // (and its trace published) before this request is even parsed.
    let resp = c.get("/trace").unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp
        .header("content-type")
        .is_some_and(|ct| ct.starts_with("application/json")));
    let check = check_trace_text(&resp.body_str()).unwrap();
    assert_eq!(check.requests, 5);
    assert_eq!(
        check.stages,
        vec![
            "http_parse",
            "admission",
            "queue_wait",
            "batch_assembly",
            "layer_gemm",
            "reassembly",
            "epilogue",
            "serialize",
            "socket_write",
        ],
        "a socket-path trace must cover every stage of the lifecycle"
    );
    assert_eq!(check.ctl_events, 0, "no controller ran in this drill");
    // Wrong method on /trace: 405, connection stays usable.
    assert_eq!(c.post("/trace", b"{}").unwrap().status, 405);
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    srv.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Hot-swap over the socket (POST /swap) and 503-retry
// ---------------------------------------------------------------------------

/// A 2-level frontier over the same checkpoint: level 0 is the mixed
/// config `setup` serves, level 1 drops every selectable layer to 2-bit.
fn two_level_frontier() -> Vec<FrontierStep> {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let (ck, bits0, _) = setup();
    let mut lo = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            lo.bits[l.qindex] = 2;
        }
    }
    vec![
        FrontierStep {
            budget_frac: 0.95,
            method: "eagl".to_string(),
            metric: 0.9,
            gbops: 1.0,
            ckpt: ck.clone(),
            bits: bits0,
        },
        FrontierStep {
            budget_frac: 0.60,
            method: "eagl".to_string(),
            metric: 0.8,
            gbops: 0.5,
            ckpt: ck,
            bits: lo.to_f32(),
        },
    ]
}

/// Front door with a swap registry (engine starts on frontier level 0).
fn frontier_server(workers: usize) -> (HttpServer, String, Vec<FrontierStep>) {
    let (_, _, data) = setup();
    let steps = two_level_frontier();
    let spawner: Spawner = Arc::new(|| {
        Ok(Box::new(SimBackend::with_kernel(MODEL, KernelChoice::Reference)?) as Box<dyn Backend>)
    });
    let eng = Engine::start(
        spawner,
        steps[0].ckpt.clone(),
        steps[0].bits.clone(),
        ServeConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
            initial_budget: steps[0].budget_frac,
            initial_label: "eagl@0.95".to_string(),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let reg = Arc::new(SwapRegistry { steps: steps.clone() });
    let srv = HttpServer::start_with(eng, data, HttpConfig::default(), Some(reg)).unwrap();
    let addr = srv.local_addr().to_string();
    (srv, addr, steps)
}

fn infer_over(c: &mut HttpClient, index: u64, samples: usize) -> mpq::serve::Response {
    let body = format!("{{\"index\":{index},\"samples\":{samples}}}");
    let resp = c.post("/infer", body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200);
    mpq::serve::http::parse_infer_response(&resp.body).unwrap()
}

#[test]
fn swap_without_a_registry_is_503_with_retry_after() {
    let (srv, addr) = default_server(1, KernelChoice::Reference);
    let mut c = HttpClient::connect(&addr).unwrap();
    let resp = c.post("/swap", b"{\"level\":0}").unwrap();
    assert_eq!(resp.status, 503);
    assert!(resp.header("retry-after").is_some());
    // The connection stays usable — this is an application-level refusal.
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    srv.shutdown().unwrap();
}

#[test]
fn swap_endpoint_hot_swaps_tags_epochs_and_surfaces_ctl_metrics() {
    let (_, _, data) = setup();
    let (srv, addr, steps) = frontier_server(2);
    let mut c = HttpClient::connect(&addr).unwrap();
    // Pre-swap traffic serves under epoch 0 with level-0 bits.
    let r0 = infer_over(&mut c, 3, 2);
    assert_eq!(r0.epoch, 0);
    let (x, y) = data.batch(mpq::data::Split::Eval, 3, 2);
    let mut be = SimBackend::new(MODEL).unwrap();
    let (loss0, out0) = be.eval_step(&steps[0].ckpt, &x, &y, &steps[0].bits).unwrap();
    assert_eq!(r0.loss.to_bits(), loss0.to_bits());
    assert_eq!(r0.evalout, out0);
    // Bad swap bodies fail closed: 400, nothing swapped.
    assert_eq!(c.post("/swap", b"{\"level\":7}").unwrap().status, 400);
    assert_eq!(c.post("/swap", b"{\"level\":true}").unwrap().status, 400);
    assert_eq!(infer_over(&mut c, 4, 1).epoch, 0, "failed swaps must not move the epoch");
    // A real swap returns the new epoch and every later response is
    // tagged with it and bit-identical to direct eval under the NEW bits.
    let resp = c.post("/swap", b"{\"level\":1}").unwrap();
    assert_eq!(resp.status, 200);
    let v = mpq::jsonio::parse(&resp.body_str()).unwrap();
    assert_eq!(v.at(&["epoch"]).as_f64(), Some(1.0));
    assert_eq!(v.at(&["level"]).as_f64(), Some(1.0));
    let r1 = infer_over(&mut c, 5, 2);
    assert_eq!(r1.epoch, 1);
    let (x, y) = data.batch(mpq::data::Split::Eval, 5, 2);
    let (loss1, out1) = be.eval_step(&steps[1].ckpt, &x, &y, &steps[1].bits).unwrap();
    assert_eq!(r1.loss.to_bits(), loss1.to_bits());
    assert_eq!(r1.evalout, out1);
    // The controller gauges follow the swap.
    let text = c.get("/metrics").unwrap().body_str();
    for want in [
        "mpq_ctl_epoch 1",
        "mpq_ctl_swap_total 1",
        "mpq_ctl_active_budget 0.6",
        "mpq_ctl_frontier_levels 2",
    ] {
        assert!(
            text.lines().any(|l| l == want),
            "missing '{want}' in:\n{text}"
        );
    }
    srv.shutdown().unwrap();
}

#[test]
fn loadgen_retries_503_sheds_with_backoff_until_answered() {
    // Capacity 1 with requests parked at a long batch deadline guarantees
    // concurrent closed-loop clients hit the admission gate.
    let (srv, addr) = server(
        1,
        KernelChoice::Reference,
        64,
        Duration::from_millis(20),
        HttpConfig {
            queue_capacity: 1,
            ..HttpConfig::default()
        },
    );
    let spec = LoadSpec {
        requests: 12,
        max_request_samples: 2,
        seed: 5,
        mode: LoadMode::Closed { concurrency: 4 },
    };
    let load = loadgen::run_http(&addr, &spec).unwrap();
    assert_eq!(load.responses.len(), 12, "every shed request must eventually be answered");
    assert!(
        load.retried > 0,
        "queue capacity 1 under concurrency 4 must shed at least once"
    );
    let (snap, hstats) = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 12);
    assert_eq!(hstats.admitted, hstats.answered);
    assert!(
        hstats.rejected >= load.retried,
        "each retried request saw at least one 503 ({} rejected, {} retried)",
        hstats.rejected,
        load.retried
    );
    assert_eq!((hstats.failed, hstats.aborted), (0, 0));
}
