//! Property-based tests over coordinator-side invariants (knapsack,
//! EAGL entropy, gains quantization, statistics, JSON, checkpoint I/O).

use mpq::prop::{close, forall, Config};
use mpq::rng::Pcg32;
use mpq::{eagl, jsonio, knapsack, quant, stats};

#[test]
fn knapsack_never_exceeds_capacity_and_dominates_greedy() {
    forall(
        &Config { cases: 200, ..Config::default() },
        |rng| {
            let n = 1 + rng.below(24) as usize;
            let values: Vec<u64> = (0..n).map(|_| rng.below(1000) as u64 + 1).collect();
            let weights: Vec<u64> = (0..n).map(|_| rng.below(500) as u64 + 1).collect();
            let cap = rng.below(3000) as u64;
            (values, weights, cap)
        },
        |(values, weights, cap)| {
            let sel = knapsack::solve_01(values, weights, *cap);
            let w: u64 = (0..values.len())
                .filter(|&i| sel.selected[i])
                .map(|i| weights[i])
                .sum();
            if w > *cap {
                return Err(format!("weight {w} > cap {cap}"));
            }
            // Greedy by value density must never beat the DP.
            let mut order: Vec<usize> = (0..values.len()).collect();
            order.sort_by(|&a, &b| {
                (values[b] as f64 / weights[b] as f64)
                    .partial_cmp(&(values[a] as f64 / weights[a] as f64))
                    .unwrap()
            });
            let mut gv = 0u64;
            let mut gw = 0u64;
            for i in order {
                if gw + weights[i] <= *cap {
                    gw += weights[i];
                    gv += values[i];
                }
            }
            if gv > sel.total_value {
                return Err(format!("greedy {gv} beat DP {}", sel.total_value));
            }
            Ok(())
        },
    );
}

#[test]
fn gain_quantization_is_monotone() {
    forall(
        &Config { cases: 200, ..Config::default() },
        |rng| {
            let n = 2 + rng.below(30) as usize;
            (0..n).map(|_| rng.normal() as f64 * 10.0).collect::<Vec<f64>>()
        },
        |gains| {
            let q = knapsack::quantize_gains(gains);
            for i in 0..gains.len() {
                for j in 0..gains.len() {
                    if gains[i] < gains[j] && q[i] > q[j] {
                        return Err(format!("order violated at ({i},{j})"));
                    }
                }
            }
            if q.iter().any(|&v| v == 0 || v > 10_000) {
                return Err("quantized gain out of 1..=10000".into());
            }
            Ok(())
        },
    );
}

/// Exact 0-1 knapsack by brute force over subsets (n ≤ 16).
fn brute_force_value(values: &[u64], weights: &[u64], cap: u64) -> u64 {
    let n = values.len();
    let mut best = 0u64;
    for mask in 0..(1u32 << n) {
        let (mut v, mut w) = (0u64, 0u64);
        for i in 0..n {
            if mask >> i & 1 == 1 {
                v += values[i];
                w += weights[i];
            }
        }
        if w <= cap {
            best = best.max(v);
        }
    }
    best
}

#[test]
fn knapsack_above_max_cap_matches_unscaled_exact_dp_within_slack() {
    // When capacity exceeds knapsack::MAX_CAP, weights are rescaled by
    // scale = capacity / MAX_CAP.  The documented ε bound:
    //   exact(cap − n·scale) ≤ solve_01(cap).total_value ≤ exact(cap).
    forall(
        &Config { cases: 40, ..Config::default() },
        |rng| {
            let n = 1 + rng.below(10) as usize;
            let values: Vec<u64> = (0..n).map(|_| rng.below(1000) as u64 + 1).collect();
            // Large weights so the big capacity is actually binding.
            let weights: Vec<u64> =
                (0..n).map(|_| rng.below(1 << 20) as u64 + (1 << 18)).collect();
            // Capacity 1–4× above the DP rescaling threshold.
            let cap = knapsack::MAX_CAP as u64 * (1 + rng.below(4) as u64)
                + rng.below(1 << 16) as u64;
            (values, weights, cap)
        },
        |(values, weights, cap)| {
            let n = values.len() as u64;
            let scale = (*cap as usize / knapsack::MAX_CAP).max(1) as u64;
            let sel = knapsack::solve_01(values, weights, *cap);
            // Feasible at full resolution.
            let w_sel: u64 = (0..values.len())
                .filter(|&i| sel.selected[i])
                .map(|i| weights[i])
                .sum();
            if w_sel > *cap {
                return Err(format!("selected weight {w_sel} > cap {cap}"));
            }
            let upper = brute_force_value(values, weights, *cap);
            let lower = brute_force_value(values, weights, cap.saturating_sub(n * scale));
            if sel.total_value > upper {
                return Err(format!("DP {} beat the exact optimum {upper}", sel.total_value));
            }
            if sel.total_value < lower {
                return Err(format!(
                    "DP {} below the ε bound {lower} (upper {upper}, scale {scale})",
                    sel.total_value
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn gain_quantization_preserves_ties_and_ordering() {
    forall(
        &Config { cases: 200, ..Config::default() },
        |rng| {
            // Draw from a small pool of distinct values so exact ties are
            // frequent.
            let pool: Vec<f64> =
                (0..1 + rng.below(5)).map(|_| rng.normal() as f64 * 5.0).collect();
            let n = 2 + rng.below(20) as usize;
            (0..n)
                .map(|_| pool[rng.below(pool.len() as u32) as usize])
                .collect::<Vec<f64>>()
        },
        |gains| {
            let q = knapsack::quantize_gains(gains);
            if q.len() != gains.len() {
                return Err("length changed".into());
            }
            for i in 0..gains.len() {
                for j in 0..gains.len() {
                    if gains[i] == gains[j] && q[i] != q[j] {
                        return Err(format!("tie broken at ({i},{j}): {} vs {}", q[i], q[j]));
                    }
                    if gains[i] < gains[j] && q[i] > q[j] {
                        return Err(format!("order violated at ({i},{j})"));
                    }
                }
            }
            if q.iter().any(|&v| v == 0 || v > 10_000) {
                return Err("quantized gain out of 1..=10000".into());
            }
            Ok(())
        },
    );
}

#[test]
fn entropy_invariant_under_code_permutation() {
    forall(
        &Config { cases: 100, ..Config::default() },
        |rng| {
            let n = 64 + rng.below(1000) as usize;
            let codes: Vec<i32> = (0..n).map(|_| rng.below(16) as i32 - 8).collect();
            let mut shuffled = codes.clone();
            rng.shuffle(&mut shuffled);
            (codes, shuffled)
        },
        |(a, b)| {
            close(
                eagl::entropy_of_codes(a, 4).map_err(|e| e.to_string())?,
                eagl::entropy_of_codes(b, 4).map_err(|e| e.to_string())?,
                1e-12,
                "permutation invariance",
            )
        },
    );
}

#[test]
fn entropy_scale_invariance_of_weights() {
    // Scaling weights and step size together must not change codes/entropy.
    forall(
        &Config { cases: 100, ..Config::default() },
        |rng| {
            let n = 128;
            let w: Vec<f32> = (0..n).map(|_| rng.normal() * 0.3).collect();
            let k = rng.range(0.1, 10.0);
            (w, k)
        },
        |(w, k)| {
            let h1 = eagl::layer_entropy(w, 0.1, 4).map_err(|e| e.to_string())?;
            let scaled: Vec<f32> = w.iter().map(|&x| x * k).collect();
            let h2 = eagl::layer_entropy(&scaled, 0.1 * k, 4).map_err(|e| e.to_string())?;
            close(h1, h2, 1e-5, "scale invariance")
        },
    );
}

#[test]
fn fake_quant_idempotent_and_bounded() {
    forall(
        &Config { cases: 300, ..Config::default() },
        |rng| {
            let v = rng.normal() * 3.0;
            let s = rng.range(0.01, 1.0);
            let bits = [2u32, 4, 8][rng.below(3) as usize];
            (v, s, bits)
        },
        |&(v, s, bits)| {
            let (qn, qp) = quant::qrange_signed(bits);
            let q1 = quant::fake_quant(v, s, qn, qp);
            let q2 = quant::fake_quant(q1, s, qn, qp);
            close(q1 as f64, q2 as f64, 1e-6, "idempotence")?;
            if q1 < qn * s - 1e-6 || q1 > qp * s + 1e-6 {
                return Err(format!("out of range: {q1}"));
            }
            Ok(())
        },
    );
}

#[test]
fn wilcoxon_p_in_unit_interval_and_symmetric() {
    forall(
        &Config { cases: 100, ..Config::default() },
        |rng| {
            let n = 3 + rng.below(6) as usize;
            let m = 3 + rng.below(6) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
            let b: Vec<f64> = (0..m).map(|_| rng.normal() as f64 + 0.2).collect();
            (a, b)
        },
        |(a, b)| {
            let (_, p_ab) = stats::ranksum(a, b);
            let (_, p_ba) = stats::ranksum(b, a);
            if !(0.0..=1.0).contains(&p_ab) {
                return Err(format!("p out of range: {p_ab}"));
            }
            close(p_ab, p_ba, 1e-9, "symmetry")
        },
    );
}

#[test]
fn json_round_trip_of_random_values() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> jsonio::Json {
        use jsonio::Json;
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(
        &Config { cases: 300, ..Config::default() },
        |rng| random_json(rng, 3),
        |v| {
            let text = v.to_string_compact();
            let back = jsonio::parse(&text).map_err(|e| e.to_string())?;
            if &back != v {
                return Err(format!("round trip changed value: {text}"));
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_io_round_trips_random_tensors() {
    use mpq::ckpt::Checkpoint;
    use mpq::tensor::Tensor;
    let dir = std::env::temp_dir().join(format!("mpq_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        &Config { cases: 30, ..Config::default() },
        |rng| {
            let k = 1 + rng.below(6) as usize;
            let mut names = Vec::new();
            let mut tensors = Vec::new();
            for i in 0..k {
                let rank = rng.below(4) as usize;
                let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5) as usize).collect();
                let n: usize = shape.iter().product();
                names.push(format!("t{i}/w"));
                tensors.push(Tensor::from_f32(
                    &shape,
                    (0..n).map(|_| rng.normal()).collect(),
                ));
            }
            Checkpoint::new(names, tensors)
        },
        |ck| {
            let path = dir.join("prop.ckpt");
            ck.save(&path).map_err(|e| e.to_string())?;
            let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
            if back.names != ck.names {
                return Err("names differ".into());
            }
            for (a, b) in back.tensors.iter().zip(&ck.tensors) {
                if a != b {
                    return Err("tensor differs".into());
                }
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ols_predicts_training_points_of_exact_linear_maps() {
    forall(
        &Config { cases: 50, ..Config::default() },
        |rng| {
            let d = 1 + rng.below(6) as usize;
            let n = d + 2 + rng.below(30) as usize;
            let beta: Vec<f64> = (0..=d).map(|_| rng.normal() as f64).collect();
            let xs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.normal() as f64).collect())
                .collect();
            let ys: Vec<f64> = xs
                .iter()
                .map(|r| {
                    r.iter().zip(&beta[..d]).map(|(a, b)| a * b).sum::<f64>() + beta[d]
                })
                .collect();
            (xs, ys)
        },
        |(xs, ys)| {
            let fit = stats::Ols::fit(xs, ys).map_err(|e| e.to_string())?;
            for (x, &y) in xs.iter().zip(ys) {
                close(fit.predict(x), y, 1e-5, "exact fit")?;
            }
            Ok(())
        },
    );
}
