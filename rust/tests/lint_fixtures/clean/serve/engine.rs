//! Lint fixture (never compiled): a clean serving module.  Every
//! pattern the rules look for appears here only in a form the linter
//! must NOT flag — literals, comments, poison-check receivers,
//! justified orderings, stderr macros, and test-only panics.
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Mentions of Instant::now, println! and .unwrap() below live inside a
/// string literal, which the lexer blanks before any rule runs.
pub const DOC: &str = "Instant::now println! .unwrap() panic!";

pub fn drain(q: &Mutex<VecDeque<u64>>, c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone counter; snapshot tearing acceptable
    let mut g = q.lock().unwrap();
    eprintln!("draining {} entries", g.len());
    g.pop_front().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        let v: Option<u64> = Some(3);
        assert_eq!(v.unwrap(), 3);
        if v.is_none() {
            panic!("unreachable");
        }
    }
}
