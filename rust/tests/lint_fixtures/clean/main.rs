//! Lint fixture (never compiled): dispatch and validation agree, so
//! `fail-closed-flags` stays quiet.
fn validate_flags(args: &Args) -> Result<(), String> {
    let Some(sub) = args.subcommand.as_deref() else {
        return Ok(());
    };
    match sub {
        "run" => args.ensure_known_flags(sub, &["seed"]),
        _ => Ok(()),
    }
}

fn run(args: &Args) -> Result<(), String> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(),
        _ => Ok(()),
    }
}
