//! Lint fixture (never compiled): an order-sensitive float reduction on
//! a kernel decode path.  Trips `float-reassoc`.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>()
}
