//! Lint fixture (never compiled): progress chatter on stdout from a
//! library module.  Trips `stdout-discipline`.
pub fn report(n: usize) {
    println!("done {n}");
}
