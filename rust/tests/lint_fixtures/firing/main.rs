//! Lint fixture (never compiled): `run()` dispatches a subcommand that
//! `validate_flags()` never validates.  Trips `fail-closed-flags`.
fn validate_flags(args: &Args) -> Result<(), String> {
    let Some(sub) = args.subcommand.as_deref() else {
        return Ok(());
    };
    match sub {
        "run" => args.ensure_known_flags(sub, &["seed"]),
        _ => Ok(()),
    }
}

fn run(args: &Args) -> Result<(), String> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(),
        Some("ghost") => cmd_ghost(),
        _ => Ok(()),
    }
}
