//! Lint fixture (never compiled): a wall-clock read in a module whose
//! outputs are contractually deterministic.  Trips `wall-clock`.
use std::time::Instant;

pub fn tick() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
