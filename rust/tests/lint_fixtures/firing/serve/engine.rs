//! Lint fixture (never compiled): an unexplained `Ordering::Relaxed`
//! and a request-reachable `.unwrap()`.  Trips `relaxed-audit` and
//! `hot-path-panic`.
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

pub fn drain(q: &mut VecDeque<u64>, c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed);
    q.pop_front().unwrap()
}
