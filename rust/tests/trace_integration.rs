//! Integration tests for per-request span tracing (`mpq serve` +
//! [`mpq::serve::trace`]).
//!
//! The contracts under test, each through a *real* engine rather than
//! the sink's unit harness:
//!
//! * **Completeness + ordering** — every traced fused-mode request
//!   publishes one whole span set (admission → queue wait → batch
//!   assembly → layer GEMM → reassembly → epilogue), stage starts
//!   monotone along that chain, and the Chrome export round-trips the
//!   `mpq trace` validator.
//! * **Bounded memory** — a full ring evicts the *oldest whole
//!   requests*; survivors are the newest and still complete.
//! * **Deterministic sampling** — `--trace-sample N` keeps exactly the
//!   ids with `id % N == 0`, nothing else.
//! * **Invisibility** — responses are byte-identical with tracing on
//!   and off, and the controller's decision JSONL is byte-identical
//!   across traced reruns.
//!
//! Hermetic: sim backend, seeded init checkpoint — no artifacts, no
//! sockets.

use std::sync::Arc;
use std::time::Duration;

use mpq::backend::{Backend, SimBackend};
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::serve::trace::RequestRecord;
use mpq::serve::{
    check_trace_text, decisions_jsonl, run_degrade, DegradeConfig, Engine, FrontierStep, Response,
    ServeConfig, SimProfile, Spawner, Stage, TraceConfig, TraceSink,
};

const MODEL: &str = "sim_tiny";

/// The six stages every fused-mode engine request must cover (the three
/// HTTP stages only exist behind the socket front door).
const ENGINE_STAGES: [Stage; 6] = [
    Stage::Admission,
    Stage::QueueWait,
    Stage::BatchAssembly,
    Stage::LayerGemm,
    Stage::Reassembly,
    Stage::Epilogue,
];

fn spawner() -> Spawner {
    Arc::new(|| Ok(Box::new(SimBackend::new(MODEL)?) as Box<dyn Backend>))
}

fn setup() -> (mpq::ckpt::Checkpoint, Vec<f32>, Dataset) {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    let mut bits = BitsConfig::uniform(&graph, 4);
    for l in &graph.layers {
        if l.fixed_bits.is_none() {
            bits.bits[l.qindex] = 2;
            break;
        }
    }
    (ck, bits.to_f32(), Dataset::for_task(be.manifest().task, 11))
}

fn traced_engine(workers: usize, trace: Option<Arc<TraceSink>>) -> Engine {
    let (ck, bits, _) = setup();
    Engine::start(
        spawner(),
        ck,
        bits,
        ServeConfig {
            workers,
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
            trace,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

/// Earliest start of `stage` within one published request record.
fn first_start(rec: &RequestRecord, stage: Stage) -> u64 {
    rec.spans
        .iter()
        .filter(|s| s.stage == stage)
        .map(|s| s.t_start_ns)
        .min()
        .unwrap_or_else(|| {
            panic!("request {} has no {} span: {:?}", rec.request_id, stage.name(), rec.spans)
        })
}

fn assert_complete(rec: &RequestRecord) {
    for stage in ENGINE_STAGES {
        assert!(
            rec.spans.iter().any(|s| s.stage == stage),
            "request {} missing stage {} — rings must drop whole requests, never \
             partial span sets: {:?}",
            rec.request_id,
            stage.name(),
            rec.spans
        );
    }
}

#[test]
fn fused_requests_publish_complete_ordered_span_sets() {
    let (_, _, data) = setup();
    let sink = TraceSink::new(TraceConfig::default());
    let eng = traced_engine(2, Some(sink.clone()));
    // Single-chunk sizes (<= max_batch 8): one queue_wait/assembly pass
    // per request, so the stage chain is a clean total order.
    let sizes = [1usize, 3, 5, 2];
    for (i, &s) in sizes.iter().enumerate() {
        let (x, y) = data.batch(Split::Eval, 100 + i as u64, s);
        let r = eng.submit(x, y).unwrap().wait().unwrap();
        assert_eq!(r.samples, s);
    }
    eng.drain().unwrap();

    let recs = sink.requests();
    assert_eq!(recs.len(), sizes.len(), "sample=1 must publish every request");
    assert_eq!(sink.published(), sizes.len() as u64);
    assert_eq!(sink.dropped(), 0);
    for rec in &recs {
        assert_complete(rec);
        for s in &rec.spans {
            assert_eq!(s.request_id, rec.request_id);
            assert_eq!(s.epoch, 0, "all spans admitted and served under epoch 0");
            assert!(s.t_end_ns >= s.t_start_ns, "span must not run backwards: {s:?}");
            if s.stage == Stage::LayerGemm {
                assert!(s.layer >= 0, "layer_gemm spans carry the layer index");
                assert!(s.bits > 0, "layer_gemm spans carry the effective precision");
                assert!(!s.variant.is_empty(), "layer_gemm spans carry the kernel variant");
            } else {
                assert_eq!((s.layer, s.bits, s.variant), (-1, 0, ""));
            }
        }
        // The lifecycle chain: each stage starts no earlier than its
        // predecessor's first start.
        let starts: Vec<u64> = ENGINE_STAGES.iter().map(|&st| first_start(rec, st)).collect();
        for (w, names) in starts.windows(2).zip(ENGINE_STAGES.windows(2)) {
            assert!(
                w[0] <= w[1],
                "request {}: {} (t={}) must start no later than {} (t={})",
                rec.request_id,
                names[0].name(),
                w[0],
                names[1].name(),
                w[1]
            );
        }
    }

    // The Chrome export of this real run round-trips the validator.
    let check = check_trace_text(&sink.chrome_trace_json().to_string_compact()).unwrap();
    assert_eq!(check.requests, sizes.len());
    for stage in ENGINE_STAGES {
        assert!(
            check.stages.contains(&stage.name()),
            "validator must see stage {} in {:?}",
            stage.name(),
            check.stages
        );
    }

    // And the pinned /metrics stage section reflects exactly these spans.
    let mut out = String::new();
    sink.render_stage_metrics(&mut out);
    let needle = format!("mpq_stage_latency_seconds_count{{stage=\"epilogue\"}} {}", sizes.len());
    assert!(out.lines().any(|l| l == needle), "missing `{needle}` in:\n{out}");
}

#[test]
fn full_ring_evicts_oldest_whole_requests() {
    let (_, _, data) = setup();
    // Tiny single-shard ring: 12 sequential requests through a capacity
    // of 4 must evict requests 0..8 and retain 8..12 — whole, not
    // truncated.
    let sink = TraceSink::new(TraceConfig { sample: 1, capacity: 4, shards: 1 });
    let eng = traced_engine(1, Some(sink.clone()));
    let total = 12u64;
    for i in 0..total {
        let (x, y) = data.batch(Split::Eval, 200 + i, 1 + (i as usize % 3));
        // Sequential submit→wait→drop: request i is fully published
        // before i+1 exists, so eviction order is the id order.
        eng.submit(x, y).unwrap().wait().unwrap();
    }
    eng.drain().unwrap();

    assert_eq!(sink.published(), total);
    assert_eq!(sink.dropped(), total - 4);
    let recs = sink.requests();
    let mut ids: Vec<u64> = recs.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![8, 9, 10, 11], "survivors must be the newest requests");
    for rec in &recs {
        assert_complete(rec);
    }
    // The evicted requests still counted into the stage histograms —
    // eviction bounds memory, not measurement.
    assert_eq!(sink.stage_count(Stage::Epilogue), total);
}

#[test]
fn sampling_keeps_exactly_the_selected_id_set() {
    let (_, _, data) = setup();
    let sink = TraceSink::new(TraceConfig { sample: 3, ..TraceConfig::default() });
    let eng = traced_engine(2, Some(sink.clone()));
    let total = 10u64;
    for i in 0..total {
        let (x, y) = data.batch(Split::Eval, 300 + i, 2);
        eng.submit(x, y).unwrap().wait().unwrap();
    }
    eng.drain().unwrap();

    // Pure modulus, no randomness: exactly {0, 3, 6, 9}.
    for i in 0..total {
        assert_eq!(sink.sampled(i), i % 3 == 0);
    }
    let mut ids: Vec<u64> = sink.requests().iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 3, 6, 9]);
    assert_eq!(sink.published(), 4);
    // Unsampled requests leave no histogram residue either.
    assert_eq!(sink.stage_count(Stage::Epilogue), 4);
}

#[test]
fn tracing_is_invisible_to_served_responses() {
    let (_, _, data) = setup();
    let requests: Vec<_> = [3usize, 1, 8, 5, 2]
        .iter()
        .enumerate()
        .map(|(i, &s)| data.batch(Split::Eval, 400 + i as u64, s))
        .collect();
    let mut streams: Vec<Vec<Response>> = Vec::new();
    for traced in [false, true] {
        let sink = traced.then(|| TraceSink::new(TraceConfig::default()));
        let eng = traced_engine(2, sink.clone());
        let rs: Vec<Response> = requests
            .iter()
            .map(|(x, y)| eng.submit(x.clone(), y.clone()).unwrap().wait().unwrap())
            .collect();
        let snap = eng.drain().unwrap();
        assert_eq!(snap.completed, requests.len() as u64);
        assert_eq!(snap.failed, 0);
        if let Some(sink) = sink {
            assert_eq!(sink.published(), requests.len() as u64);
        }
        streams.push(rs);
    }
    for (off, on) in streams[0].iter().zip(&streams[1]) {
        assert_eq!(off.id, on.id);
        assert_eq!(off.samples, on.samples);
        assert_eq!(off.epoch, on.epoch);
        assert_eq!(
            off.loss.to_bits(),
            on.loss.to_bits(),
            "tracing must not perturb the served loss"
        );
        assert_eq!(off.evalout, on.evalout, "tracing must not perturb the served logits");
    }
}

/// Frontier + drill config for the traced degrade rerun (the compact
/// sibling of `degrade_integration.rs`'s setup).
fn frontier() -> Vec<FrontierStep> {
    let be = SimBackend::new(MODEL).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    let ck = be.init_checkpoint().unwrap();
    let selectable: Vec<usize> = graph
        .layers
        .iter()
        .filter(|l| l.fixed_bits.is_none())
        .map(|l| l.qindex)
        .collect();
    let mut levels = Vec::new();
    for (i, &(budget, gbops)) in [(0.95, 1.0), (0.70, 0.5), (0.50, 0.25)].iter().enumerate() {
        let mut bits = BitsConfig::uniform(&graph, 4);
        for &q in selectable.iter().take(i) {
            bits.bits[q] = 2;
        }
        levels.push(FrontierStep {
            budget_frac: budget,
            method: "eagl".to_string(),
            metric: 0.9 - 0.05 * i as f64,
            gbops,
            ckpt: ck.clone(),
            bits: bits.to_f32(),
        });
    }
    levels
}

#[test]
fn degrade_decision_jsonl_is_byte_identical_across_traced_reruns() {
    let (_, _, data) = setup();
    let frontier = frontier();
    let cfg = DegradeConfig::new(SimProfile::named("spike").unwrap());
    let mut logs: Vec<String> = Vec::new();
    let mut sinks: Vec<Arc<TraceSink>> = Vec::new();
    for _ in 0..2 {
        let sink = TraceSink::new(TraceConfig::default());
        let eng = Engine::start(
            spawner(),
            frontier[0].ckpt.clone(),
            frontier[0].bits.clone(),
            ServeConfig {
                workers: 2,
                max_batch: 8,
                batch_timeout: Duration::from_millis(1),
                force_per_request: false,
                warmup: true,
                trace: Some(sink.clone()),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let out = run_degrade(&eng, &data, &frontier, &cfg).unwrap();
        eng.drain().unwrap();
        assert!(out.swaps_down >= 1, "spike must force a downgrade:\n{}", out.log_text);
        let jsonl = decisions_jsonl(&out.log);
        assert_eq!(
            jsonl.lines().count(),
            out.log.len(),
            "one JSONL line per controller tick"
        );
        logs.push(jsonl);
        sinks.push(sink);
    }
    assert_eq!(
        logs[0], logs[1],
        "--decision-log must be byte-identical across reruns of the same drill"
    );
    // Every tick also landed in the trace as a controller instant, and
    // the whole traced drill round-trips the validator.
    for sink in &sinks {
        let check = check_trace_text(&sink.chrome_trace_json().to_string_compact()).unwrap();
        assert_eq!(
            check.ctl_events,
            logs[0].lines().count(),
            "one ctl_tick instant per decision record"
        );
        assert!(check.requests > 0);
    }
}
