//! Integration: the [`Backend`] execution seam, exercised hermetically on
//! [`SimBackend`] — every test here runs with no `artifacts/` directory.
//! The artifact-gated PJRT equivalents live in the `pjrt_artifacts` module
//! at the bottom, compiled only with `--features pjrt` and skipped at
//! runtime when artifacts are absent.

use mpq::backend::{Backend, SimBackend, TrainState};
use mpq::data::{Dataset, Split};
use mpq::eagl;
use mpq::graph::Graph;
use mpq::quant::BitsConfig;

fn sim(model: &str) -> (SimBackend, Graph) {
    let be = SimBackend::new(model).unwrap();
    let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
    (be, graph)
}

#[test]
fn manifest_and_graph_agree() {
    for model in ["sim_tiny", "sim_skew"] {
        let (be, graph) = sim(model);
        assert_eq!(be.manifest().n_bits, graph.n_bits(), "{model}");
        assert!(!graph.groups.is_empty(), "{model}");
        // Init checkpoint matches manifest param specs.
        let ck = be.init_checkpoint().unwrap();
        assert_eq!(ck.names.len(), be.manifest().params.len());
        for (name, spec) in ck.names.iter().zip(&be.manifest().params) {
            assert_eq!(name, &spec.name);
            assert_eq!(ck.get(name).unwrap().shape, spec.shape, "{model} {name}");
        }
    }
}

#[test]
fn eval_and_train_step_execute() {
    let (mut be, graph) = sim("sim_tiny");
    let data = Dataset::for_task(be.manifest().task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();

    let ck = be.init_checkpoint().unwrap();
    let (xe, ye) = data.batch(Split::Eval, 0, be.manifest().eval_batch);
    let (loss0, out) = be.eval_step(&ck, &xe, &ye, &bits).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);
    assert_eq!(out.shape, be.manifest().evalout_shape);

    // A few train steps must change the params and keep the loss finite.
    let mut state = TrainState::new(ck.clone());
    let (xt, yt) = data.batch(Split::Train, 0, be.manifest().train_batch);
    for _ in 0..3 {
        let (l, m) = be.train_step(&mut state, &xt, &yt, 0.05, 1e-4, &bits).unwrap();
        assert!(l.is_finite());
        assert!((0.0..=1.0).contains(&m));
    }
    let w0 = ck.get("h1/w").unwrap();
    let w1 = state.params.get("h1/w").unwrap();
    assert_ne!(w0.f32s(), w1.f32s(), "params must move");
    // Momentum should be non-zero after steps.
    assert!(state.mom.get("h1/w").unwrap().norm2() > 0.0);
    // Step sizes are inert under training (LSQ steps adapt only through
    // the explicit rescale transform).
    assert_eq!(
        ck.get("h1/sw").unwrap().item(),
        state.params.get("h1/sw").unwrap().item()
    );
}

#[test]
fn same_seed_same_result() {
    let (mut be, graph) = sim("sim_tiny");
    let data = Dataset::for_task(be.manifest().task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let ck = be.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Train, 0, be.manifest().train_batch);
    let mut a = TrainState::new(ck.clone());
    let mut b = TrainState::new(ck);
    let ra = be.train_step(&mut a, &x, &y, 0.01, 0.0, &bits).unwrap();
    let rb = be.train_step(&mut b, &x, &y, 0.01, 0.0, &bits).unwrap();
    assert_eq!(ra, rb);
    assert_eq!(
        a.params.get("h1/w").unwrap().f32s(),
        b.params.get("h1/w").unwrap().f32s()
    );
}

#[test]
fn bits_vector_affects_execution() {
    let (mut be, graph) = sim("sim_tiny");
    let data = Dataset::for_task(be.manifest().task, 1);
    let ck = be.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Eval, 0, be.manifest().eval_batch);
    let b4 = BitsConfig::uniform(&graph, 4).to_f32();
    let b2 = BitsConfig::uniform(&graph, 2).to_f32();
    let (l4, _) = be.eval_step(&ck, &x, &y, &b4).unwrap();
    let (l2, _) = be.eval_step(&ck, &x, &y, &b2).unwrap();
    assert_ne!(l4, l2, "2-bit and 4-bit must differ");
}

#[test]
fn native_eagl_matches_backend_kernel() {
    // The cross-check the paper's Appendix E snippet implies: the native
    // host entropy must equal the backend's eagl_step output.
    for model in ["sim_tiny", "sim_skew"] {
        let (mut be, graph) = sim(model);
        let ck = be.init_checkpoint().unwrap();
        let kernel = be.eagl_step(&ck).unwrap();
        let native = eagl::checkpoint_entropies(&graph, &ck, 4).unwrap();
        assert_eq!(kernel.len(), native.len());
        for (i, (k, n)) in kernel.iter().zip(&native).enumerate() {
            assert!(
                (*k as f64 - n).abs() < 1e-3,
                "{model} layer {i}: kernel {k} native {n}"
            );
        }
    }
}

#[test]
fn vhv_deterministic_per_seed() {
    let (mut be, graph) = sim("sim_tiny");
    let data = Dataset::for_task(be.manifest().task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let ck = be.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Train, 0, be.manifest().train_batch);
    let v1 = be.vhv_step(&ck, &x, &y, &bits, 11).unwrap();
    let v2 = be.vhv_step(&ck, &x, &y, &bits, 11).unwrap();
    let v3 = be.vhv_step(&ck, &x, &y, &bits, 12).unwrap();
    assert_eq!(v1, v2);
    assert_ne!(v1, v3);
    assert_eq!(v1.len(), graph.n_bits());
    assert!(v1.iter().all(|v| v.is_finite()));
}

#[test]
fn sim_checkpoint_save_load_round_trips() {
    // ckpt I/O on SimBackend-shaped checkpoints (scalars, 1-d biases,
    // 2-d weight matrices in one file).
    let (be, _) = sim("sim_skew");
    let ck = be.init_checkpoint().unwrap();
    let dir = std::env::temp_dir().join(format!("mpq_sim_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sim_skew_init.ckpt");
    ck.save(&path).unwrap();
    let back = mpq::ckpt::Checkpoint::load(&path).unwrap();
    assert_eq!(back.names, ck.names);
    for (a, b) in back.tensors.iter().zip(&ck.tensors) {
        assert_eq!(a, b);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_entry_errors() {
    let (mut be, _) = sim("sim_tiny");
    let err = be.execute("not_an_entry", &[]).unwrap_err().to_string();
    assert!(err.contains("not_an_entry"), "{err}");
}

// ---------------------------------------------------------------------------
// Artifact-gated PJRT tests: compiled only with --features pjrt, and
// skipped at runtime when `make artifacts` has not run.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use mpq::backend::{Backend, PjrtBackend, TrainState};
    use mpq::data::{Dataset, Split};
    use mpq::graph::Graph;
    use mpq::quant::BitsConfig;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = mpq::artifacts_dir();
        if dir.join("qsegnet.manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn pjrt_eval_and_train_step_execute() {
        let Some(dir) = artifacts() else { return };
        let mut rt = PjrtBackend::load(&dir, "qsegnet").unwrap();
        let graph = Graph::load(&dir, "qsegnet").unwrap();
        let data = Dataset::for_task(rt.manifest().task, 1);
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let ck = rt.init_checkpoint().unwrap();
        let (xe, ye) = data.batch(Split::Eval, 0, rt.manifest().eval_batch);
        let (loss0, out) = rt.eval_step(&ck, &xe, &ye, &bits).unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0);
        assert_eq!(out.shape, rt.manifest().evalout_shape);
        let mut state = TrainState::new(ck);
        let (xt, yt) = data.batch(Split::Train, 0, rt.manifest().train_batch);
        let (l, m) = rt.train_step(&mut state, &xt, &yt, 0.05, 1e-4, &bits).unwrap();
        assert!(l.is_finite());
        assert!((0.0..=1.0).contains(&m));
    }

    #[test]
    fn pjrt_native_eagl_matches_pallas_kernel() {
        let Some(dir) = artifacts() else { return };
        for model in ["qsegnet", "qresnet20"] {
            let mut rt = PjrtBackend::load(&dir, model).unwrap();
            let graph = Graph::load(&dir, model).unwrap();
            let ck = rt.init_checkpoint().unwrap();
            let kernel = rt.eagl_step(&ck).unwrap();
            let native = mpq::eagl::checkpoint_entropies(&graph, &ck, 4).unwrap();
            assert_eq!(kernel.len(), native.len());
            for (i, (k, n)) in kernel.iter().zip(&native).enumerate() {
                assert!(
                    (*k as f64 - n).abs() < 1e-3,
                    "{model} layer {i}: kernel {k} native {n}"
                );
            }
        }
    }

    #[test]
    fn pjrt_qbert_pallas_path_executes() {
        // qbert's linears run through the Pallas quant_matmul kernel inside
        // the artifact — this is the L1-on-the-hot-path proof.
        let Some(dir) = artifacts() else { return };
        let mut rt = PjrtBackend::load(&dir, "qbert").unwrap();
        let graph = Graph::load(&dir, "qbert").unwrap();
        let data = Dataset::for_task(rt.manifest().task, 1);
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let ck = rt.init_checkpoint().unwrap();
        let (x, y) = data.batch(Split::Eval, 0, rt.manifest().eval_batch);
        let (loss, pred) = rt.eval_step(&ck, &x, &y, &bits).unwrap();
        assert!(loss.is_finite());
        assert_eq!(pred.shape, vec![rt.manifest().eval_batch, 2]);
    }
}
