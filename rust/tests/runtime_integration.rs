//! Integration: Rust runtime ↔ AOT artifacts (the L3↔L2/L1 seam).
//!
//! Requires `make artifacts` to have run (skipped otherwise).  qsegnet is
//! used as the vehicle — it is the smallest model — plus qbert for the
//! Pallas-kernel-on-the-hot-path case.

use mpq::data::{Dataset, Split};
use mpq::eagl;
use mpq::graph::Graph;
use mpq::quant::BitsConfig;
use mpq::runtime::{Runtime, TrainState};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = mpq::artifacts_dir();
    if dir.join("qsegnet.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn manifest_and_graph_agree() {
    let Some(dir) = artifacts() else { return };
    for model in ["qsegnet", "qresnet20", "qbert"] {
        let rt = Runtime::load(&dir, model).unwrap();
        let graph = Graph::load(&dir, model).unwrap();
        assert_eq!(rt.manifest.n_bits, graph.n_bits(), "{model}");
        assert!(!graph.groups.is_empty(), "{model}");
        // Init checkpoint matches manifest param specs.
        let ck = rt.init_checkpoint().unwrap();
        assert_eq!(ck.names.len(), rt.manifest.params.len());
        for (name, spec) in ck.names.iter().zip(&rt.manifest.params) {
            assert_eq!(name, &spec.name);
        }
    }
}

#[test]
fn eval_and_train_step_execute() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir, "qsegnet").unwrap();
    let graph = Graph::load(&dir, "qsegnet").unwrap();
    let data = Dataset::for_task(rt.manifest.task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();

    let ck = rt.init_checkpoint().unwrap();
    let (xe, ye) = data.batch(Split::Eval, 0, rt.manifest.eval_batch);
    let (loss0, out) = rt.eval_step(&ck, &xe, &ye, &bits).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);
    assert_eq!(out.shape, rt.manifest.evalout_shape);

    // A few train steps must change the params and keep the loss finite.
    let mut state = TrainState::new(ck.clone());
    let (xt, yt) = data.batch(Split::Train, 0, rt.manifest.train_batch);
    let mut losses = Vec::new();
    for _ in 0..3 {
        let (l, m) = rt.train_step(&mut state, &xt, &yt, 0.05, 1e-4, &bits).unwrap();
        assert!(l.is_finite());
        assert!((0.0..=1.0).contains(&m));
        losses.push(l);
    }
    let w0 = ck.get("enc1/w").unwrap();
    let w1 = state.params.get("enc1/w").unwrap();
    assert_ne!(w0.f32s(), w1.f32s(), "params must move");
    // Momentum should be non-zero after steps.
    assert!(state.mom.get("enc1/w").unwrap().norm2() > 0.0);
}

#[test]
fn same_seed_same_result() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir, "qsegnet").unwrap();
    let graph = Graph::load(&dir, "qsegnet").unwrap();
    let data = Dataset::for_task(rt.manifest.task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let ck = rt.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Train, 0, rt.manifest.train_batch);
    let mut a = TrainState::new(ck.clone());
    let mut b = TrainState::new(ck);
    let ra = rt.train_step(&mut a, &x, &y, 0.01, 0.0, &bits).unwrap();
    let rb = rt.train_step(&mut b, &x, &y, 0.01, 0.0, &bits).unwrap();
    assert_eq!(ra, rb);
    assert_eq!(
        a.params.get("enc1/w").unwrap().f32s(),
        b.params.get("enc1/w").unwrap().f32s()
    );
}

#[test]
fn bits_vector_affects_execution() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir, "qsegnet").unwrap();
    let graph = Graph::load(&dir, "qsegnet").unwrap();
    let data = Dataset::for_task(rt.manifest.task, 1);
    let ck = rt.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Eval, 0, rt.manifest.eval_batch);
    let b4 = BitsConfig::uniform(&graph, 4).to_f32();
    let b2 = BitsConfig::uniform(&graph, 2).to_f32();
    let (l4, _) = rt.eval_step(&ck, &x, &y, &b4).unwrap();
    let (l2, _) = rt.eval_step(&ck, &x, &y, &b2).unwrap();
    assert_ne!(l4, l2, "2-bit and 4-bit must differ");
}

#[test]
fn native_eagl_matches_pallas_kernel() {
    // The cross-check the paper's Appendix E snippet implies: the Rust
    // host entropy must equal the L1 Pallas histogram kernel's output.
    let Some(dir) = artifacts() else { return };
    for model in ["qsegnet", "qresnet20"] {
        let mut rt = Runtime::load(&dir, model).unwrap();
        let graph = Graph::load(&dir, model).unwrap();
        let ck = rt.init_checkpoint().unwrap();
        let kernel = rt.eagl_step(&ck).unwrap();
        let native = eagl::checkpoint_entropies(&graph, &ck, 4).unwrap();
        assert_eq!(kernel.len(), native.len());
        for (i, (k, n)) in kernel.iter().zip(&native).enumerate() {
            assert!(
                (*k as f64 - n).abs() < 1e-3,
                "{model} layer {i}: kernel {k} native {n}"
            );
        }
    }
}

#[test]
fn vhv_deterministic_per_seed() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir, "qsegnet").unwrap();
    let graph = Graph::load(&dir, "qsegnet").unwrap();
    let data = Dataset::for_task(rt.manifest.task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let ck = rt.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Train, 0, rt.manifest.train_batch);
    let v1 = rt.vhv_step(&ck, &x, &y, &bits, 11).unwrap();
    let v2 = rt.vhv_step(&ck, &x, &y, &bits, 11).unwrap();
    let v3 = rt.vhv_step(&ck, &x, &y, &bits, 12).unwrap();
    assert_eq!(v1, v2);
    assert_ne!(v1, v3);
    assert_eq!(v1.len(), graph.n_bits());
    assert!(v1.iter().all(|v| v.is_finite()));
}

#[test]
fn qbert_pallas_path_executes() {
    // qbert's linears run through the Pallas quant_matmul kernel inside
    // the artifact — this is the L1-on-the-hot-path proof.
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::load(&dir, "qbert").unwrap();
    let graph = Graph::load(&dir, "qbert").unwrap();
    let data = Dataset::for_task(rt.manifest.task, 1);
    let bits = BitsConfig::uniform(&graph, 4).to_f32();
    let ck = rt.init_checkpoint().unwrap();
    let (x, y) = data.batch(Split::Eval, 0, rt.manifest.eval_batch);
    let (loss, pred) = rt.eval_step(&ck, &x, &y, &bits).unwrap();
    assert!(loss.is_finite());
    assert_eq!(pred.shape, vec![rt.manifest.eval_batch, 2]);
    let mut state = TrainState::new(ck);
    let (xt, yt) = data.batch(Split::Train, 0, rt.manifest.train_batch);
    let (l, _) = rt.train_step(&mut state, &xt, &yt, 0.01, 0.0, &bits).unwrap();
    assert!(l.is_finite());
}
