//! Fig. 6 / Appendix A experiment 1: additivity of layer-wise accuracy
//! drops.
//!
//! Protocol (paper §A): from the trained 4-bit qresnet20, measure D(L) =
//! training-set accuracy drop when layer group L alone goes to 2-bit with
//! **no fine-tuning**; then for random pairs <L1, L2> compare the predicted
//! drop D(L1)+D(L2) against the measured drop with both at 2-bit.
//!
//! Paper shape: strong linear correlation (paper reports R = 0.98) —
//! justifying the additive-gain assumption behind the knapsack.

use mpq::backend::Backend;
use mpq::coordinator::Coordinator;
use mpq::data::Split;
use mpq::methods::prepare_mp_checkpoint;
use mpq::quant::BitsConfig;
use mpq::rng::Pcg32;
use mpq::stats;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    let n_pairs = if quick { 15 } else { 80 };
    let eval_batches = 2;

    let ck4 = co.base_checkpoint()?;
    let n_groups = co.graph.groups.len();

    // Training-set accuracy is the paper's measurement; our evaluate()
    // uses the eval split, so run eval_step over train batches directly.
    let acc_at = |selected: &[bool],
                  co: &mut Coordinator<Box<dyn Backend>>|
     -> mpq::Result<f64> {
        let bits = BitsConfig::from_selection(&co.graph, selected, 4, 2);
        let ck = prepare_mp_checkpoint(&ck4, &co.graph, &bits, 4)?;
        let bitsf = bits.to_f32();
        let batch = co.rt.manifest().eval_batch;
        let mut correct = 0.0;
        let mut seen = 0usize;
        for i in 0..eval_batches {
            // Eval-shaped batches drawn from the *train* stream.
            let (x, y) = co.data.batch(Split::Train, 500 + i as u64, batch);
            let (_, out) = co.rt.eval_step(&ck, &x, &y, &bitsf)?;
            correct += out.item() as f64;
            seen += batch;
        }
        Ok(correct / seen as f64)
    };

    println!("== Fig. 6 (analog): additivity of per-group accuracy drops ==\n");
    let base_acc = acc_at(&vec![true; n_groups], &mut co)?;
    println!("4-bit train accuracy: {base_acc:.4}");

    // Single-group drops.
    let mut single = vec![0.0f64; n_groups];
    for g in 0..n_groups {
        let mut sel = vec![true; n_groups];
        sel[g] = false;
        single[g] = base_acc - acc_at(&sel, &mut co)?;
    }
    println!("single-group drops: min {:.4} max {:.4}",
        single.iter().cloned().fold(f64::INFINITY, f64::min),
        single.iter().cloned().fold(f64::NEG_INFINITY, f64::max));

    // Random pairs: predicted vs actual.
    let mut rng = Pcg32::new(42, 6);
    let mut predicted = Vec::with_capacity(n_pairs);
    let mut actual = Vec::with_capacity(n_pairs);
    let mut seen_pairs = std::collections::HashSet::new();
    while predicted.len() < n_pairs && seen_pairs.len() < n_groups * (n_groups - 1) / 2 {
        let a = rng.below(n_groups as u32) as usize;
        let b = rng.below(n_groups as u32) as usize;
        if a == b || !seen_pairs.insert((a.min(b), a.max(b))) {
            continue;
        }
        let mut sel = vec![true; n_groups];
        sel[a] = false;
        sel[b] = false;
        predicted.push(single[a] + single[b]);
        actual.push(base_acc - acc_at(&sel, &mut co)?);
    }

    let r = stats::pearson(&predicted, &actual);
    println!("\n{:>12} {:>12}", "predicted", "actual");
    for (p, a) in predicted.iter().zip(&actual).take(15) {
        println!("{:>12.4} {:>12.4}", p, a);
    }
    println!("... ({} pairs total)", predicted.len());
    println!("\nPearson R = {r:.4}   (paper Fig. 6: R = 0.98)");
    println!("shape check: R close to 1 justifies the knapsack's additive assumption.");

    Ok(())
}
