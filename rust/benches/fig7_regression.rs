//! Fig. 7 / Appendix A experiment 2: a linear regression over random
//! mixed-precision configurations predicts network accuracy.
//!
//! Protocol: train N stratified random mixed 4/2-bit qresnet20 networks
//! for a short fine-tune, regress final eval accuracy on the binary
//! layer-precision vector, and report R on the training samples and a
//! held-out 10%.
//!
//! Paper shape: R ≈ 0.999 on both — overall accuracy is very nearly a
//! linear function of the per-layer choices.  The fitted coefficients feed
//! Fig. 8 as the "oracle" gains.

use mpq::jsonio::Json;
use mpq::methods::prepare_mp_checkpoint;
use mpq::quant::BitsConfig;
use mpq::rng::Pcg32;
use mpq::backend::TrainState;
use mpq::stats::{self, Ols};
use mpq::train::{evaluate, finetune, TrainConfig};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    let ft_steps = if quick { 20 } else { 60 };
    let n_samples = if quick { 16 } else { 60 };
    let eval_batches = 2;

    let ck4 = co.base_checkpoint()?;
    let n_groups = co.graph.groups.len();
    println!("== Fig. 7 (analog): linear regression over {n_samples} random mixes ==\n");

    // Stratified sampling: k groups at 2-bit, k swept over the range.
    let mut rng = Pcg32::new(7, 77);
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for i in 0..n_samples {
        let k = 1 + (i % (n_groups - 1));
        let drop = rng.choose_k(n_groups, k);
        let mut sel = vec![true; n_groups];
        for d in drop {
            sel[d] = false;
        }
        let bits = BitsConfig::from_selection(&co.graph, &sel, 4, 2);
        let ck = prepare_mp_checkpoint(&ck4, &co.graph, &bits, 4)?;
        let mut state = TrainState::new(ck);
        let tcfg = TrainConfig { steps: ft_steps, lr0: 0.005, seed: i as u64, ..Default::default() };
        finetune(&mut co.rt, &mut state, &co.data, &bits.to_f32(), &tcfg)?;
        let ev = evaluate(&mut co.rt, &state.params, &co.data, &bits.to_f32(), eval_batches)?;
        xs.push(sel.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect());
        ys.push(ev.metric);
        if (i + 1) % 10 == 0 {
            eprintln!("  {}/{} samples", i + 1, n_samples);
        }
    }

    // 90/10 split.
    let n_hold = (n_samples / 10).max(2);
    let (xs_tr, xs_ho) = xs.split_at(n_samples - n_hold);
    let (ys_tr, ys_ho) = ys.split_at(n_samples - n_hold);
    let fit = Ols::fit(xs_tr, ys_tr)?;

    let pred_tr: Vec<f64> = xs_tr.iter().map(|x| fit.predict(x)).collect();
    let pred_ho: Vec<f64> = xs_ho.iter().map(|x| fit.predict(x)).collect();
    let r_tr = stats::pearson(&pred_tr, ys_tr);
    let r_ho = stats::pearson(&pred_ho, ys_ho);
    println!("R (train samples):   {r_tr:.4}   (paper: 0.9996)");
    println!("R (hold-out):        {r_ho:.4}   (paper: 0.9994)");

    // Persist coefficients as the Fig. 8 oracle gains (per layer).
    let coefs = fit.coefficients();
    let mut per_layer = vec![0.0f64; co.graph.layers.len()];
    for (g, group) in co.graph.groups.iter().enumerate() {
        let share = coefs[g] / group.layer_idx.len() as f64;
        for &li in &group.layer_idx {
            per_layer[co.graph.layers[li].qindex] = share;
        }
    }
    let payload = Json::obj(vec![
        ("per_layer", Json::arr(per_layer.iter().map(|&g| Json::num(g)))),
        ("wall_seconds", Json::num(0.0)),
    ]);
    let path = co.results_dir.join("gains_oracle.json");
    std::fs::write(&path, payload.to_string_compact())?;
    println!("\noracle gains written to {} (used by fig8_oracle_frontier)", path.display());
    Ok(())
}
