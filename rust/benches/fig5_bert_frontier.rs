//! Fig. 5: F1–throughput frontier for qbert (BERT/SQuAD analog): 4 budgets
//! (90/80/70/60%), EAGL/ALPS vs the two topological baselines the paper
//! uses for this task.
//!
//! Paper shape: EAGL and ALPS at or above both baselines across the
//! frontier.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qbert", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.ft_steps = if quick { 30 } else { 120 };
    co.eval_batches = 2;
    co.mcfg.alps_steps = if quick { 8 } else { 30 };

    let budgets = [0.90, 0.80, 0.70, 0.60];
    let seeds: Vec<u64> = (0..if quick { 1 } else { 3 }).collect();
    let kinds = [
        MethodKind::Eagl,
        MethodKind::Alps,
        MethodKind::FirstToLast,
        MethodKind::LastToFirst,
    ];
    println!("== Fig. 5 (analog): qbert F1 frontier ==\n");
    let mut store = ResultStore::open(&co.results_dir.join("sweep.jsonl"))?;
    let records = co.sweep(&kinds, &budgets, &seeds, &mut store)?;
    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, "F1"));
    println!("{}", report::frontier_plot(&cells, 64, 14));
    for (a, b) in [("eagl", "first_to_last"), ("alps", "first_to_last"), ("eagl", "last_to_first")] {
        for (budget, p) in report::significance(&cells, a, b) {
            println!("Wilcoxon {a} vs {b} @ {:>3.0}%: p = {:.4}", budget * 100.0, p);
        }
    }
    report::write_csv(&cells, &co.results_dir.join("fig5.csv"))?;
    Ok(())
}
