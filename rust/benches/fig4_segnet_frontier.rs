//! Fig. 4: mIoU–throughput frontier for qsegnet (PSPNet analog): 4 budgets
//! (95/85/75/65%), ALPS driven by the *loss* signal (Algorithm 1's
//! segmentation branch).
//!
//! Paper shape: EAGL/ALPS statistically indistinguishable from HAWQ-v3
//! (p > 0.1) and all three above first-to-last.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qsegnet", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.ft_steps = if quick { 30 } else { 120 };
    co.eval_batches = 4;
    co.mcfg.alps_steps = if quick { 10 } else { 40 };
    co.mcfg.hawq_samples = 2;
    co.mcfg.hawq_batches = 2;

    let budgets = [0.95, 0.85, 0.75, 0.65];
    let seeds: Vec<u64> = (0..if quick { 1 } else { 3 }).collect();
    let kinds = [
        MethodKind::Eagl,
        MethodKind::Alps,
        MethodKind::HawqV3,
        MethodKind::FirstToLast,
    ];
    println!("== Fig. 4 (analog): qsegnet mIoU frontier ==\n");
    let mut store = ResultStore::open(&co.results_dir.join("sweep.jsonl"))?;
    let records = co.sweep(&kinds, &budgets, &seeds, &mut store)?;
    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, "mIoU"));
    println!("{}", report::frontier_plot(&cells, 64, 14));
    for (a, b) in [("eagl", "hawq_v3"), ("alps", "hawq_v3"), ("eagl", "first_to_last")] {
        for (budget, p) in report::significance(&cells, a, b) {
            println!("Wilcoxon {a} vs {b} @ {:>3.0}%: p = {:.4}", budget * 100.0, p);
        }
    }
    report::write_csv(&cells, &co.results_dir.join("fig4.csv"))?;
    Ok(())
}
