//! Table 3: computational cost of the layer-wise metric estimation itself —
//! the paper's headline efficiency claim (EAGL: CPU *seconds*; ALPS/HAWQ:
//! GPU *hours*).
//!
//! We measure wall-clock on this testbed for whichever models open in this
//! environment (sim models always; artifact models under --features pjrt).
//! The paper shape to reproduce is the *orders-of-magnitude ordering*
//! EAGL ≪ HAWQ-v3 < ALPS (ALPS ∝ L fine-tune epochs; HAWQ ∝ Hutchinson
//! draws; EAGL is one pass over the checkpoint, no data, no accelerator).

use mpq::bench::{coordinator_or_skip, fmt_s, measure};
use mpq::methods::{estimate_gains, MethodConfig, MethodKind};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    println!("== Table 3: metric computation cost (wall-clock, this testbed) ==\n");
    println!("{:<12} {:>14} {:>14} {:>14}", "model", "EAGL", "ALPS", "HAWQ-v3");
    println!("{}", "-".repeat(60));
    for model in ["sim_skew", "qresnet20", "qsegnet"] {
        let Some(mut co) = coordinator_or_skip(model, 7) else {
            continue;
        };
        co.base_steps = if quick { 100 } else { 300 };
        let mcfg = MethodConfig {
            alps_steps: if quick { 8 } else { 40 },
            hawq_samples: if quick { 2 } else { 4 },
            hawq_batches: 2,
            ..MethodConfig::default()
        };
        let ck4 = co.base_checkpoint()?;

        // EAGL is microseconds–milliseconds: measure with repetitions.
        let graph = co.graph.clone();
        let ck = ck4.clone();
        let m_eagl = measure("eagl", 2, 20, || {
            let _ = mpq::eagl::checkpoint_entropies(&graph, &ck, 4).unwrap();
        });

        // ALPS / HAWQ involve training/HVPs: one timed estimation each,
        // on the coordinator's own backend.
        let data = co.data.clone();
        let alps = estimate_gains(MethodKind::Alps, &mut co.rt, &graph, &ck4, &data, &mcfg)?;
        let hawq = estimate_gains(MethodKind::HawqV3, &mut co.rt, &graph, &ck4, &data, &mcfg)?;

        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            model,
            fmt_s(m_eagl.mean_s),
            fmt_s(alps.wall_seconds),
            fmt_s(hawq.wall_seconds),
        );
        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            "",
            "(per call)",
            format!("({} probes)", graph.groups.len()),
            format!("({} draws)", mcfg.hawq_samples * mcfg.hawq_batches),
        );
    }
    println!("\npaper: ResNet-50 → EAGL 3.15 CPU-s, ALPS 166 GPU-h, HAWQ-v3 2 GPU-h;");
    println!("       PSPNet    → EAGL <1 CPU-min, ALPS 67 GPU-h, HAWQ-v3 1032 GPU-h.");
    println!("shape: EAGL orders of magnitude below both data-driven methods. ✓/✗ above.");
    Ok(())
}
