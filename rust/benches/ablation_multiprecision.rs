//! Ablation (paper §5 extension): two precision choices {2,4} vs three
//! {2,4,8} under the same BMAC budgets, EAGL gains, MCKP optimizer.
//!
//! The paper argues the framework extends beyond binary choices "by
//! changing the optimizer" — this bench shows the multiple-choice
//! knapsack finding strictly-richer allocations (some layers promoted to
//! 8-bit where the budget allows) and reports the resulting accuracy and
//! energy estimates side by side.

use mpq::methods::{self, MethodKind};
use mpq::quant::energy::EnergyModel;
use mpq::quant::{self};
use mpq::backend::TrainState;
use mpq::train::{evaluate, finetune, TrainConfig};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    let ft_steps = if quick { 30 } else { 120 };
    let eval_batches = 2;
    let energy = EnergyModel::default();

    let ck4 = co.base_checkpoint()?;
    let gains = co.gains(MethodKind::Eagl)?.per_layer;

    println!("== Ablation: binary {{2,4}} vs ternary {{2,4,8}} precision choices ==\n");
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "budget", "top1{2,4}", "top1{2,4,8}", "comp{2,4}", "comp{2,4,8}", "E-sav 2ch", "E-sav 3ch"
    );
    for frac in [0.9, 0.75, 0.6] {
        // Budgets are measured against the all-4-bit cost in both cases so
        // the comparison is at matched compute.
        let budget = co.graph.budget_at(frac, 4);
        let mut row = vec![format!("{:>7.0}%", frac * 100.0)];
        let mut cfgs = Vec::new();
        for choices in [vec![2u32, 4], vec![2, 4, 8]] {
            let bits = methods::select_multi(&co.graph, &gains, &choices, budget)?;
            let ck = methods::prepare_mp_checkpoint(&ck4, &co.graph, &bits, 4)?;
            let mut state = TrainState::new(ck);
            let tcfg = TrainConfig { steps: ft_steps, lr0: 0.005, ..Default::default() };
            finetune(&mut co.rt, &mut state, &co.data, &bits.to_f32(), &tcfg)?;
            let ev = evaluate(&mut co.rt, &state.params, &co.data, &bits.to_f32(), eval_batches)?;
            cfgs.push((bits, ev.metric));
        }
        let (b2, m2) = &cfgs[0];
        let (b3, m3) = &cfgs[1];
        row.push(format!("{:>10.4}", m2));
        row.push(format!("{:>10.4}", m3));
        row.push(format!("{:>11.2}x", quant::compression_ratio(&co.graph, b2)));
        row.push(format!("{:>11.2}x", quant::compression_ratio(&co.graph, b3)));
        row.push(format!("{:>9.2}x", energy.savings_vs(&co.graph, b2, 8)));
        row.push(format!("{:>9.2}x", energy.savings_vs(&co.graph, b3, 8)));
        println!("{}", row.join(" "));
        println!(
            "         3-choice allocation: {} at 2-bit, {} at 4-bit, {} at 8-bit",
            b3.count_at(&co.graph, 2),
            b3.count_at(&co.graph, 4),
            b3.count_at(&co.graph, 8)
        );
    }
    println!("\nshape: at matched BMACs the 3-choice optimizer can trade a few 2-bit");
    println!("drops for 8-bit promotions on high-gain layers — accuracy ≥ binary.");
    Ok(())
}
