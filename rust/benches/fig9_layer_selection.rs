//! Fig. 9: per-layer precision choices at the 70% budget, compared across
//! methods.
//!
//! Paper shape: EAGL drops *fewer* layers to 2-bit at the same budget than
//! HAWQ-v3/ALPS (it prefers dropping big-MAC low-entropy layers), and the
//! total count of dropped layers does not predict final accuracy.

use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.mcfg.alps_steps = if quick { 10 } else { 40 };
    co.mcfg.hawq_samples = 2;
    co.mcfg.hawq_batches = 2;

    println!("== Fig. 9 (analog): layer-wise precision choices @ 70% budget ==\n");
    let kinds = [
        MethodKind::Eagl,
        MethodKind::Alps,
        MethodKind::HawqV3,
        MethodKind::Uniform,
        MethodKind::FirstToLast,
    ];
    let mut choices = Vec::new();
    for kind in kinds {
        let bits = co.select(kind, 0.70)?;
        let dropped = bits.count_at(&co.graph, 2);
        println!("{:<15} {} of {} selectable layers at 2-bit", kind.name(), dropped, co.graph.groups.len());
        choices.push((kind.name().to_string(), bits));
    }
    println!();
    println!("{}", report::layer_selection_map(&co.graph, &choices));
    Ok(())
}
