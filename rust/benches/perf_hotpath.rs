//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): per-layer latencies of
//! everything the coordinator executes repeatedly.
//!
//!  * backend hot path: fused train_step / eval_step per model (batch
//!    included) — the dominant cost of every experiment.  Runs on the
//!    hermetic sim models always, and on the artifact models when
//!    artifacts + the pjrt feature are available;
//!  * L3: knapsack solve (paper: their Python took 2.3 s on ResNet-50 —
//!    target ≥100× faster), EAGL metric, data generation, checkpoint I/O,
//!    manifest JSON parse.
//!
//! Every measurement is recorded into a machine-readable
//! `BENCH_hotpath.json` (path: `MPQ_BENCH_OUT`, else the cwd) via the
//! [`mpq::bench::BenchSink`]; when a previous record exists, each
//! measurement also prints its speedup against the recorded mean, so
//! perf claims in PRs are checked against the baseline file rather than
//! asserted from memory.  `make bench-quick` runs this in quick mode and
//! writes the record at the repo root.

use std::collections::BTreeMap;

use mpq::backend::{Backend, KernelChoice, KernelTuning, PackedVariant, TrainState};
use mpq::bench::{coordinator_or_skip, fmt_s, header, measure, try_measure, BenchSink, Measurement};
use mpq::data::{Dataset, Split};
use mpq::kernels::{gemm, packed};
use mpq::knapsack;
use mpq::quant::{self, BitsConfig};
use mpq::rng::Pcg32;

/// Report a measurement, print its delta vs the recorded baseline (if
/// any), and record it into the sink.
fn note(sink: &mut BenchSink, baseline: &Option<BTreeMap<String, f64>>, m: Measurement) {
    m.report();
    if let Some(base) = baseline {
        if let Some(&old) = base.get(&m.name) {
            if m.mean_s > 0.0 && old > 0.0 {
                println!(
                    "  -> vs recorded baseline: {:>6.2}x  ({} -> {})",
                    old / m.mean_s,
                    fmt_s(old),
                    fmt_s(m.mean_s)
                );
            }
        }
    }
    sink.record(m);
}

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let iters = if quick { 5 } else { 20 };
    let out_path = BenchSink::out_path("hotpath");
    let baseline = mpq::bench::load_baseline(&out_path);
    let mut sink = BenchSink::new("hotpath");
    if baseline.is_some() {
        println!("comparing against recorded baseline {}\n", out_path.display());
    }
    header();

    // -- L3 pure-host paths -------------------------------------------------
    // Knapsack at paper scale: ResNet-50 has 54 quantizable layers; also a
    // 1000-layer stress case at fine capacity resolution.
    let mut rng = Pcg32::new(1, 1);
    for &(n, cap) in &[(54usize, 1_000_000u64), (1000, 10_000_000)] {
        let values: Vec<u64> = (0..n).map(|_| rng.below(10_000) as u64 + 1).collect();
        let weights: Vec<u64> = (0..n).map(|_| rng.below(50_000) as u64 + 1).collect();
        let m = measure(&format!("knapsack n={n} cap={cap}"), 1, iters, || {
            std::hint::black_box(knapsack::solve_01(&values, &weights, cap));
        });
        note(&mut sink, &baseline, m);
    }

    // EAGL + checkpoint I/O over a realistic checkpoint (any model that
    // opens in this environment; sim_skew always does).
    if let Some(co) = coordinator_or_skip("sim_skew", 7) {
        let ck = co.rt.init_checkpoint()?;
        let graph = co.graph.clone();
        let m = measure("eagl metric sim_skew (full ckpt)", 1, iters, || {
            std::hint::black_box(mpq::eagl::checkpoint_entropies(&graph, &ck, 4).unwrap());
        });
        note(&mut sink, &baseline, m);

        let tmp = std::env::temp_dir().join("mpq_perf.ckpt");
        let m = measure("checkpoint save sim_skew", 1, iters, || {
            ck.save(&tmp).unwrap();
        });
        note(&mut sink, &baseline, m);
        let m = measure("checkpoint load sim_skew", 1, iters, || {
            std::hint::black_box(mpq::ckpt::Checkpoint::load(&tmp).unwrap());
        });
        note(&mut sink, &baseline, m);
        let _ = std::fs::remove_file(&tmp);

        // Manifest JSON parse (the sim manifest re-serialized).
        let text = co.rt.manifest().raw.to_string_compact();
        let m = measure("manifest JSON parse", 1, iters, || {
            std::hint::black_box(mpq::jsonio::parse(&text).unwrap());
        });
        note(&mut sink, &baseline, m);
    }

    // Data generation (host side of every train step).  The Dataset memo
    // caches repeated batches, so measure the miss path with a fresh
    // index per iteration, and the hit path on a pinned index.
    for task in [mpq::backend::Task::Cls, mpq::backend::Task::Seg, mpq::backend::Task::Span] {
        let ds = Dataset::for_task(task, 7);
        let mut i = 0u64;
        let m = measure(&format!("datagen {:?} batch=64 (miss)", task), 1, iters, || {
            i += 1;
            std::hint::black_box(ds.batch(Split::Train, i, 64));
        });
        note(&mut sink, &baseline, m);
        let m = measure(&format!("datagen {:?} batch=64 (memo hit)", task), 1, iters, || {
            std::hint::black_box(ds.batch(Split::Train, 1, 64));
        });
        note(&mut sink, &baseline, m);
    }

    // -- packed integer kernels (the serve hot path's compute format) --------
    // One synthetic layer large enough that the weight working set
    // actually moves between cache levels: at 2-bit the packed codes are
    // 16x smaller than the f32 fake-quant image.  Rows compare the
    // reference GEMM against the LUT-decode packed GEMM (bit-identical
    // results) and the fully integer u8xpacked i32 MAC.
    {
        let (fi, fo, batch) = if quick { (128usize, 128usize, 8usize) } else { (256, 256, 16) };
        let (sw, sa) = (0.02f32, 0.05f32);
        let mut rng = Pcg32::new(3, 3);
        let w: Vec<f32> = (0..fi * fo).map(|_| rng.normal() * 0.05).collect();
        let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
        let acodes: Vec<u8> = (0..batch * fi).map(|_| rng.below(16) as u8).collect();
        let a: Vec<f32> = acodes.iter().map(|&c| c as f32 * sa).collect();
        let mut z = vec![0f32; batch * fo];
        for &bits in &[2u32, 4, 8] {
            let (qn, qp) = quant::qrange_signed(bits);
            let mut wt = vec![0f32; fi * fo];
            let mut w_in = vec![false; fi * fo];
            gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
            let m = measure(&format!("gemm reference f32 {fi}x{fo} b={bits}"), 1, iters, || {
                gemm::gemm_bias_wt(&a, &wt, &bias, &mut z, batch, fi, fo);
                std::hint::black_box(&z);
            });
            note(&mut sink, &baseline, m);
            let pk = packed::pack(&w, sw, bits, fi, fo)?;
            let m = measure(&format!("gemm packed lut {fi}x{fo} b={bits}"), 1, iters, || {
                packed::gemm_bias_packed(&a, &pk, &bias, &mut z, batch);
                std::hint::black_box(&z);
            });
            note(&mut sink, &baseline, m);
            let m = measure(&format!("gemm packed i32 {fi}x{fo} b={bits}"), 1, iters, || {
                packed::gemm_bias_packed_i32(&acodes, &pk, &bias, sa * sw, &mut z, batch);
                std::hint::black_box(&z);
            });
            note(&mut sink, &baseline, m);
            println!(
                "{:<44} {:>10} packed vs {} f32",
                format!("  -> b={bits} weight bytes"),
                pk.packed_bytes(),
                4 * fi * fo
            );

            // Variant × gemm-threads grid (the SIMD/unrolled + row-parallel
            // trajectory): results are bit-identical across every cell —
            // asserted in the kernel tests — so these rows measure pure
            // speed.  The untagged rows above keep their PR 5 names (they
            // now run the default = unrolled tiles).
            let mut i32_means: BTreeMap<(&'static str, usize), f64> = BTreeMap::new();
            #[allow(unused_mut)]
            let mut variants = vec![PackedVariant::Scalar, PackedVariant::Unrolled];
            #[cfg(feature = "simd")]
            variants.push(PackedVariant::Simd);
            for &v in &variants {
                for &t in &[1usize, 4] {
                    let m = measure(
                        &format!("gemm packed lut {} {fi}x{fo} b={bits} t={t}", v.name()),
                        1,
                        iters,
                        || {
                            packed::gemm_bias_packed_v(&a, &pk, &bias, &mut z, batch, v, t);
                            std::hint::black_box(&z);
                        },
                    );
                    note(&mut sink, &baseline, m);
                    let m = measure(
                        &format!("gemm packed i32 {} {fi}x{fo} b={bits} t={t}", v.name()),
                        1,
                        iters,
                        || {
                            packed::gemm_bias_packed_i32_v(
                                &acodes, &pk, &bias, sa * sw, &mut z, batch, v, t,
                            );
                            std::hint::black_box(&z);
                        },
                    );
                    i32_means.insert((v.name(), t), m.mean_s);
                    note(&mut sink, &baseline, m);
                }
            }
            for &t in &[1usize, 4] {
                if let (Some(&s), Some(&u)) =
                    (i32_means.get(&("scalar", t)), i32_means.get(&("unrolled", t)))
                {
                    println!(
                        "{:<44} {:>6.2}x  ({} -> {})",
                        format!("  -> i32 unrolled vs scalar b={bits} t={t}"),
                        s / u,
                        fmt_s(s),
                        fmt_s(u)
                    );
                }
                #[cfg(feature = "simd")]
                if let (Some(&s), Some(&d)) =
                    (i32_means.get(&("scalar", t)), i32_means.get(&("simd", t)))
                {
                    println!(
                        "{:<44} {:>6.2}x  ({} -> {})",
                        format!("  -> i32 simd vs scalar b={bits} t={t}"),
                        s / d,
                        fmt_s(s),
                        fmt_s(d)
                    );
                }
            }
        }
    }

    // -- backend executable hot paths ---------------------------------------
    for model in ["sim_tiny", "sim_skew", "qsegnet", "qresnet20", "qbert"] {
        let Some(mut co) = coordinator_or_skip(model, 7) else {
            continue;
        };
        let bits = BitsConfig::uniform(&co.graph, 4).to_f32();
        let ck = co.rt.init_checkpoint()?;
        let train_batch = co.rt.manifest().train_batch;
        let eval_batch = co.rt.manifest().eval_batch;
        let (xt, yt) = co.data.batch(Split::Train, 0, train_batch);
        let (xe, ye) = co.data.batch(Split::Eval, 0, eval_batch);
        let mut state = TrainState::new(ck.clone());

        let m = try_measure(&format!("{model} train_step (b={train_batch})"), 2, iters, || {
            co.rt.train_step(&mut state, &xt, &yt, 0.01, 1e-4, &bits)?;
            Ok(())
        })?;
        let thr = m.throughput(train_batch as f64);
        note(&mut sink, &baseline, m);
        println!(
            "{:<44} {:>10.1} samples/s",
            format!("  -> {model} train throughput"),
            thr
        );
        let m = try_measure(&format!("{model} eval_step (b={eval_batch})"), 1, iters, || {
            co.rt.eval_step(&ck, &xe, &ye, &bits)?;
            Ok(())
        })?;
        let thr = m.throughput(eval_batch as f64);
        note(&mut sink, &baseline, m);
        println!(
            "{:<44} {:>10.1} samples/s",
            format!("  -> {model} eval throughput"),
            thr
        );
    }

    // -- serving engine ------------------------------------------------------
    // The serve path (mpq serve): dynamic micro-batching over per-worker
    // backends, driven closed-loop by the deterministic loadgen.  Each
    // config records the request-latency histogram and the wall-clock
    // seconds-per-request (whose inverse is req/s).
    // Rows cover 1 vs N workers, unbatched (max-batch 1) vs batched
    // (max-batch 32), and the reference vs packed kernel paths
    // (`--kernel` on `mpq serve`; packed shares one bit-packed weight
    // materialization across all workers).  Reference rows keep their
    // original names so the recorded trajectory stays comparable; packed
    // rows carry a `kernel=packed` tag (and now run the default unrolled
    // tiles), a `variant=scalar` row pins the pre-variant tiles, and —
    // under `--features simd` — a `variant=simd` row measures the 16-wide
    // tiles.  Packed-vs-reference and variant-vs-scalar wall/req
    // comparisons print per configuration.
    {
        use mpq::serve::{loadgen, Engine, LoadMode, LoadSpec, ServeConfig, Spawner};
        let be = mpq::backend::SimBackend::new("sim_skew")?;
        let ck = be.init_checkpoint()?;
        let graph = mpq::graph::Graph::from_manifest(&be.manifest().raw)?;
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let data = Dataset::for_task(mpq::backend::Task::Cls, 7);
        let requests = if quick { 64 } else { 256 };
        let mut wall_per_req: BTreeMap<(&'static str, usize, usize), f64> = BTreeMap::new();
        #[allow(unused_mut)]
        let mut entries: Vec<(&'static str, &'static str, KernelChoice, KernelTuning)> = vec![
            ("reference", "", KernelChoice::Reference, KernelTuning::default()),
            ("packed", "kernel=packed ", KernelChoice::Packed, KernelTuning::default()),
            (
                "packed-scalar",
                "kernel=packed variant=scalar ",
                KernelChoice::Packed,
                KernelTuning { variant: PackedVariant::Scalar, gemm_threads: 1 },
            ),
        ];
        #[cfg(feature = "simd")]
        entries.push((
            "packed-simd",
            "kernel=packed variant=simd ",
            KernelChoice::Packed,
            KernelTuning { variant: PackedVariant::Simd, gemm_threads: 1 },
        ));
        for &(label, tag, kernel, tuning) in &entries {
            let spawner: Spawner = std::sync::Arc::new(move || {
                Ok(Box::new(mpq::backend::SimBackend::with_tuning("sim_skew", kernel, tuning)?)
                    as Box<dyn Backend>)
            });
            for &(workers, max_batch) in &[(1usize, 1usize), (1, 32), (4, 1), (4, 32)] {
                let cfg = ServeConfig {
                    workers,
                    max_batch,
                    batch_timeout: std::time::Duration::from_millis(1),
                    force_per_request: false,
                    warmup: true,
                    ..ServeConfig::default()
                };
                let engine = Engine::start(spawner.clone(), ck.clone(), bits.clone(), cfg)?;
                let spec = LoadSpec {
                    requests,
                    max_request_samples: 2,
                    seed: 42,
                    mode: LoadMode::Closed { concurrency: 8 },
                };
                let load = loadgen::run(&engine, &data, &spec)?;
                let snap = engine.drain()?;
                let m = Measurement {
                    name: format!("serve sim_skew {tag}w={workers} mb={max_batch} req lat"),
                    iters: snap.completed as usize,
                    mean_s: snap.mean_latency_s,
                    std_s: 0.0,
                    p50_s: snap.p50_s,
                    p95_s: snap.p95_s,
                    p99_s: snap.p99_s,
                    min_s: snap.min_latency_s,
                };
                note(&mut sink, &baseline, m);
                let per_req = load.wall_s / requests as f64;
                wall_per_req.insert((label, workers, max_batch), per_req);
                let m = Measurement {
                    name: format!("serve sim_skew {tag}w={workers} mb={max_batch} wall/req"),
                    iters: requests,
                    mean_s: per_req,
                    std_s: 0.0,
                    p50_s: per_req,
                    p95_s: per_req,
                    p99_s: per_req,
                    min_s: per_req,
                };
                note(&mut sink, &baseline, m);
                println!(
                    "{:<44} {:>10.1} req/s  {:>8.1} samples/s  occupancy {:.2}",
                    format!("  -> serve {tag}w={workers} mb={max_batch} throughput"),
                    load.throughput_rps,
                    load.samples_per_s,
                    snap.mean_occupancy()
                );
            }
        }
        for &(workers, max_batch) in &[(1usize, 1usize), (1, 32), (4, 1), (4, 32)] {
            if let (Some(&r), Some(&p)) = (
                wall_per_req.get(&("reference", workers, max_batch)),
                wall_per_req.get(&("packed", workers, max_batch)),
            ) {
                println!(
                    "{:<44} {:>6.2}x  ({} -> {})",
                    format!("  -> packed vs reference w={workers} mb={max_batch}"),
                    r / p,
                    fmt_s(r),
                    fmt_s(p)
                );
            }
            if let (Some(&s), Some(&u)) = (
                wall_per_req.get(&("packed-scalar", workers, max_batch)),
                wall_per_req.get(&("packed", workers, max_batch)),
            ) {
                println!(
                    "{:<44} {:>6.2}x  ({} -> {})",
                    format!("  -> packed unrolled vs scalar w={workers} mb={max_batch}"),
                    s / u,
                    fmt_s(s),
                    fmt_s(u)
                );
            }
            #[cfg(feature = "simd")]
            if let (Some(&s), Some(&d)) = (
                wall_per_req.get(&("packed-scalar", workers, max_batch)),
                wall_per_req.get(&("packed-simd", workers, max_batch)),
            ) {
                println!(
                    "{:<44} {:>6.2}x  ({} -> {})",
                    format!("  -> packed simd vs scalar w={workers} mb={max_batch}"),
                    s / d,
                    fmt_s(s),
                    fmt_s(d)
                );
            }
        }
    }

    // -- span-tracing overhead -----------------------------------------------
    // The same in-process closed-loop drive with the trace sink off, at
    // sample=1 (every request carries the full span set), and at
    // sample=16 (1-in-16).  Disabled tracing is one `Option` check at
    // admission; the printed ratios are the observability tax the
    // `--trace-sample` flag buys into.  Row names are new — the existing
    // `serve sim_skew ...` trajectory above is untouched.
    {
        use mpq::serve::{
            loadgen, Engine, LoadMode, LoadSpec, ServeConfig, Spawner, TraceConfig, TraceSink,
        };
        let be = mpq::backend::SimBackend::new("sim_skew")?;
        let ck = be.init_checkpoint()?;
        let graph = mpq::graph::Graph::from_manifest(&be.manifest().raw)?;
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let data = Dataset::for_task(mpq::backend::Task::Cls, 7);
        let requests = if quick { 64 } else { 256 };
        let spawner: Spawner = std::sync::Arc::new(|| {
            Ok(Box::new(mpq::backend::SimBackend::new("sim_skew")?) as Box<dyn Backend>)
        });
        let mut per_cfg: BTreeMap<(&'static str, usize), f64> = BTreeMap::new();
        for &workers in &[1usize, 4] {
            for &(tag, sample) in &[("trace=off", 0u64), ("trace=1", 1), ("trace=16", 16)] {
                let trace = (sample > 0)
                    .then(|| TraceSink::new(TraceConfig { sample, ..TraceConfig::default() }));
                let cfg = ServeConfig {
                    workers,
                    max_batch: 32,
                    batch_timeout: std::time::Duration::from_millis(1),
                    force_per_request: false,
                    warmup: true,
                    trace,
                    ..ServeConfig::default()
                };
                let engine = Engine::start(spawner.clone(), ck.clone(), bits.clone(), cfg)?;
                let spec = LoadSpec {
                    requests,
                    max_request_samples: 2,
                    seed: 42,
                    mode: LoadMode::Closed { concurrency: 8 },
                };
                let load = loadgen::run(&engine, &data, &spec)?;
                engine.drain()?;
                let per_req = load.wall_s / requests as f64;
                per_cfg.insert((tag, workers), per_req);
                let m = Measurement {
                    name: format!("serve sim_skew {tag} w={workers} mb=32 wall/req"),
                    iters: requests,
                    mean_s: per_req,
                    std_s: 0.0,
                    p50_s: per_req,
                    p95_s: per_req,
                    p99_s: per_req,
                    min_s: per_req,
                };
                note(&mut sink, &baseline, m);
            }
            for &(tag, label) in &[("trace=1", "sample=1"), ("trace=16", "sample=16")] {
                if let (Some(&off), Some(&on)) =
                    (per_cfg.get(&("trace=off", workers)), per_cfg.get(&(tag, workers)))
                {
                    println!(
                        "{:<44} {:>6.2}x  ({} -> {})",
                        format!("  -> trace overhead {label} w={workers}"),
                        on / off,
                        fmt_s(off),
                        fmt_s(on)
                    );
                }
            }
        }
    }

    // -- config hot-swap latency ---------------------------------------------
    // Wall time from just before `Engine::swap` to the first response
    // served under the new epoch, with a backlog of old-epoch requests
    // in flight — the availability cost of one controller decision
    // (materialize off the hot path + atomic publish + drain the
    // admitted backlog ahead of the probe).
    {
        use mpq::serve::{Engine, ServeConfig, Spawner};
        let be = mpq::backend::SimBackend::new("sim_skew")?;
        let ck = be.init_checkpoint()?;
        let graph = mpq::graph::Graph::from_manifest(&be.manifest().raw)?;
        let bits_a = BitsConfig::uniform(&graph, 4).to_f32();
        let mut lo = BitsConfig::uniform(&graph, 4);
        for l in &graph.layers {
            if l.fixed_bits.is_none() {
                lo.bits[l.qindex] = 2;
            }
        }
        let bits_b = lo.to_f32();
        let data = Dataset::for_task(mpq::backend::Task::Cls, 7);
        let spawner: Spawner = std::sync::Arc::new(|| {
            Ok(Box::new(mpq::backend::SimBackend::new("sim_skew")?) as Box<dyn Backend>)
        });
        for &workers in &[1usize, 4] {
            let engine = Engine::start(
                spawner.clone(),
                ck.clone(),
                bits_a.clone(),
                ServeConfig {
                    workers,
                    max_batch: 32,
                    batch_timeout: std::time::Duration::from_millis(1),
                    force_per_request: false,
                    warmup: true,
                    ..ServeConfig::default()
                },
            )?;
            let mut durs: Vec<f64> = Vec::with_capacity(iters);
            for it in 0..iters {
                // Old-epoch backlog riding through the swap.
                let background: mpq::Result<Vec<_>> = (0..16)
                    .map(|j| {
                        let (x, y) = data.batch(Split::Eval, (it * 16 + j) as u64, 1);
                        engine.submit(x, y)
                    })
                    .collect();
                let background = background?;
                // Alternate targets so every iteration is a real config
                // change (each swap bumps the epoch).
                let to_bits = if it % 2 == 0 { &bits_b } else { &bits_a };
                let (px, py) = data.batch(Split::Eval, 100_000 + it as u64, 1);
                let t0 = std::time::Instant::now();
                let epoch = engine.swap(ck.clone(), to_bits.clone(), 0.5, "swap-bench")?;
                let probe = engine.submit(px, py)?.wait()?;
                let dt = t0.elapsed().as_secs_f64();
                mpq::ensure!(
                    probe.epoch == epoch,
                    "swap bench: probe served under epoch {} != {epoch}",
                    probe.epoch
                );
                for t in background {
                    t.wait()?;
                }
                durs.push(dt);
            }
            engine.drain()?;
            durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q = |f: f64| durs[((f * durs.len() as f64).ceil() as usize).clamp(1, durs.len()) - 1];
            let m = Measurement {
                name: format!("serve sim_skew swap latency w={workers}"),
                iters: durs.len(),
                mean_s: durs.iter().sum::<f64>() / durs.len() as f64,
                std_s: 0.0,
                p50_s: q(0.50),
                p95_s: q(0.95),
                p99_s: q(0.99),
                min_s: durs[0],
            };
            note(&mut sink, &baseline, m);
        }
    }

    // -- serving over real loopback sockets ----------------------------------
    // The same engine behind the HTTP/1.1 front door (mpq serve --listen),
    // driven by the socket loadgen: these rows isolate the network +
    // parse + JSON-transport overhead against the matching in-process
    // `serve sim_skew w=.. mb=32` rows above.
    {
        use mpq::serve::{
            loadgen, Engine, HttpConfig, HttpServer, LoadMode, LoadSpec, ServeConfig, Spawner,
        };
        let be = mpq::backend::SimBackend::new("sim_skew")?;
        let ck = be.init_checkpoint()?;
        let graph = mpq::graph::Graph::from_manifest(&be.manifest().raw)?;
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let data = Dataset::for_task(mpq::backend::Task::Cls, 7);
        let requests = if quick { 64 } else { 256 };
        for &(kernel, tag, workers) in &[
            (KernelChoice::Reference, "", 1usize),
            (KernelChoice::Packed, "kernel=packed ", 4),
        ] {
            let spawner: Spawner = std::sync::Arc::new(move || {
                Ok(Box::new(mpq::backend::SimBackend::with_kernel("sim_skew", kernel)?)
                    as Box<dyn Backend>)
            });
            let cfg = ServeConfig {
                workers,
                max_batch: 32,
                batch_timeout: std::time::Duration::from_millis(1),
                force_per_request: false,
                warmup: true,
                ..ServeConfig::default()
            };
            let engine = Engine::start(spawner, ck.clone(), bits.clone(), cfg)?;
            let server = HttpServer::start(engine, data.clone(), HttpConfig::default())?;
            let addr = server.local_addr().to_string();
            let spec = LoadSpec {
                requests,
                max_request_samples: 2,
                seed: 42,
                mode: LoadMode::Closed { concurrency: 8 },
            };
            let load = loadgen::run_http(&addr, &spec)?;
            let (snap, hstats) = server.shutdown()?;
            mpq::ensure!(
                hstats.admitted == hstats.answered && snap.failed == 0,
                "http bench: admitted {} != answered {} ({} engine failures)",
                hstats.admitted,
                hstats.answered,
                snap.failed
            );
            let m = Measurement {
                name: format!("serve sim_skew http {tag}w={workers} mb=32 req lat"),
                iters: snap.completed as usize,
                mean_s: snap.mean_latency_s,
                std_s: 0.0,
                p50_s: snap.p50_s,
                p95_s: snap.p95_s,
                p99_s: snap.p99_s,
                min_s: snap.min_latency_s,
            };
            note(&mut sink, &baseline, m);
            let per_req = load.wall_s / requests as f64;
            let m = Measurement {
                name: format!("serve sim_skew http {tag}w={workers} mb=32 wall/req"),
                iters: requests,
                mean_s: per_req,
                std_s: 0.0,
                p50_s: per_req,
                p95_s: per_req,
                p99_s: per_req,
                min_s: per_req,
            };
            note(&mut sink, &baseline, m);
            println!(
                "{:<44} {:>10.1} req/s  {:>8.1} samples/s",
                format!("  -> serve http {tag}w={workers} mb=32 throughput"),
                load.throughput_rps,
                load.samples_per_s
            );
        }
    }

    sink.write(&out_path)?;
    println!(
        "\nwrote {} ({} measurements)",
        out_path.display(),
        sink.measurements.len()
    );
    Ok(())
}
