//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): per-layer latencies of
//! everything the coordinator executes repeatedly.
//!
//!  * L2/L1: fused train_step / eval_step per model (batch included) —
//!    the dominant cost of every experiment;
//!  * L3: knapsack solve (paper: their Python took 2.3 s on ResNet-50 —
//!    target ≥100× faster), EAGL metric, data generation, checkpoint I/O,
//!    manifest JSON parse.

use mpq::bench::{header, measure, try_measure};
use mpq::data::{Dataset, Split};
use mpq::graph::Graph;
use mpq::knapsack;
use mpq::quant::BitsConfig;
use mpq::rng::Pcg32;
use mpq::runtime::{Runtime, TrainState};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let artifacts = mpq::artifacts_dir();
    let iters = if quick { 5 } else { 20 };
    header();

    // -- L3 pure-host paths -------------------------------------------------
    // Knapsack at paper scale: ResNet-50 has 54 quantizable layers; also a
    // 1000-layer stress case at fine capacity resolution.
    let mut rng = Pcg32::new(1, 1);
    for &(n, cap) in &[(54usize, 1_000_000u64), (1000, 10_000_000)] {
        let values: Vec<u64> = (0..n).map(|_| rng.below(10_000) as u64 + 1).collect();
        let weights: Vec<u64> = (0..n).map(|_| rng.below(50_000) as u64 + 1).collect();
        measure(&format!("knapsack n={n} cap={cap}"), 1, iters, || {
            std::hint::black_box(knapsack::solve_01(&values, &weights, cap));
        })
        .report();
    }

    // EAGL over a realistic checkpoint.
    if artifacts.join("qresnet20.manifest.json").exists() {
        let rt = Runtime::load(&artifacts, "qresnet20")?;
        let graph = Graph::load(&artifacts, "qresnet20")?;
        let ck = rt.init_checkpoint()?;
        measure("eagl metric qresnet20 (full ckpt)", 1, iters, || {
            std::hint::black_box(mpq::eagl::checkpoint_entropies(&graph, &ck, 4).unwrap());
        })
        .report();

        // Checkpoint I/O.
        let tmp = std::env::temp_dir().join("mpq_perf.ckpt");
        measure("checkpoint save qresnet20", 1, iters, || {
            ck.save(&tmp).unwrap();
        })
        .report();
        measure("checkpoint load qresnet20", 1, iters, || {
            std::hint::black_box(mpq::ckpt::Checkpoint::load(&tmp).unwrap());
        })
        .report();
        let _ = std::fs::remove_file(&tmp);

        // Manifest parse.
        let text = std::fs::read_to_string(artifacts.join("qresnet20.manifest.json"))?;
        measure("manifest JSON parse", 1, iters, || {
            std::hint::black_box(mpq::jsonio::parse(&text).unwrap());
        })
        .report();
    }

    // Data generation (host side of every train step).
    for task in [mpq::runtime::Task::Cls, mpq::runtime::Task::Seg, mpq::runtime::Task::Span] {
        let ds = Dataset::for_task(task, 7);
        let mut i = 0u64;
        measure(&format!("datagen {:?} batch=64", task), 1, iters, || {
            i += 1;
            std::hint::black_box(ds.batch(Split::Train, i, 64));
        })
        .report();
    }

    // -- L2/L1 executable hot paths ------------------------------------------
    for model in ["qsegnet", "qresnet20", "qbert"] {
        if !artifacts.join(format!("{model}.manifest.json")).exists() {
            continue;
        }
        let mut rt = Runtime::load(&artifacts, model)?;
        let graph = Graph::load(&artifacts, model)?;
        let data = Dataset::for_task(rt.manifest.task, 7);
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let ck = rt.init_checkpoint()?;
        let (xt, yt) = data.batch(Split::Train, 0, rt.manifest.train_batch);
        let (xe, ye) = data.batch(Split::Eval, 0, rt.manifest.eval_batch);
        let mut state = TrainState::new(ck.clone());

        let m = try_measure(&format!("{model} train_step (b={})", rt.manifest.train_batch), 2, iters, || {
            rt.train_step(&mut state, &xt, &yt, 0.01, 1e-4, &bits)?;
            Ok(())
        })?;
        m.report();
        println!(
            "{:<44} {:>10.1} samples/s",
            format!("  -> {model} train throughput"),
            m.throughput(rt.manifest.train_batch as f64)
        );
        let m = try_measure(&format!("{model} eval_step (b={})", rt.manifest.eval_batch), 1, iters, || {
            rt.eval_step(&ck, &xe, &ye, &bits)?;
            Ok(())
        })?;
        m.report();
        println!(
            "{:<44} {:>10.1} samples/s",
            format!("  -> {model} eval throughput"),
            m.throughput(rt.manifest.eval_batch as f64)
        );
    }
    Ok(())
}
