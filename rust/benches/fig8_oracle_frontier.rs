//! Fig. 8 / Appendix B: EAGL and ALPS frontiers vs the regression-
//! coefficient "oracle" — the strongest (but impractical: the paper burned
//! ~1080 A100-hours building it) gain estimate available.
//!
//! Requires `fig7_regression` to have run first (it writes
//! `results/qresnet20/gains_oracle.json`); the oracle then rides the
//! standard gain-cache path.
//!
//! Paper shape: EAGL/ALPS hug the oracle frontier — little headroom left.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.ft_steps = if quick { 30 } else { 120 };
    co.eval_batches = 4;
    co.mcfg.alps_steps = if quick { 10 } else { 40 };

    let oracle_path = co.results_dir.join("gains_oracle.json");
    if !oracle_path.exists() {
        println!("oracle gains missing — run `cargo bench --bench fig7_regression` first;");
        println!("falling back to EAGL/ALPS-only frontier.");
    }

    let budgets = [0.90, 0.80, 0.70, 0.60];
    let seeds: Vec<u64> = (0..if quick { 1 } else { 3 }).collect();
    let mut kinds = vec![MethodKind::Eagl, MethodKind::Alps];
    if oracle_path.exists() {
        kinds.push(MethodKind::Oracle);
    }
    println!("== Fig. 8 (analog): oracle vs EAGL/ALPS frontiers ==\n");
    let mut store = ResultStore::open(&co.results_dir.join("sweep.jsonl"))?;
    let records = co.sweep(&kinds, &budgets, &seeds, &mut store)?;
    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, "top-1"));
    println!("{}", report::frontier_plot(&cells, 64, 14));
    if oracle_path.exists() {
        for (a, b) in [("eagl", "oracle"), ("alps", "oracle")] {
            for (budget, p) in report::significance(&cells, a, b) {
                println!("Wilcoxon {a} vs {b} @ {:>3.0}%: p = {:.4}", budget * 100.0, p);
            }
        }
    }
    report::write_csv(&cells, &co.results_dir.join("fig8.csv"))?;
    Ok(())
}
