//! Fig. 3: the accuracy–throughput frontier for qresnet20 (and qresnet32
//! unless quick): 8 budgets × methods × seeds, mean ± std, Wilcoxon
//! significance of EAGL/ALPS vs HAWQ-v3 and the baselines.
//!
//! Paper shape: EAGL and ALPS at or above every comparator across the
//! whole frontier; all methods converge at the 95-100% end.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report;

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let models: &[&str] = if quick { &["qresnet20"] } else { &["qresnet20", "qresnet32"] };
    let budgets: &[f64] = if quick {
        &[0.90, 0.80, 0.70, 0.60]
    } else {
        &[0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60]
    };
    let seeds: Vec<u64> = (0..if quick { 1 } else { 3 }).collect();
    let kinds: &[MethodKind] = if quick {
        &[MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3, MethodKind::FirstToLast]
    } else {
        &[MethodKind::Eagl, MethodKind::Alps, MethodKind::HawqV3,
          MethodKind::Uniform, MethodKind::FirstToLast, MethodKind::LastToFirst]
    };
    for model in models {
        let Some(mut co) = mpq::bench::coordinator_or_skip(model, 7) else {
            continue;
        };
        co.base_steps = if quick { 150 } else { 400 };
        co.ft_steps = if quick { 30 } else { 120 };
        co.eval_batches = 4;
        co.mcfg.alps_steps = if quick { 10 } else { 40 };
        co.mcfg.hawq_samples = 2;
        co.mcfg.hawq_batches = 2;
        println!("== Fig. 3 (analog): {model} frontier ==\n");
        let mut store = ResultStore::open(&co.results_dir.join("sweep.jsonl"))?;
        let records = co.sweep(kinds, budgets, &seeds, &mut store)?;
        let cells = report::frontier(&records);
        println!("{}", report::frontier_table(&cells, "top-1"));
        println!("{}", report::frontier_plot(&cells, 64, 16));
        for (a, b) in [("eagl", "hawq_v3"), ("alps", "hawq_v3"), ("eagl", "first_to_last")] {
            for (budget, p) in report::significance(&cells, a, b) {
                println!("Wilcoxon {a} vs {b} @ {:>3.0}%: p = {:.4}", budget * 100.0, p);
            }
        }
        report::write_csv(&cells, &co.results_dir.join("fig3.csv"))?;
        println!();
    }
    Ok(())
}
