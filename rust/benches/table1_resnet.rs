//! Table 1: classification accuracy drop / compression ratio / GBOPs for
//! mixed 4/2-bit qresnet20 networks at the ~70% budget, per method.
//!
//! Paper shape to reproduce: EAGL and ALPS recover (or exceed) the
//! reference accuracy (negative drop) at ~10x compression while comparator
//! selections lose more accuracy at the same budget.
//!
//! Env: MPQ_BENCH_QUICK=1 shrinks training budgets.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report::{summary_table, SummaryRow};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qresnet20", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.ft_steps = if quick { 30 } else { 150 };
    co.eval_batches = 4;
    co.mcfg.alps_steps = if quick { 10 } else { 40 };
    co.mcfg.hawq_samples = 2;
    co.mcfg.hawq_batches = 2;

    println!("== Table 1 (analog): qresnet20 @ 70% budget ==\n");
    let ck8 = co.reference_checkpoint()?;
    let ref_metric = co.eval_uniform(&ck8, 8)?.metric;
    println!("8-bit reference top-1: {:.4}\n", ref_metric);

    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path)?;
    let kinds = [
        MethodKind::Eagl,
        MethodKind::Alps,
        MethodKind::HawqV3,
        MethodKind::Uniform,
        MethodKind::FirstToLast,
    ];
    let seeds: [u64; 1] = [0];
    let records = co.sweep(&kinds, &[0.70], &seeds, &mut store)?;

    let mut rows = Vec::new();
    for r in &records {
        rows.push(SummaryRow {
            method: r.method.clone(),
            metric_drop: ref_metric - r.metric,
            ref_metric,
            mp_metric: r.metric,
            compression: r.compression,
            gbops: r.gbops,
        });
    }
    rows.sort_by(|a, b| a.metric_drop.partial_cmp(&b.metric_drop).unwrap());
    println!("{}", summary_table(&rows, "top-1"));
    println!("paper shape: EAGL/ALPS rows should sit at the top (lowest drop).");
    Ok(())
}
