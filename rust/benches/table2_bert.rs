//! Table 2: span-extraction F1 (drop) + compression for mixed 4/2-bit
//! qbert networks — the BERT-base/SQuAD analog.
//!
//! Paper shape: EAGL/ALPS find 4/2-bit mixes whose F1 matches or exceeds
//! the reference at ~8-9x compression, beating topological selections.

use mpq::coordinator::ResultStore;
use mpq::methods::MethodKind;
use mpq::report::{summary_table, SummaryRow};

fn main() -> mpq::Result<()> {
    let quick = mpq::bench::quick();
    let Some(mut co) = mpq::bench::coordinator_or_skip("qbert", 7) else {
        return Ok(());
    };
    co.base_steps = if quick { 150 } else { 400 };
    co.ft_steps = if quick { 30 } else { 150 };
    co.eval_batches = 2;
    co.mcfg.alps_steps = if quick { 8 } else { 30 };
    co.mcfg.hawq_samples = 2;
    co.mcfg.hawq_batches = 1;

    println!("== Table 2 (analog): qbert 4/2-bit mixes ==\n");
    let ck8 = co.reference_checkpoint()?;
    let ref_f1 = co.eval_uniform(&ck8, 8)?.metric;
    println!("8-bit reference F1: {:.4}\n", ref_f1);

    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path)?;
    let kinds = [
        MethodKind::Eagl,
        MethodKind::Alps,
        MethodKind::FirstToLast,
        MethodKind::LastToFirst,
    ];
    let budget = 0.75; // "less than 4 bits on average"
    let records = co.sweep(&kinds, &[budget], &[0], &mut store)?;

    let mut rows = Vec::new();
    for r in &records {
        rows.push(SummaryRow {
            method: format!("{} 4/2", r.method),
            metric_drop: ref_f1 - r.metric,
            ref_metric: ref_f1,
            mp_metric: r.metric,
            compression: r.compression,
            gbops: r.gbops,
        });
    }
    rows.sort_by(|a, b| a.metric_drop.partial_cmp(&b.metric_drop).unwrap());
    println!("{}", summary_table(&rows, "F1"));
    println!("W-bits/A-bits = 4/2 mixed (shared per layer, §3.4.1); span head fixed 8-bit.");
    Ok(())
}
