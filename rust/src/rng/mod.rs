//! Deterministic RNG substrate (offline environment — no `rand` crate).
//!
//! [`Pcg32`] (PCG-XSH-RR 64/32, O'Neill 2014) is the workhorse: small
//! state, excellent statistical quality for simulation workloads, and
//! streams let every (experiment, seed, layer) tuple derive an independent
//! generator so results are reproducible regardless of execution order.

/// SplitMix64 — used to seed PCG streams from small integers.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MUL: u64 = 6_364_136_223_846_793_005;

    /// Generator from a (seed, stream) pair; distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-layer / per-worker
    /// streams).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let seed = splitmix64(&mut s);
        let stream = splitmix64(&mut s);
        Pcg32::new(seed, stream)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in [0, n) (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let lo = m as u32;
            if lo >= n {
                return (m >> 32) as u32;
            }
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Rademacher (+1 / -1 with equal probability).
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from 0..n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 1);
    }

    #[test]
    fn uniform_in_range_and_centered() {
        let mut rng = Pcg32::new(1, 1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(3, 9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Pcg32::new(5, 5);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(9, 1);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Pcg32::new(11, 2);
        let picks = rng.choose_k(50, 10);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(picks.iter().all(|&i| i < 50));
    }
}
