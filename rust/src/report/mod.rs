//! Report generation: regenerates the paper's tables and figures as
//! aligned text tables, ASCII frontier plots, and CSV files.

use std::collections::BTreeMap;

use crate::bench::fmt_s;
use crate::coordinator::RunRecord;
use crate::graph::Graph;
use crate::quant::BitsConfig;
use crate::serve::{LoadReport, MetricsSnapshot};
use crate::stats;

/// Mean ± std of the metric for each (method, budget) cell.
#[derive(Debug, Clone)]
pub struct FrontierCell {
    pub method: String,
    pub budget_frac: f64,
    pub mean: f64,
    pub std: f64,
    pub n: usize,
    pub samples: Vec<f64>,
}

/// Total-order sortable key for an f64 (IEEE-754 bit flip): preserves
/// numeric order including negatives, and distinct bit patterns stay
/// distinct.  Frontier cells are grouped on this key so the exact budget
/// survives — the old `format!("{:.4}")` → `parse()` round-trip both
/// lost precision and merged budgets that only agreed to 4 decimals.
fn f64_order_key(f: f64) -> u64 {
    let b = f.to_bits();
    if b & (1 << 63) != 0 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Aggregate raw run records into frontier cells.
pub fn frontier(records: &[RunRecord]) -> Vec<FrontierCell> {
    let mut cells: BTreeMap<(String, u64), (f64, Vec<f64>)> = BTreeMap::new();
    for r in records {
        cells
            .entry((r.method.clone(), f64_order_key(r.budget_frac)))
            .or_insert_with(|| (r.budget_frac, Vec::new()))
            .1
            .push(r.metric);
    }
    let mut out = Vec::new();
    for ((method, _), (budget_frac, samples)) in cells {
        out.push(FrontierCell {
            method,
            budget_frac,
            mean: stats::mean(&samples),
            std: stats::std_dev(&samples),
            n: samples.len(),
            samples,
        });
    }
    out
}

/// Sorted, deduplicated method names present in a cell set — the basis
/// for deriving significance pairs from the data actually in the store.
pub fn methods_in(cells: &[FrontierCell]) -> Vec<String> {
    let mut methods: Vec<String> = cells.iter().map(|c| c.method.clone()).collect();
    methods.sort();
    methods.dedup();
    methods
}

/// All unordered method pairs present in a cell set, for Wilcoxon
/// comparisons (replaces the old hardcoded three pairs, which silently
/// reported nothing for sweeps that ran other method sets).
pub fn method_pairs(cells: &[FrontierCell]) -> Vec<(String, String)> {
    let methods = methods_in(cells);
    let mut out = Vec::new();
    for i in 0..methods.len() {
        for j in (i + 1)..methods.len() {
            out.push((methods[i].clone(), methods[j].clone()));
        }
    }
    out
}

/// The frontier table (Fig. 3/4/5 data): rows = budgets, cols = methods.
pub fn frontier_table(cells: &[FrontierCell], metric_name: &str) -> String {
    let mut methods: Vec<String> = cells.iter().map(|c| c.method.clone()).collect();
    methods.sort();
    methods.dedup();
    let mut budgets: Vec<f64> = cells.iter().map(|c| c.budget_frac).collect();
    budgets.sort_by(|a, b| b.partial_cmp(a).unwrap());
    budgets.dedup();
    let mut s = format!("{:>8} |", "budget");
    for m in &methods {
        s += &format!(" {:>21} |", m);
    }
    s += &format!("   ({metric_name}, mean ± std)\n");
    s += &format!("{}\n", "-".repeat(10 + 25 * methods.len()));
    for &b in &budgets {
        s += &format!("{:>7.0}% |", b * 100.0);
        for m in &methods {
            match cells
                .iter()
                .find(|c| c.method == *m && (c.budget_frac - b).abs() < 1e-9)
            {
                Some(c) => s += &format!(" {:>9.4} ± {:<9.4} |", c.mean, c.std),
                None => s += &format!(" {:>21} |", "-"),
            }
        }
        s.push('\n');
    }
    s
}

/// ASCII frontier plot: budget (x) vs metric (y), one glyph per method.
pub fn frontier_plot(cells: &[FrontierCell], width: usize, height: usize) -> String {
    if cells.is_empty() {
        return "(no data)\n".to_string();
    }
    let mut methods: Vec<String> = cells.iter().map(|c| c.method.clone()).collect();
    methods.sort();
    methods.dedup();
    let glyphs = ['E', 'A', 'H', 'U', 'F', 'L', 'O', '*', '+', 'x'];
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    for c in cells {
        ymin = ymin.min(c.mean);
        ymax = ymax.max(c.mean);
        xmin = xmin.min(c.budget_frac);
        xmax = xmax.max(c.budget_frac);
    }
    let ypad = ((ymax - ymin) * 0.1).max(1e-6);
    ymin -= ypad;
    ymax += ypad;
    let xspan = (xmax - xmin).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for c in cells {
        let x = ((c.budget_frac - xmin) / xspan * (width - 1) as f64).round() as usize;
        let y = ((c.mean - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
        let gi = methods.iter().position(|m| *m == c.method).unwrap();
        grid[height - 1 - y][x.min(width - 1)] = glyphs[gi % glyphs.len()];
    }
    let mut s = String::new();
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        s += &format!("{:>8.4} |", yval);
        s.extend(row.iter());
        s.push('\n');
    }
    s += &format!("{:>9}+{}\n", "", "-".repeat(width));
    s += &format!(
        "{:>9} {:.0}%{}{:.0}%  (budget)\n",
        "",
        xmin * 100.0,
        " ".repeat(width.saturating_sub(8)),
        xmax * 100.0
    );
    s += "legend: ";
    for (i, m) in methods.iter().enumerate() {
        s += &format!("{}={} ", glyphs[i % glyphs.len()], m);
    }
    s.push('\n');
    s
}

/// Wilcoxon rank-sum comparison of two methods at each budget (the paper's
/// significance protocol, §4.1).
pub fn significance(
    cells: &[FrontierCell],
    method_a: &str,
    method_b: &str,
) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    let mut budgets: Vec<f64> = cells.iter().map(|c| c.budget_frac).collect();
    budgets.sort_by(|a, b| a.partial_cmp(b).unwrap());
    budgets.dedup();
    for b in budgets {
        let a = cells
            .iter()
            .find(|c| c.method == method_a && (c.budget_frac - b).abs() < 1e-9);
        let bb = cells
            .iter()
            .find(|c| c.method == method_b && (c.budget_frac - b).abs() < 1e-9);
        if let (Some(ca), Some(cb)) = (a, bb) {
            if ca.samples.len() > 1 && cb.samples.len() > 1 {
                let (_, p) = stats::ranksum(&ca.samples, &cb.samples);
                out.push((b, p));
            }
        }
    }
    out
}

/// Table 1/2 style summary row.
#[derive(Debug, Clone)]
pub struct SummaryRow {
    pub method: String,
    pub metric_drop: f64,
    pub ref_metric: f64,
    pub mp_metric: f64,
    pub compression: f64,
    pub gbops: f64,
}

pub fn summary_table(rows: &[SummaryRow], metric_name: &str) -> String {
    let mut s = format!(
        "{:<15} {:>12} {:>20} {:>13} {:>10}\n",
        "method",
        format!("{metric_name} drop"),
        "(ref → mp)",
        "compression",
        "GBOPs"
    );
    s += &format!("{}\n", "-".repeat(75));
    for r in rows {
        s += &format!(
            "{:<15} {:>12.4} {:>9.4} → {:<8.4} {:>12.2}x {:>10.4}\n",
            r.method, r.metric_drop, r.ref_metric, r.mp_metric, r.compression, r.gbops
        );
    }
    s
}

/// Fig. 9: per-layer precision choice map, one row per method.
pub fn layer_selection_map(graph: &Graph, choices: &[(String, BitsConfig)]) -> String {
    let mut s = String::new();
    let sel_layers: Vec<usize> = graph
        .layers
        .iter()
        .filter(|l| l.fixed_bits.is_none())
        .map(|l| l.qindex)
        .collect();
    s += &format!("layers (topological, {} selectable): ", sel_layers.len());
    s += "each column is one layer; '4' = kept at 4-bit, '2' = dropped to 2-bit\n\n";
    for (name, bits) in choices {
        let row: String = sel_layers
            .iter()
            .map(|&qi| match bits.bits[qi] {
                2 => '2',
                4 => '4',
                _ => '?',
            })
            .collect();
        s += &format!("{:<15} {}\n", name, row);
    }
    s.push('\n');
    s += "layer names: ";
    s += &graph
        .layers
        .iter()
        .filter(|l| l.fixed_bits.is_none())
        .map(|l| l.name.clone())
        .collect::<Vec<_>>()
        .join(", ");
    s.push('\n');
    s
}

/// Write frontier cells as CSV (figure source data).
pub fn write_csv(cells: &[FrontierCell], path: &std::path::Path) -> crate::Result<()> {
    let mut s = String::from("method,budget_frac,mean,std,n\n");
    for c in cells {
        s += &format!(
            "{},{},{},{},{}\n",
            c.method, c.budget_frac, c.mean, c.std, c.n
        );
    }
    std::fs::write(path, s)?;
    Ok(())
}

/// Serving summary for one load run: throughput, the latency
/// percentiles from the engine's histogram, and batching efficiency
/// (`mpq serve` prints this; `make serve-smoke` exercises it).
pub fn serve_table(snap: &MetricsSnapshot, load: &LoadReport) -> String {
    let mut s = String::new();
    s += &format!(
        "requests   {:>8} ok, {} failed   samples {:>8}   wall {:.2}s\n",
        snap.completed, snap.failed, load.total_samples, load.wall_s
    );
    s += &format!(
        "throughput {:>8.1} req/s   {:>8.1} samples/s\n",
        load.throughput_rps, load.samples_per_s
    );
    s += &format!(
        "latency    mean {}  p50 {}  p95 {}  p99 {}  max {}\n",
        fmt_s(snap.mean_latency_s),
        fmt_s(snap.p50_s),
        fmt_s(snap.p95_s),
        fmt_s(snap.p99_s),
        fmt_s(snap.max_latency_s)
    );
    s += &format!(
        "batches    {:>8}   occupancy {:.2} samples/batch   {:.2} chunks/batch\n",
        snap.batches,
        snap.mean_occupancy(),
        if snap.batches > 0 {
            snap.batch_chunks as f64 / snap.batches as f64
        } else {
            f64::NAN
        }
    );
    if load.retried > 0 {
        s += &format!("retries    {:>8} (503 sheds retried after backoff)\n", load.retried);
    }
    if load.mean_accuracy.is_finite() {
        s += &format!("accuracy   {:>8.4} (sample-weighted)\n", load.mean_accuracy);
    }
    s
}

/// Cross-model overview (the `mpq exp` / multi-model `mpq report`
/// summary): for every (model, method), the cell count, budget range, and
/// the best frontier point.
pub fn cross_model_table(per_model: &[(String, Vec<FrontierCell>)]) -> String {
    let mut s = format!(
        "{:<12} {:<15} {:>6} {:>15} {:>12} {:>8}\n",
        "model", "method", "cells", "budgets", "best mean", "at"
    );
    s += &format!("{}\n", "-".repeat(74));
    for (model, cells) in per_model {
        for method in methods_in(cells) {
            let mine: Vec<&FrontierCell> = cells.iter().filter(|c| c.method == method).collect();
            let lo = mine.iter().map(|c| c.budget_frac).fold(f64::INFINITY, f64::min);
            let hi = mine.iter().map(|c| c.budget_frac).fold(f64::NEG_INFINITY, f64::max);
            // total_cmp: a NaN mean (diverged fine-tune) must not panic
            // the summary after an hours-long sweep already succeeded.
            let best = mine
                .iter()
                .max_by(|a, b| a.mean.total_cmp(&b.mean))
                .unwrap();
            s += &format!(
                "{:<12} {:<15} {:>6} {:>6.0}%–{:>4.0}%{:>4} {:>12.4} {:>7.0}%\n",
                model,
                method,
                mine.len(),
                lo * 100.0,
                hi * 100.0,
                "",
                best.mean,
                best.budget_frac * 100.0
            );
        }
    }
    s
}

/// Multi-model frontier CSV (`model` as the leading column).
pub fn write_csv_multi(
    per_model: &[(String, Vec<FrontierCell>)],
    path: &std::path::Path,
) -> crate::Result<()> {
    let mut s = String::from("model,method,budget_frac,mean,std,n\n");
    for (model, cells) in per_model {
        for c in cells {
            s += &format!(
                "{},{},{},{},{},{}\n",
                model, c.method, c.budget_frac, c.mean, c.std, c.n
            );
        }
    }
    std::fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(method: &str, frac: f64, seed: u64, metric: f64) -> RunRecord {
        RunRecord {
            model: "m".into(),
            method: method.into(),
            budget_frac: frac,
            seed,
            metric,
            loss: 0.0,
            groups_at_lo: 0,
            compression: 10.0,
            gbops: 1.0,
            wall_s: 1.0,
        }
    }

    #[test]
    fn frontier_aggregates_seeds() {
        let records = vec![
            rec("eagl", 0.7, 0, 0.90),
            rec("eagl", 0.7, 1, 0.92),
            rec("alps", 0.7, 0, 0.91),
        ];
        let cells = frontier(&records);
        assert_eq!(cells.len(), 2);
        let eagl = cells.iter().find(|c| c.method == "eagl").unwrap();
        assert_eq!(eagl.n, 2);
        assert!((eagl.mean - 0.91).abs() < 1e-12);
    }

    #[test]
    fn tables_render() {
        let records = vec![
            rec("eagl", 0.9, 0, 0.95),
            rec("eagl", 0.6, 0, 0.90),
            rec("hawq_v3", 0.9, 0, 0.94),
            rec("hawq_v3", 0.6, 0, 0.88),
        ];
        let cells = frontier(&records);
        let tbl = frontier_table(&cells, "accuracy");
        assert!(tbl.contains("eagl"));
        assert!(tbl.contains("90%"));
        let plot = frontier_plot(&cells, 40, 10);
        assert!(plot.contains("legend"));
    }

    #[test]
    fn frontier_keeps_exact_budgets_distinct() {
        // Two budgets equal to 4 decimals but different f64s: the old
        // {:.4} key merged them into one cell; the bit key must not.
        let b1 = 0.7;
        let b2 = 0.7 + 1e-9;
        let records = vec![rec("eagl", b1, 0, 0.90), rec("eagl", b2, 0, 0.80)];
        let cells = frontier(&records);
        assert_eq!(cells.len(), 2);
        // And the surviving budget is the exact input value, not a
        // parse("0.7000") round-trip.
        assert!(cells.iter().any(|c| c.budget_frac.to_bits() == b1.to_bits()));
        assert!(cells.iter().any(|c| c.budget_frac.to_bits() == b2.to_bits()));
        // Cells keep ascending budget order within a method.
        assert!(cells[0].budget_frac < cells[1].budget_frac);
    }

    #[test]
    fn f64_order_key_is_monotone() {
        let vals = [-2.5, -0.0, 0.0, 1e-300, 0.5999, 0.6, 0.9, 1.0];
        for w in vals.windows(2) {
            assert!(
                f64_order_key(w[0]) <= f64_order_key(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
        assert!(f64_order_key(0.6) < f64_order_key(0.9));
    }

    #[test]
    fn method_pairs_derived_from_cells() {
        let records = vec![
            rec("eagl", 0.7, 0, 0.9),
            rec("alps", 0.7, 0, 0.9),
            rec("uniform", 0.7, 0, 0.8),
        ];
        let cells = frontier(&records);
        assert_eq!(methods_in(&cells), vec!["alps", "eagl", "uniform"]);
        let pairs = method_pairs(&cells);
        assert_eq!(pairs.len(), 3);
        assert!(pairs.contains(&("alps".into(), "eagl".into())));
        assert!(pairs.contains(&("eagl".into(), "uniform".into())));
    }

    #[test]
    fn cross_model_table_renders_every_model() {
        let cells_a = frontier(&[rec("eagl", 0.9, 0, 0.95), rec("eagl", 0.6, 0, 0.90)]);
        let cells_b = frontier(&[rec("uniform", 0.9, 0, 0.80)]);
        let per_model = vec![("tiny".to_string(), cells_a), ("skew".to_string(), cells_b)];
        let tbl = cross_model_table(&per_model);
        assert!(tbl.contains("tiny"), "{tbl}");
        assert!(tbl.contains("skew"), "{tbl}");
        assert!(tbl.contains("eagl"), "{tbl}");
        assert!(tbl.contains("0.9500"), "{tbl}");
    }

    #[test]
    fn significance_needs_replicates() {
        let mut records = Vec::new();
        for s in 0..5 {
            records.push(rec("eagl", 0.7, s, 0.92 + s as f64 * 1e-4));
            records.push(rec("hawq_v3", 0.7, s, 0.85 + s as f64 * 1e-4));
        }
        let cells = frontier(&records);
        let sig = significance(&cells, "eagl", "hawq_v3");
        assert_eq!(sig.len(), 1);
        // Fully separated 5v5 → exact p = 0.0079.
        assert!((sig[0].1 - 2.0 / 252.0).abs() < 1e-6);
    }
}
