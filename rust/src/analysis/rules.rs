//! The `mpq lint` rule set (see [`crate::analysis`] for the engine and
//! rust/README.md §Static analysis for the catalog).
//!
//! Every rule encodes an invariant the repo already enforces by
//! convention and regression test; the rules make the conventions
//! machine-checked.  Rules scan the *blanked* text from
//! [`super::lex`], so literal contents and comment prose can never
//! trip them, and they skip test regions — test code is allowed to
//! panic, print, and read clocks.

use super::lex::Lexed;

/// One diagnostic.  `file` is the scan-root-relative path with forward
/// slashes; `line` is 1-indexed; `excerpt` is the trimmed original
/// source line (waivers match on it by substring).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub note: String,
}

/// The rule names, sorted — pinned into the JSON report so an
/// accidentally emptied rule set is loudly visible (and gated in the
/// Makefile with the same guard pattern as `bench-quick`).
pub const RULES: &[&str] = &[
    "fail-closed-flags",
    "float-reassoc",
    "hot-path-panic",
    "relaxed-audit",
    "stdout-discipline",
    "wall-clock",
];

/// Per-file input to the rules.
pub struct FileCtx<'a> {
    pub rel: &'a str,
    pub raw: &'a str,
    pub lexed: &'a Lexed,
}

/// Run every rule over one file.
pub fn check_file(ctx: &FileCtx, out: &mut Vec<Finding>) {
    wall_clock(ctx, out);
    relaxed_audit(ctx, out);
    hot_path_panic(ctx, out);
    float_reassoc(ctx, out);
    stdout_discipline(ctx, out);
    fail_closed_flags(ctx, out);
}

fn push(out: &mut Vec<Finding>, ctx: &FileCtx, rule: &'static str, line: usize, note: String) {
    let excerpt = ctx
        .raw
        .split('\n')
        .nth(line.saturating_sub(1))
        .unwrap_or("")
        .trim()
        .to_string();
    out.push(Finding { rule, file: ctx.rel.to_string(), line, excerpt, note });
}

/// Is the byte before `pos` something that could extend an identifier?
/// Used to keep `println!` from matching inside `eprintln!` and
/// `panic!` inside `sim_panic!`.
fn ident_before(code: &str, pos: usize) -> bool {
    pos > 0
        && matches!(code.as_bytes()[pos - 1], b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_')
}

fn line_of(code: &str, pos: usize) -> usize {
    code.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count()
}

// ---------------------------------------------------------------------------
// wall-clock
// ---------------------------------------------------------------------------

/// Modules whose outputs are contractually byte-identical across
/// reruns/workers/kernels: no wall-clock reads at all.
const WALL_CLOCK_FILES: &[&str] = &["serve/controller.rs"];
const WALL_CLOCK_DIRS: &[&str] = &["experiment/", "rng/", "jsonio/"];

/// In the loadgen, only the *content generation* functions are
/// deterministic (pacing legitimately reads the clock), so the rule is
/// function-scoped there.
const LOADGEN_CONTENT_FNS: &[&str] = &[
    "request_sizes",
    "request_index",
    "request_set",
    "infer_body",
    "latency_jsonl",
    "finalize",
    "hits",
    "stalls",
    "stall_wall_for",
    "sim_extra_work",
];

fn wall_clock(ctx: &FileCtx, out: &mut Vec<Finding>) {
    let whole_file = WALL_CLOCK_FILES.contains(&ctx.rel)
        || WALL_CLOCK_DIRS.iter().any(|d| ctx.rel.starts_with(d));
    let fn_scoped = ctx.rel == "serve/loadgen.rs";
    if !whole_file && !fn_scoped {
        return;
    }
    for (ln0, lt) in ctx.lexed.code.split('\n').enumerate() {
        if ctx.lexed.in_test[ln0] {
            continue;
        }
        if !(lt.contains("Instant::now") || lt.contains("SystemTime::now")) {
            continue;
        }
        if fn_scoped {
            let names = ctx.lexed.fn_names_at(ln0 + 1);
            if !names.iter().any(|n| LOADGEN_CONTENT_FNS.contains(n)) {
                continue;
            }
        }
        push(
            out,
            ctx,
            "wall-clock",
            ln0 + 1,
            "wall-clock read in a deterministic module (outputs are contractually \
             byte-identical across reruns/workers/kernels)"
                .to_string(),
        );
    }
}

// ---------------------------------------------------------------------------
// relaxed-audit
// ---------------------------------------------------------------------------

fn relaxed_audit(ctx: &FileCtx, out: &mut Vec<Finding>) {
    let lines: Vec<&str> = ctx.lexed.code.split('\n').collect();
    for ln0 in 0..lines.len() {
        if !lines[ln0].contains("Ordering::Relaxed") || ctx.lexed.in_test[ln0] {
            continue;
        }
        if relaxed_justified(ctx.lexed, &lines, ln0) {
            continue;
        }
        push(
            out,
            ctx,
            "relaxed-audit",
            ln0 + 1,
            "Ordering::Relaxed without a `// relaxed-ok: <why>` justification on the \
             same line or the comment lines directly above"
                .to_string(),
        );
    }
}

/// Same line, or any comment-only/blank line walking straight up.
fn relaxed_justified(lexed: &Lexed, lines: &[&str], ln0: usize) -> bool {
    if lexed.relaxed_ok[ln0] {
        return true;
    }
    let mut j = ln0;
    while j > 0 {
        j -= 1;
        if lines[j].trim().is_empty() {
            if lexed.relaxed_ok[j] {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

// ---------------------------------------------------------------------------
// hot-path-panic
// ---------------------------------------------------------------------------

/// Receiver methods whose `Result` is only `Err` on a panic elsewhere:
/// the mutex/condvar/join poison idiom.  `x.lock().unwrap()` is the
/// repo's standard form — propagating poison would just turn one panic
/// into a cascade — so these receivers are exempt by construction.
const POISON_RECEIVERS: &[&str] = &[
    "lock",
    "wait",
    "wait_timeout",
    "wait_while",
    "into_inner",
    "join",
    "read",
    "write",
    "get_mut",
];

fn hot_path_panic(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !(ctx.rel.starts_with("serve/") || ctx.rel.starts_with("kernels/")) {
        return;
    }
    let code = ctx.lexed.code.as_str();
    for pat in ["panic!", "todo!", "unimplemented!", "debug_assert"] {
        for (pos, _) in code.match_indices(pat) {
            if ident_before(code, pos) {
                continue;
            }
            let ln0 = line_of(code, pos);
            if ctx.lexed.in_test[ln0] {
                continue;
            }
            push(
                out,
                ctx,
                "hot-path-panic",
                ln0 + 1,
                format!(
                    "`{pat}` in non-test serve/kernels code: a panic in a worker \
                     strands in-flight tickets — return an error instead"
                ),
            );
        }
    }
    for pat in [".unwrap()", ".expect("] {
        for (pos, _) in code.match_indices(pat) {
            let ln0 = line_of(code, pos);
            if ctx.lexed.in_test[ln0] {
                continue;
            }
            if let Some(recv) = call_receiver(code, pos) {
                if POISON_RECEIVERS.contains(&recv.as_str()) {
                    continue;
                }
            }
            push(
                out,
                ctx,
                "hot-path-panic",
                ln0 + 1,
                format!(
                    "`{pat}…` in non-test serve/kernels code (poison-idiom receivers \
                     like .lock()/.join() are exempt): return an error or waive with \
                     an infallibility proof"
                ),
            );
        }
    }
}

/// For `…method(args).unwrap()` with the `.` at `dot`, the name of the
/// method call directly feeding it — `None` when the receiver is a
/// plain variable/field (`s.expect(…)`).
fn call_receiver(code: &str, dot: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut i = dot;
    while i > 0 && (b[i - 1] as char).is_whitespace() {
        i -= 1;
    }
    if i == 0 || b[i - 1] != b')' {
        return None;
    }
    // Balance backward over the argument list (literals are blanked, so
    // parens inside strings cannot confuse the count).
    let mut depth = 1usize;
    i -= 1;
    while i > 0 && depth > 0 {
        i -= 1;
        match b[i] {
            b')' => depth += 1,
            b'(' => depth -= 1,
            _ => {}
        }
    }
    if depth != 0 {
        return None;
    }
    while i > 0 && (b[i - 1] as char).is_whitespace() {
        i -= 1;
    }
    let end = i;
    while i > 0 && matches!(b[i - 1], b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_') {
        i -= 1;
    }
    if i == end {
        return None;
    }
    Some(code[i..end].to_string())
}

// ---------------------------------------------------------------------------
// float-reassoc
// ---------------------------------------------------------------------------

/// The ε=0 kernel modules: reference/packed GEMM must accumulate in
/// the pinned order (bit-identity contract), so iterator reductions —
/// which invite reassociation under future refactors — are banned
/// outright; integer reductions get waivers with a one-line proof.
const REASSOC_FILES: &[&str] = &["kernels/gemm.rs", "kernels/packed.rs"];

fn float_reassoc(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !REASSOC_FILES.contains(&ctx.rel) {
        return;
    }
    for (ln0, lt) in ctx.lexed.code.split('\n').enumerate() {
        if ctx.lexed.in_test[ln0] {
            continue;
        }
        if !(lt.contains(".sum(") || lt.contains(".sum::<") || lt.contains(".fold(")) {
            continue;
        }
        push(
            out,
            ctx,
            "float-reassoc",
            ln0 + 1,
            "iterator reduction in an ε=0 kernel module — accumulation order is \
             contractual; use the explicit loop form, or waive integer reductions \
             with a proof"
                .to_string(),
        );
    }
}

// ---------------------------------------------------------------------------
// stdout-discipline
// ---------------------------------------------------------------------------

fn stdout_discipline(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.rel == "main.rs" || ctx.rel.starts_with("cli/") {
        return;
    }
    let code = ctx.lexed.code.as_str();
    for pat in ["println!", "print!"] {
        for (pos, _) in code.match_indices(pat) {
            // Skip `eprintln!`/`eprint!` (stderr is fine everywhere).
            if ident_before(code, pos) {
                continue;
            }
            let ln0 = line_of(code, pos);
            if ctx.lexed.in_test[ln0] {
                continue;
            }
            push(
                out,
                ctx,
                "stdout-discipline",
                ln0 + 1,
                "stdout belongs to main.rs/cli/ (machine-readable output and the \
                 Makefile gate lines) — use the crate::info!/warn!/debug! logging \
                 macros here"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// fail-closed-flags
// ---------------------------------------------------------------------------

/// Every subcommand dispatched in `run()`'s `match` must be named in
/// `validate_flags()`, which must itself call `ensure_known_flags` —
/// otherwise a new subcommand silently accepts misspelled flags (the
/// exact failure mode `ensure_known_flags` exists to prevent).  This
/// rule reads the *raw* source: the dispatch names live in string
/// literals the lexer blanks.
fn fail_closed_flags(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.rel != "main.rs" {
        return;
    }
    // Dispatch arms: lines shaped like `Some("name") => …`.
    let mut dispatched: Vec<(String, usize)> = Vec::new();
    for (ln0, lt) in ctx.raw.split('\n').enumerate() {
        if ctx.lexed.in_test.get(ln0).copied().unwrap_or(false) {
            continue;
        }
        if !lt.contains("=>") {
            continue;
        }
        let mut rest = lt;
        while let Some(start) = rest.find("Some(\"") {
            let after = &rest[start + 6..];
            let Some(end) = after.find('"') else { break };
            dispatched.push((after[..end].to_string(), ln0 + 1));
            rest = &after[end..];
        }
    }
    if dispatched.is_empty() {
        return;
    }
    // validate_flags body: from its `fn` line to the next top-level item.
    let raw_lines: Vec<&str> = ctx.raw.split('\n').collect();
    let Some(vf_start) = raw_lines.iter().position(|l| l.contains("fn validate_flags"))
    else {
        push(
            out,
            ctx,
            "fail-closed-flags",
            dispatched[0].1,
            "subcommands are dispatched but there is no validate_flags() gate".to_string(),
        );
        return;
    };
    let vf_end = raw_lines[vf_start + 1..]
        .iter()
        .position(|l| l.starts_with("fn ") || l.starts_with("const ") || l.starts_with("pub fn "))
        .map(|off| vf_start + 1 + off)
        .unwrap_or(raw_lines.len());
    let body = raw_lines[vf_start..vf_end].join("\n");
    if !body.contains("ensure_known_flags") {
        push(
            out,
            ctx,
            "fail-closed-flags",
            vf_start + 1,
            "validate_flags() never reaches ensure_known_flags".to_string(),
        );
        return;
    }
    // Quoted names inside the body (flag names too — a harmless
    // superset; only the subcommand names are looked up).
    let quoted: Vec<&str> = body.split('"').skip(1).step_by(2).collect();
    for (name, line) in dispatched {
        if !quoted.contains(&name.as_str()) {
            push(
                out,
                ctx,
                "fail-closed-flags",
                line,
                format!(
                    "subcommand '{name}' is dispatched in run() but never validated in \
                     validate_flags() — unknown flags would be silently accepted"
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let lexed = lex::lex(src);
        let ctx = FileCtx { rel, raw: src, lexed: &lexed };
        let mut out = Vec::new();
        check_file(&ctx, &mut out);
        out
    }

    fn rules_of(fs: &[Finding]) -> Vec<&str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn wall_clock_fires_in_deterministic_modules_only() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules_of(&findings("serve/controller.rs", src)), vec!["wall-clock"]);
        assert_eq!(rules_of(&findings("experiment/schedule.rs", src)), vec!["wall-clock"]);
        assert!(findings("serve/engine.rs", src)
            .iter()
            .all(|f| f.rule != "wall-clock"));
    }

    #[test]
    fn wall_clock_ignores_strings_comments_and_tests() {
        let in_str = "fn f() { let s = \"Instant::now\"; } // Instant::now\n";
        assert!(findings("rng/mod.rs", in_str).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n  fn t() { let t = Instant::now(); }\n}\n";
        assert!(findings("rng/mod.rs", in_test).is_empty());
    }

    #[test]
    fn wall_clock_is_fn_scoped_in_loadgen() {
        let src = "fn request_sizes() { let t = Instant::now(); }\nfn pace() { let t = Instant::now(); }\n";
        let fs = findings("serve/loadgen.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn relaxed_audit_requires_justification() {
        let bare = "fn f() { c.load(Ordering::Relaxed); }\n";
        assert_eq!(rules_of(&findings("serve/metrics.rs", bare)), vec!["relaxed-audit"]);
        let same_line = "fn f() { c.load(Ordering::Relaxed); } // relaxed-ok: counter\n";
        assert!(findings("serve/metrics.rs", same_line).is_empty());
        let above = "fn f() {\n  // relaxed-ok: counter\n  c.load(Ordering::Relaxed);\n}\n";
        assert!(findings("serve/metrics.rs", above).is_empty());
        let detached = "fn f() {\n  // relaxed-ok: counter\n  other();\n  c.load(Ordering::Relaxed);\n}\n";
        assert_eq!(rules_of(&findings("serve/metrics.rs", detached)), vec!["relaxed-audit"]);
    }

    #[test]
    fn hot_path_panic_flags_unwrap_but_exempts_poison_idiom() {
        let bad = "fn f() { q.pop_front().unwrap(); }\n";
        assert_eq!(rules_of(&findings("serve/engine.rs", bad)), vec!["hot-path-panic"]);
        let poison = "fn f() { let g = self.q.lock().unwrap(); cv.wait(g).unwrap(); h.join().unwrap(); }\n";
        assert!(findings("serve/engine.rs", poison).is_empty());
        let multiline = "fn f() {\n  self.q\n    .lock()\n    .unwrap();\n}\n";
        assert!(findings("serve/engine.rs", multiline).is_empty());
    }

    #[test]
    fn hot_path_panic_flags_expect_on_plain_receivers() {
        // A method *named* expect on a local scanner type still matches
        // textually (waived in the real tree with a justification).
        let src = "fn f() { s.expect(b'x')?; }\n";
        assert_eq!(rules_of(&findings("serve/http/lazyjson.rs", src)), vec!["hot-path-panic"]);
    }

    #[test]
    fn hot_path_panic_flags_panics_and_debug_asserts_outside_tests() {
        let src = "fn f() { debug_assert_eq!(a, b); }\nfn g() { panic!(\"x\"); }\n";
        let fs = findings("kernels/packed.rs", src);
        assert_eq!(rules_of(&fs), vec!["hot-path-panic", "hot-path-panic"]);
        let test_only = "#[cfg(test)]\nmod tests {\n  fn t() { panic!(); x.unwrap(); }\n}\n";
        assert!(findings("serve/batcher.rs", test_only).is_empty());
        // Not a serve/kernels file: out of scope.
        assert!(findings("experiment/mod.rs", src).is_empty());
    }

    #[test]
    fn float_reassoc_flags_iterator_reductions_in_kernel_files() {
        let src = "fn f(d: &[f32]) -> f32 { d.iter().sum() }\n";
        assert_eq!(rules_of(&findings("kernels/gemm.rs", src)), vec!["float-reassoc"]);
        let turbofish = "fn f(d: &[f32]) -> f32 { d.iter().sum::<f32>() }\n";
        assert_eq!(rules_of(&findings("kernels/packed.rs", turbofish)), vec!["float-reassoc"]);
        let fold = "fn f(d: &[f32]) -> f32 { d.iter().fold(0.0, |a, b| a + b) }\n";
        assert_eq!(rules_of(&findings("kernels/gemm.rs", fold)), vec!["float-reassoc"]);
        // Explicit loop form is the sanctioned idiom.
        let explicit = "fn f(d: &[f32]) -> f32 { let mut a = 0.0; for &x in d { a += x; } a }\n";
        assert!(findings("kernels/gemm.rs", explicit).is_empty());
        // Other modules may reduce freely.
        assert!(findings("stats/mod.rs", src).is_empty());
    }

    #[test]
    fn stdout_discipline_allows_main_cli_eprintln_and_tests() {
        let src = "fn f() { println!(\"x\"); }\n";
        assert_eq!(rules_of(&findings("serve/engine.rs", src)), vec!["stdout-discipline"]);
        assert!(findings("main.rs", src).is_empty());
        assert!(findings("cli/mod.rs", src).is_empty());
        let stderr = "fn f() { eprintln!(\"x\"); eprint!(\"y\"); }\n";
        assert!(findings("serve/engine.rs", stderr).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n  fn t() { println!(\"dbg\"); }\n}\n";
        assert!(findings("serve/engine.rs", in_test).is_empty());
    }

    #[test]
    fn fail_closed_flags_catches_unvalidated_subcommands() {
        let ok = "fn validate_flags(args: &Args) -> R {\n    match sub {\n        \"run\" => {}\n    }\n    args.ensure_known_flags(sub, &[])\n}\nfn run() -> R {\n    match args.subcommand.as_deref() {\n        Some(\"run\") => cmd_run(),\n    }\n}\n";
        assert!(findings("main.rs", ok).is_empty());
        let ghost = ok.replace("Some(\"run\")", "Some(\"ghost\")");
        let fs = findings("main.rs", &ghost);
        assert_eq!(rules_of(&fs), vec!["fail-closed-flags"]);
        assert!(fs[0].note.contains("ghost"));
        let no_gate = ok.replace("args.ensure_known_flags(sub, &[])", "Ok(())");
        assert_eq!(rules_of(&findings("main.rs", &no_gate)), vec!["fail-closed-flags"]);
    }

    #[test]
    fn rule_names_are_sorted_and_nonempty() {
        assert!(!RULES.is_empty());
        let mut sorted = RULES.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, RULES);
    }
}
