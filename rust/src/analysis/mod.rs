//! `mpq lint` — repo-aware static analysis for the serving stack.
//!
//! The repo's load-bearing invariants (bit-identical packed/reference
//! kernels, byte-identical decision/JSONL logs, wall-clock-free
//! deterministic modules, fail-closed flag parsing, justified
//! relaxed-atomic telemetry, panic-free request paths) were enforced by
//! convention and regression test for nine PRs; this pass makes them
//! machine-checked.  Zero new dependencies: a small lexer
//! ([`lex`]) blanks comments/literals while preserving line numbers,
//! and a textual rule engine ([`rules`]) runs six rules over the
//! blanked source with per-rule `file:line` diagnostics.
//!
//! Exceptions live in one explicit allowlist, `rust/lint-waivers.json`,
//! parsed fail-closed via [`crate::jsonio`] (unknown keys are errors
//! with a key path, every waiver needs a non-empty `why`, and a waiver
//! that matches no finding is itself an error — stale waivers cannot
//! accumulate).  The CLI (`mpq lint [--root DIR] [--json]
//! [--waivers F]`) pins exit codes: 0 clean, 1 findings, 2 config
//! error; `make lint` wires it into `make verify`, and the pass is
//! self-hosting (it lints its own source).

pub mod lex;
pub mod rules;

use std::path::{Path, PathBuf};

use crate::jsonio::Json;
pub use rules::{Finding, RULES};

/// One allowlist entry: suppresses findings of `rule` in `file` whose
/// source line contains `contains`.  Matching by substring rather than
/// line number keeps waivers robust to unrelated edits above them; the
/// mandatory `why` is the reviewable justification.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub file: String,
    pub contains: String,
    pub why: String,
}

/// The outcome of a lint run over one tree.
#[derive(Debug)]
pub struct Report {
    /// Unwaived findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings suppressed by waivers.
    pub waived: usize,
    pub files_scanned: usize,
}

impl Report {
    /// The pinned machine-readable report (format version 1; keys are
    /// emitted sorted by `to_string_compact`, so the byte form is
    /// deterministic and golden-tested).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("rules", Json::arr(RULES.iter().map(|r| Json::str(r)))),
            ("waived", Json::num(self.waived as f64)),
            (
                "findings",
                Json::arr(self.findings.iter().map(|f| {
                    Json::obj(vec![
                        ("rule", Json::str(f.rule)),
                        ("file", Json::str(&f.file)),
                        ("line", Json::num(f.line as f64)),
                        ("excerpt", Json::str(&f.excerpt)),
                        ("note", Json::str(&f.note)),
                    ])
                })),
            ),
        ])
    }

    /// Human-readable rendering (stdout of `mpq lint` without `--json`).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{} [{}] {}\n    {}\n", f.file, f.line, f.rule, f.note, f.excerpt));
        }
        if self.findings.is_empty() {
            s.push_str(&format!(
                "lint OK ({} files, {} rules, {} waived)\n",
                self.files_scanned,
                RULES.len(),
                self.waived
            ));
        } else {
            s.push_str(&format!(
                "lint: {} finding(s) across {} files ({} waived)\n",
                self.findings.len(),
                self.files_scanned,
                self.waived
            ));
        }
        s
    }
}

/// Lint `root`, discovering the waiver file as `<root>/lint-waivers.json`
/// or `<root>/../lint-waivers.json` (the repo layout: sources in
/// `rust/src`, waivers in `rust/`).  Missing waiver file = no waivers.
pub fn run(root: &Path) -> crate::Result<Report> {
    let candidates = [
        root.join("lint-waivers.json"),
        root.join("..").join("lint-waivers.json"),
    ];
    let waivers = candidates.iter().find(|p| p.is_file());
    run_with(root, waivers.map(|p| p.as_path()))
}

/// Lint `root` with an explicit waiver file (or none).  `Err` is a
/// configuration error (exit 2 at the CLI); findings are data, not
/// errors — inspect [`Report::findings`].
pub fn run_with(root: &Path, waivers_path: Option<&Path>) -> crate::Result<Report> {
    // Loud-empty guard: an accidentally emptied rule table must never
    // read as "everything passes" (same failure mode the bench-quick
    // empty-record guard closes).
    crate::ensure!(!RULES.is_empty(), "lint: empty rule set");
    let waivers = match waivers_path {
        Some(p) => load_waivers(p)?,
        None => Vec::new(),
    };
    let files = walk(root)?;
    crate::ensure!(
        !files.is_empty(),
        "lint: no .rs files under {} — wrong --root?",
        root.display()
    );
    let mut all: Vec<Finding> = Vec::new();
    for (rel, path) in &files {
        let raw = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("lint: reading {}: {e}", path.display()))?;
        let lexed = lex::lex(&raw);
        rules::check_file(&rules::FileCtx { rel, raw: &raw, lexed: &lexed }, &mut all);
    }
    let mut matched = vec![false; waivers.len()];
    let mut kept = Vec::new();
    let mut waived = 0usize;
    for f in all {
        let mut hit = false;
        for (wi, w) in waivers.iter().enumerate() {
            if w.rule == f.rule && w.file == f.file && f.excerpt.contains(&w.contains) {
                matched[wi] = true;
                hit = true;
            }
        }
        if hit {
            waived += 1;
        } else {
            kept.push(f);
        }
    }
    // Fail closed on stale waivers: an allowlist entry that no longer
    // matches anything is dead weight that would silently re-admit the
    // pattern it once excused.
    for (wi, w) in waivers.iter().enumerate() {
        crate::ensure!(
            matched[wi],
            "lint: stale waiver (rule '{}', file '{}', contains {:?}) matches no \
             finding — delete it or fix its pattern",
            w.rule,
            w.file,
            w.contains
        );
    }
    kept.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { findings: kept, waived, files_scanned: files.len() })
}

/// Recursively collect `*.rs` under `root` as (root-relative path with
/// forward slashes, absolute path), sorted for deterministic reports.
fn walk(root: &Path) -> crate::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| crate::err!("lint: reading {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| crate::err!("lint: reading {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
                let rel = path
                    .strip_prefix(root)
                    .map_err(|e| crate::err!("lint: {}: {e}", path.display()))?
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((rel, path));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Parse the waiver file fail-closed (the compas registry-manifest
/// discipline): unknown keys are errors with a key path, every field is
/// a required non-empty string, and `rule` must name a known rule.
fn load_waivers(path: &Path) -> crate::Result<Vec<Waiver>> {
    let v = crate::jsonio::parse_file(path)
        .map_err(|e| crate::err!("{}: {e}", path.display()))?;
    let obj = v
        .as_obj()
        .ok_or_else(|| crate::err!("{}: top level must be an object", path.display()))?;
    for key in obj.keys() {
        crate::ensure!(
            key == "waivers",
            "{}: unknown key '{}' (expected only 'waivers')",
            path.display(),
            key
        );
    }
    let arr = obj
        .get("waivers")
        .and_then(|w| w.as_arr())
        .ok_or_else(|| crate::err!("{}: 'waivers' must be an array", path.display()))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, entry) in arr.iter().enumerate() {
        let eobj = entry.as_obj().ok_or_else(|| {
            crate::err!("{}: waivers[{i}] must be an object", path.display())
        })?;
        for key in eobj.keys() {
            crate::ensure!(
                matches!(key.as_str(), "rule" | "file" | "contains" | "why"),
                "{}: waivers[{i}].{}: unknown key (expected rule/file/contains/why)",
                path.display(),
                key
            );
        }
        let field = |name: &str| -> crate::Result<String> {
            let s = eobj
                .get(name)
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    crate::err!("{}: waivers[{i}].{name}: required string", path.display())
                })?;
            crate::ensure!(
                !s.trim().is_empty(),
                "{}: waivers[{i}].{name}: must be non-empty",
                path.display()
            );
            Ok(s.to_string())
        };
        let w = Waiver {
            rule: field("rule")?,
            file: field("file")?,
            contains: field("contains")?,
            why: field("why")?,
        };
        crate::ensure!(
            RULES.contains(&w.rule.as_str()),
            "{}: waivers[{i}].rule: unknown rule '{}' (known: {})",
            path.display(),
            w.rule,
            RULES.join(", ")
        );
        out.push(w);
    }
    Ok(out)
}
