//! Minimal Rust lexer for `mpq lint` (see [`crate::analysis`]).
//!
//! The rule engine scans source *textually*, so everything that could
//! produce a false positive — comment prose, string/char/raw-string
//! literal contents — is blanked to spaces before the rules run, while
//! every newline is preserved so findings keep their original line
//! numbers.  On top of the blanked text the lexer derives the three
//! structural facts the rules need:
//!
//! * per-line `// relaxed-ok:` comment markers (the only information
//!   stripping would otherwise destroy — the `relaxed-audit` rule needs
//!   to see justification comments);
//! * per-line test-region membership (`#[cfg(test)]` / `#[test]` /
//!   `mod tests` items, tracked by brace depth) so rules can exclude
//!   test code;
//! * `fn` spans (name + inclusive line range) so rules can scope to
//!   specific functions (the `wall-clock` rule on the loadgen content
//!   generators).
//!
//! This is deliberately not a full parser: it never needs to be right
//! about Rust semantics, only about where literals and comments start
//! and end, and it fails toward *under*-reporting structure (e.g. a
//! `#[cfg(test)]` that never opens a brace just stays armed), which the
//! fixtures pin down.

/// One `fn` item's span in the blanked source: `start..=end` are
/// 1-indexed source lines from the `fn` keyword's line to the line of
/// the body's closing brace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// The lexed view of one source file.
#[derive(Debug)]
pub struct Lexed {
    /// Source with comments and string/char literal contents blanked to
    /// spaces; has exactly the same number of lines as the input.
    pub code: String,
    /// `relaxed_ok[i]` — line `i` (0-indexed) carries a comment
    /// containing `relaxed-ok:`.
    pub relaxed_ok: Vec<bool>,
    /// `in_test[i]` — line `i` (0-indexed) is inside a test region.
    pub in_test: Vec<bool>,
    /// Every `fn` item, outermost first for nested functions.
    pub fns: Vec<FnSpan>,
}

impl Lexed {
    /// Names of the functions whose span contains 1-indexed `line`
    /// (outermost first; empty at module scope).
    pub fn fn_names_at(&self, line: usize) -> Vec<&str> {
        self.fns
            .iter()
            .filter(|f| f.start <= line && line <= f.end)
            .map(|f| f.name.as_str())
            .collect()
    }
}

/// Lex one source file.  Infallible by design: malformed input (an
/// unterminated literal, an unbalanced brace) degrades to blanked text
/// and truncated spans rather than an error, so the linter can always
/// report on whatever the compiler will reject anyway.
pub fn lex(src: &str) -> Lexed {
    let (code, relaxed_ok) = strip(src);
    let (in_test, fns) = regions(&code);
    Lexed { code, relaxed_ok, in_test, fns }
}

/// Blank comments and literals to spaces, preserving every newline.
/// Returns the blanked text plus the per-line `relaxed-ok:` markers
/// harvested from the comments while they were still visible.
fn strip(src: &str) -> (String, Vec<bool>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let nlines = src.split('\n').count();
    let mut relaxed_ok = vec![false; nlines];
    let mut out = String::with_capacity(src.len());
    let mut line = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        // Line comment: blank to end of line, harvesting relaxed-ok.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i;
            let mut text = String::new();
            while j < n && chars[j] != '\n' {
                text.push(chars[j]);
                out.push(' ');
                j += 1;
            }
            if text.contains("relaxed-ok:") {
                relaxed_ok[line] = true;
            }
            i = j;
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            out.push(' ');
            out.push(' ');
            let mut text_line = String::new();
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    j += 2;
                    continue;
                }
                if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    j += 2;
                    continue;
                }
                if chars[j] == '\n' {
                    if text_line.contains("relaxed-ok:") {
                        relaxed_ok[line] = true;
                    }
                    text_line.clear();
                    out.push('\n');
                    line += 1;
                    j += 1;
                    continue;
                }
                text_line.push(chars[j]);
                out.push(' ');
                j += 1;
            }
            if text_line.contains("relaxed-ok:") {
                relaxed_ok[line] = true;
            }
            i = j;
            continue;
        }
        // Raw / byte string prefixes (`r"…"`, `r#"…"#`, `br#"…"#`,
        // `b"…"`, `b'…'`) — only when not glued to an identifier.
        let prev_ident =
            i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        if !prev_ident && (c == 'r' || c == 'b') {
            let mut j = i;
            let is_b = chars[j] == 'b';
            if is_b {
                j += 1;
            }
            let is_r = j < n && chars[j] == 'r';
            if is_r {
                j += 1;
                let mut hashes = 0usize;
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    // Raw string: blank prefix + opening quote…
                    for _ in i..=j {
                        out.push(' ');
                    }
                    j += 1;
                    // …then blank the body until `"` + `hashes` * `#`.
                    while j < n {
                        if chars[j] == '"' {
                            let mut k = j + 1;
                            let mut h = 0usize;
                            while k < n && h < hashes && chars[k] == '#' {
                                h += 1;
                                k += 1;
                            }
                            if h == hashes {
                                for _ in j..k {
                                    out.push(' ');
                                }
                                j = k;
                                break;
                            }
                        }
                        if chars[j] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        j += 1;
                    }
                    i = j;
                    continue;
                }
                // `r`/`br` not followed by a raw string (identifier or
                // raw identifier) — fall through, emit `c` as code.
            } else if is_b && j < n && (chars[j] == '"' || chars[j] == '\'') {
                // Byte string / byte char: blank the `b`, re-enter the
                // loop on the quote so the literal branches handle it.
                out.push(' ');
                i = j;
                continue;
            }
        }
        // String literal with escapes.
        if c == '"' {
            out.push(' ');
            let mut j = i + 1;
            while j < n {
                if chars[j] == '\\' {
                    out.push(' ');
                    j += 1;
                    if j < n {
                        if chars[j] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        j += 1;
                    }
                    continue;
                }
                if chars[j] == '"' {
                    out.push(' ');
                    j += 1;
                    break;
                }
                if chars[j] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // Char literal vs lifetime: `'\…'` and `'x'` are literals,
        // anything else starting with `'` is a lifetime and stays.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                out.push(' ');
                let mut j = i + 1;
                while j < n && chars[j] != '\'' {
                    if chars[j] == '\\' {
                        out.push(' ');
                        j += 1;
                        if j < n {
                            if chars[j] == '\n' {
                                out.push('\n');
                                line += 1;
                            } else {
                                out.push(' ');
                            }
                            j += 1;
                        }
                        continue;
                    }
                    if chars[j] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    j += 1;
                }
                if j < n {
                    out.push(' ');
                    j += 1;
                }
                i = j;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                out.push(' ');
                if chars[i + 1] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                out.push(' ');
                i += 3;
                continue;
            }
            out.push('\'');
            i += 1;
            continue;
        }
        if c == '\n' {
            line += 1;
        }
        out.push(c);
        i += 1;
    }
    (out, relaxed_ok)
}

/// Walk the blanked text once, tracking brace depth, to derive test
/// regions and `fn` spans.
fn regions(code: &str) -> (Vec<bool>, Vec<FnSpan>) {
    let nlines = code.split('\n').count();
    let mut in_test = vec![false; nlines];
    let mut fns: Vec<FnSpan> = Vec::new();
    // (index into fns, brace depth its body opened at)
    let mut open_fns: Vec<(usize, usize)> = Vec::new();
    let mut depth = 0usize;
    // Depth at which the innermost-sufficient test region opened.
    let mut test_at: Option<usize> = None;
    let mut armed_test = false;
    // `fn` item seen; its body's `{` is still ahead.
    let mut pending_fn: Option<(String, usize)> = None;
    let mut expect_name = false;
    for (ln, lt) in code.split('\n').enumerate() {
        if test_at.is_some() {
            in_test[ln] = true;
        }
        // Arm only outside an open region: a `#[test]` attribute inside
        // `mod tests` must not leave the flag set past the region's
        // closing brace.
        if test_at.is_none()
            && (lt.contains("#[cfg(test)]") || lt.contains("#[test]") || lt.contains("mod tests"))
        {
            armed_test = true;
        }
        let mut tok = String::new();
        for ch in lt.chars().chain(std::iter::once(' ')) {
            if ch.is_alphanumeric() || ch == '_' {
                tok.push(ch);
                continue;
            }
            if !tok.is_empty() {
                if tok == "fn" {
                    expect_name = true;
                } else if expect_name {
                    pending_fn = Some((std::mem::take(&mut tok), ln));
                    expect_name = false;
                }
                tok.clear();
            }
            match ch {
                '{' => {
                    if test_at.is_none() && armed_test {
                        test_at = Some(depth);
                        armed_test = false;
                        in_test[ln] = true;
                    }
                    if let Some((name, sline)) = pending_fn.take() {
                        open_fns.push((fns.len(), depth));
                        fns.push(FnSpan { name, start: sline + 1, end: sline + 1 });
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if test_at == Some(depth) {
                        test_at = None;
                    }
                    while let Some(&(idx, d)) = open_fns.last() {
                        if d == depth {
                            fns[idx].end = ln + 1;
                            open_fns.pop();
                        } else {
                            break;
                        }
                    }
                }
                // A `;` ends a bodiless item (`fn f();` in a trait,
                // `mod tests;` or `#[cfg(test)] use …;` in a parent) —
                // disarm both trackers.
                ';' => {
                    pending_fn = None;
                    expect_name = false;
                    armed_test = false;
                }
                _ => {}
            }
        }
    }
    // Unterminated spans (unbalanced braces) close at EOF.
    for (idx, _) in open_fns {
        fns[idx].end = nlines;
    }
    (in_test, fns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn line_count(s: &str) -> usize {
        s.split('\n').count()
    }

    #[test]
    fn stripping_preserves_line_count_on_fixtures() {
        let cases = [
            "fn main() {}\n",
            "// comment\nlet x = \"two\nlines\";\n",
            "/* block\nover\nlines */ code();\n",
            "let r = r#\"raw\nwith \" quote\"#;\n",
            "let c = '\\n'; let l: &'static str = s;\n",
            "let b = b\"bytes\"; let bc = b'x';\n",
        ];
        for src in cases {
            let l = lex(src);
            assert_eq!(line_count(&l.code), line_count(src), "{src:?}");
        }
    }

    #[test]
    fn literal_contents_do_not_leak() {
        let src = "let s = \"Instant::now\"; // Instant::now in prose\nlet r = r\"SystemTime::now\";\n";
        let l = lex(src);
        assert!(!l.code.contains("Instant::now"), "{:?}", l.code);
        assert!(!l.code.contains("SystemTime::now"), "{:?}", l.code);
    }

    #[test]
    fn code_outside_literals_survives() {
        let src = "let t = Instant::now(); // ok\n";
        let l = lex(src);
        assert!(l.code.contains("Instant::now"));
        assert!(!l.code.contains("ok"));
    }

    #[test]
    fn relaxed_ok_markers_are_per_line() {
        let src = "a.load(O::Relaxed); // relaxed-ok: counter\nb.load(O::Relaxed);\n// relaxed-ok: next line\nc.store(1, O::Relaxed);\n";
        let l = lex(src);
        assert_eq!(l.relaxed_ok, vec![true, false, true, false, false]);
        // The justification prose itself must be blanked out of code.
        assert!(!l.code.contains("counter"));
    }

    #[test]
    fn cfg_test_region_detection() {
        let src = "fn live() {\n    work();\n}\n\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n    #[test]\n    fn t() { assert!(true); }\n}\nfn after() {}\n";
        let l = lex(src);
        // Lines 1..=3 (0-indexed 0..=2) and the trailing fn are live.
        assert!(!l.in_test[0] && !l.in_test[1] && !l.in_test[2]);
        // `mod tests {` through its closing brace are test lines.
        for ln in 5..=9 {
            assert!(l.in_test[ln], "line {} should be in_test", ln + 1);
        }
        assert!(!l.in_test[10]);
    }

    #[test]
    fn mod_tests_without_cfg_is_a_test_region() {
        let src = "mod tests {\n    fn t() {}\n}\nfn live() {}\n";
        let l = lex(src);
        assert!(l.in_test[0] && l.in_test[1] && l.in_test[2]);
        assert!(!l.in_test[3]);
    }

    #[test]
    fn fn_spans_cover_bodies_and_nesting() {
        let src = "fn outer() {\n    let x = 1;\n    fn inner() {\n        let y = 2;\n    }\n    done(x);\n}\n";
        let l = lex(src);
        assert_eq!(
            l.fns,
            vec![
                FnSpan { name: "outer".into(), start: 1, end: 7 },
                FnSpan { name: "inner".into(), start: 3, end: 5 },
            ]
        );
        assert_eq!(l.fn_names_at(4), vec!["outer", "inner"]);
        assert_eq!(l.fn_names_at(6), vec!["outer"]);
    }

    #[test]
    fn trait_method_declarations_do_not_open_spans() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n    fn with_body(&self) {\n        let _ = 1;\n    }\n}\n";
        let l = lex(src);
        assert_eq!(l.fns.len(), 1);
        assert_eq!(l.fns[0].name, "with_body");
    }

    /// Deterministic generator for small random "Rust-ish" sources: a
    /// token soup of code idents, comments, and every literal family,
    /// with sensitive substrings planted inside literals/comments only.
    fn gen_source(rng: &mut crate::rng::Pcg32) -> String {
        let pieces: &[&str] = &[
            "let x = 1;",
            "foo(bar, baz);",
            "\n",
            "// line comment with Instant::now\n",
            "/* block\ncomment SystemTime::now */",
            "let s = \"str Instant::now \\\" esc\";",
            "let r = r#\"raw \" SystemTime::now\"#;",
            "let c = 'q';",
            "let e = '\\n';",
            "let b = b\"bytes Instant::now\";",
            "let l: &'static str = t;",
            "{ nested(); }",
        ];
        let n = 1 + (rng.next_u64() % 12) as usize;
        let mut out = String::new();
        for _ in 0..n {
            let i = (rng.next_u64() % pieces.len() as u64) as usize;
            out.push_str(pieces[i]);
            out.push(' ');
        }
        out
    }

    #[test]
    fn prop_stripping_never_changes_line_numbers() {
        prop::forall(
            &prop::Config::default(),
            gen_source,
            |src| {
                let l = lex(src);
                if line_count(&l.code) == line_count(src) {
                    Ok(())
                } else {
                    Err(format!(
                        "line count changed: {} -> {}",
                        line_count(src),
                        line_count(&l.code)
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_literals_never_leak_into_code() {
        // Every planted "Instant::now"/"SystemTime::now" lives inside a
        // literal or comment, so none may survive stripping.
        prop::forall(
            &prop::Config::default(),
            gen_source,
            |src| {
                let l = lex(src);
                if l.code.contains("Instant::now") || l.code.contains("SystemTime::now") {
                    Err(format!("literal leaked into code: {:?}", l.code))
                } else {
                    Ok(())
                }
            },
        );
    }
}
