//! Append-only JSONL result store with exact-key resume.
//!
//! One store holds one model's run records (`results/<model>/sweep.jsonl`).
//! Every record is keyed by (model, method, budget, seed); the store keeps
//! a fingerprint index over the **exact f64 bits** of the budget so resume
//! lookups are O(1) and never merge distinct budgets that happen to print
//! the same (the old report path's `{:.4}` round-trip bug class).
//!
//! The multi-model registry in [`crate::experiment::registry`] routes
//! records to per-model stores; the experiment scheduler appends in plan
//! order so a killed sweep leaves a valid prefix to resume from.

use std::collections::HashSet;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::jsonio;

use super::RunRecord;

/// Exact content key of a run record: (model, method, budget-bits, seed).
/// `budget_frac` enters by `to_bits`, so two budgets collide only when
/// they are the same f64 — values round-trip bit-exactly through the
/// JSONL store (shortest-representation float formatting).
pub fn record_key(model: &str, method: &str, budget_frac: f64, seed: u64) -> (String, String, u64, u64) {
    (model.to_string(), method.to_string(), budget_frac.to_bits(), seed)
}

pub struct ResultStore {
    path: PathBuf,
    records: Vec<RunRecord>,
    keys: HashSet<(String, String, u64, u64)>,
}

impl ResultStore {
    pub fn open(path: &Path) -> crate::Result<ResultStore> {
        let mut records = Vec::new();
        if path.exists() {
            let content = std::fs::read_to_string(path)?;
            // Every append ends in '\n', so a newline-less tail can only
            // be a record cut short by a mid-write kill.  Drop it and
            // truncate the file to the last line boundary — otherwise the
            // next append would concatenate onto the partial bytes and
            // turn two records into one permanently unparseable line.
            let valid_len = content.rfind('\n').map(|i| i + 1).unwrap_or(0);
            if valid_len != content.len() {
                let f = std::fs::OpenOptions::new().write(true).open(path)?;
                f.set_len(valid_len as u64)?;
            }
            for line in content[..valid_len].lines() {
                if line.trim().is_empty() {
                    continue;
                }
                if let Ok(v) = jsonio::parse(line) {
                    if let Some(r) = RunRecord::from_json(&v) {
                        records.push(r);
                    }
                }
            }
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let keys = records
            .iter()
            .map(|r| record_key(&r.model, &r.method, r.budget_frac, r.seed))
            .collect();
        Ok(ResultStore {
            path: path.to_path_buf(),
            records,
            keys,
        })
    }

    /// Exact-key membership (O(1); budget compared by f64 bits).
    pub fn contains(&self, model: &str, method: &str, frac: f64, seed: u64) -> bool {
        self.keys.contains(&record_key(model, method, frac, seed))
    }

    /// Exact-key fetch (budget compared by f64 bits) — the resume path's
    /// lookup, consistent with [`contains`](Self::contains) so two
    /// budgets closer than any print tolerance never alias.
    pub fn find_exact(
        &self,
        model: &str,
        method: &str,
        frac: f64,
        seed: u64,
    ) -> Option<RunRecord> {
        self.records
            .iter()
            .find(|r| {
                r.model == model
                    && r.method == method
                    && r.budget_frac.to_bits() == frac.to_bits()
                    && r.seed == seed
            })
            .cloned()
    }

    /// Find a record by key.  Kept tolerant (budget within 1e-9) for
    /// callers holding budgets that went through lossy formatting; new
    /// code should prefer [`find_exact`](Self::find_exact).
    pub fn find(&self, model: &str, method: &str, frac: f64, seed: u64) -> Option<RunRecord> {
        self.records
            .iter()
            .find(|r| {
                r.model == model
                    && r.method == method
                    && (r.budget_frac - frac).abs() < 1e-9
                    && r.seed == seed
            })
            .cloned()
    }

    pub fn append(&mut self, rec: &RunRecord) -> crate::Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", rec.to_json().to_string_compact())?;
        self.keys
            .insert(record_key(&rec.model, &rec.method, rec.budget_frac, rec.seed));
        self.records.push(rec.clone());
        Ok(())
    }

    pub fn records(&self) -> &[RunRecord] {
        &self.records
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> RunRecord {
        RunRecord {
            model: "m".into(),
            method: "eagl".into(),
            budget_frac: 0.7,
            seed: 3,
            metric: 0.91,
            loss: 0.3,
            groups_at_lo: 5,
            compression: 9.1,
            gbops: 1.25,
            wall_s: 2.0,
        }
    }

    #[test]
    fn result_store_round_trip_and_resume() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        store.append(&sample_record()).unwrap();
        // Reopen → record still there.
        let store2 = ResultStore::open(&path).unwrap();
        let found = store2.find("m", "eagl", 0.7, 3).unwrap();
        assert!((found.metric - 0.91).abs() < 1e-12);
        assert!(store2.find("m", "eagl", 0.7, 4).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn partial_trailing_line_is_truncated_and_append_stays_clean() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_partial_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // One complete record followed by a mid-write kill's partial line
        // (no trailing newline).
        let full = sample_record().to_json().to_string_compact();
        std::fs::write(&path, format!("{full}\n{{\"model\":\"sim_ti")).unwrap();
        let mut store = ResultStore::open(&path).unwrap();
        assert_eq!(store.records().len(), 1);
        // The partial tail is gone from the file, so a new append starts
        // on a clean line boundary instead of concatenating.
        let mut rec2 = sample_record();
        rec2.seed = 9;
        store.append(&rec2).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(RunRecord::from_json(&jsonio::parse(line).unwrap()).is_some(), "{line}");
        }
        let store2 = ResultStore::open(&path).unwrap();
        assert_eq!(store2.records().len(), 2);
        assert!(store2.contains("m", "eagl", 0.7, 9));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn find_exact_never_aliases_nearby_budgets() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_exact_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        let mut a = sample_record();
        a.metric = 0.90;
        store.append(&a).unwrap();
        let mut b = sample_record();
        b.budget_frac = 0.7 + 1e-13; // within find()'s 1e-9 tolerance
        b.metric = 0.80;
        store.append(&b).unwrap();
        let hit = store.find_exact("m", "eagl", b.budget_frac, 3).unwrap();
        assert!((hit.metric - 0.80).abs() < 1e-12, "must fetch the exact cell");
        assert!(store.find_exact("m", "eagl", 0.7 + 2e-13, 3).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn contains_uses_exact_budget_bits() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_bits_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        store.append(&sample_record()).unwrap();
        assert!(store.contains("m", "eagl", 0.7, 3));
        assert!(!store.contains("m", "eagl", 0.7, 4));
        // A budget that prints like 0.7000 but differs in bits is distinct.
        let near = 0.7 + 1e-13;
        assert_ne!(near.to_bits(), 0.7f64.to_bits());
        assert!(!store.contains("m", "eagl", near, 3));
        // After a JSONL round-trip the exact key still matches.
        let store2 = ResultStore::open(&path).unwrap();
        assert!(store2.contains("m", "eagl", 0.7, 3));
        let _ = std::fs::remove_file(&path);
    }
}
