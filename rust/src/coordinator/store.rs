//! Append-only JSONL result store with exact-key resume.
//!
//! One store holds one model's run records (`results/<model>/sweep.jsonl`).
//! Every record is keyed by (model, method, budget, seed); the store keeps
//! a fingerprint index over the **exact f64 bits** of the budget so resume
//! lookups are O(1) and never merge distinct budgets that happen to print
//! the same (the old report path's `{:.4}` round-trip bug class).
//!
//! The multi-model registry in [`crate::experiment::registry`] routes
//! records to per-model stores; the experiment scheduler appends in plan
//! order so a killed sweep leaves a valid prefix to resume from.

use std::collections::HashSet;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::jsonio;

use super::RunRecord;

/// Exact content key of a run record: (model, method, budget-bits, seed).
/// `budget_frac` enters by `to_bits`, so two budgets collide only when
/// they are the same f64 — values round-trip bit-exactly through the
/// JSONL store (shortest-representation float formatting).
pub fn record_key(model: &str, method: &str, budget_frac: f64, seed: u64) -> (String, String, u64, u64) {
    (model.to_string(), method.to_string(), budget_frac.to_bits(), seed)
}

/// What [`ResultStore::open`] had to skip or default while loading — a
/// nonzero count means the JSONL file carries corruption that used to be
/// absorbed silently (see [`RunRecord::from_json_diag`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadIssues {
    /// Lines dropped entirely (unparseable JSON or missing required
    /// fields).
    pub skipped_lines: usize,
    /// Optional numeric fields that fell back to a default across all
    /// loaded records.
    pub defaulted_fields: usize,
}

pub struct ResultStore {
    path: PathBuf,
    records: Vec<RunRecord>,
    keys: HashSet<(String, String, u64, u64)>,
    issues: LoadIssues,
}

impl ResultStore {
    pub fn open(path: &Path) -> crate::Result<ResultStore> {
        let mut records = Vec::new();
        let mut issues = LoadIssues::default();
        if path.exists() {
            let content = std::fs::read_to_string(path)?;
            // Every append ends in '\n', so a newline-less tail can only
            // be a record cut short by a mid-write kill.  Drop it and
            // truncate the file to the last line boundary — otherwise the
            // next append would concatenate onto the partial bytes and
            // turn two records into one permanently unparseable line.
            let valid_len = content.rfind('\n').map(|i| i + 1).unwrap_or(0);
            if valid_len != content.len() {
                let f = std::fs::OpenOptions::new().write(true).open(path)?;
                f.set_len(valid_len as u64)?;
            }
            for (lineno, line) in content[..valid_len].lines().enumerate() {
                let lineno = lineno + 1;
                if line.trim().is_empty() {
                    continue;
                }
                match jsonio::parse(line) {
                    Err(e) => {
                        issues.skipped_lines += 1;
                        crate::warn!(
                            "{}:{lineno}: skipped unparseable record: {e}",
                            path.display()
                        );
                    }
                    Ok(v) => {
                        let parsed = RunRecord::from_json_diag(&v);
                        match parsed.record {
                            None => {
                                issues.skipped_lines += 1;
                                crate::warn!(
                                    "{}:{lineno}: skipped record — missing/invalid required \
                                     field(s): {}",
                                    path.display(),
                                    parsed.missing.join(", ")
                                );
                            }
                            Some(r) => {
                                if !parsed.defaulted.is_empty() {
                                    issues.defaulted_fields += parsed.defaulted.len();
                                    crate::warn!(
                                        "{}:{lineno}: defaulted missing/malformed field(s): {}",
                                        path.display(),
                                        parsed.defaulted.join(", ")
                                    );
                                }
                                records.push(r);
                            }
                        }
                    }
                }
            }
            if issues.skipped_lines + issues.defaulted_fields > 0 {
                crate::warn!(
                    "{}: loaded {} record(s); {} line(s) skipped, {} field(s) defaulted",
                    path.display(),
                    records.len(),
                    issues.skipped_lines,
                    issues.defaulted_fields
                );
            }
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let keys = records
            .iter()
            .map(|r| record_key(&r.model, &r.method, r.budget_frac, r.seed))
            .collect();
        Ok(ResultStore {
            path: path.to_path_buf(),
            records,
            keys,
            issues,
        })
    }

    /// Load diagnostics of the `open` that produced this store.
    pub fn load_issues(&self) -> LoadIssues {
        self.issues
    }

    /// Best-metric record for `model` at `budget` — the `mpq serve
    /// --bits-from` lookup.  Exact f64-bits budget matches win; when none
    /// exist the nearest stored budget is used, and an exact-distance tie
    /// between two *different* budgets (e.g. 0.6 vs 0.8 queried at 0.7)
    /// resolves deterministically to the **lower** budget before any
    /// record-level comparison.  Records whose `budget_frac` is not
    /// finite (skipped-field defaults, corrupt rows) never participate —
    /// a single NaN must not poison the distance fold — and a non-finite
    /// query matches nothing.  Within the chosen budget, ties break:
    /// higher metric, then lower seed, then method name.
    pub fn best_at_budget(&self, model: &str, budget: f64) -> Option<RunRecord> {
        if !budget.is_finite() {
            return None;
        }
        let of_model: Vec<&RunRecord> = self
            .records
            .iter()
            .filter(|r| r.model == model && r.budget_frac.is_finite())
            .collect();
        if of_model.is_empty() {
            return None;
        }
        let exact: Vec<&RunRecord> = of_model
            .iter()
            .copied()
            .filter(|r| r.budget_frac.to_bits() == budget.to_bits())
            .collect();
        let pool: Vec<&RunRecord> = if !exact.is_empty() {
            exact
        } else {
            let nearest = of_model
                .iter()
                .map(|r| (r.budget_frac - budget).abs())
                .fold(f64::INFINITY, f64::min);
            // Lower budget wins an exact-distance tie; then only that
            // budget's records compete on metric/seed/method.
            let winner = of_model
                .iter()
                .filter(|r| (r.budget_frac - budget).abs() <= nearest)
                .map(|r| r.budget_frac)
                .fold(f64::INFINITY, f64::min);
            of_model
                .iter()
                .copied()
                .filter(|r| r.budget_frac.to_bits() == winner.to_bits())
                .collect()
        };
        pool.into_iter()
            .min_by(|a, b| {
                b.metric
                    .partial_cmp(&a.metric)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.seed.cmp(&b.seed))
                    .then(a.method.cmp(&b.method))
            })
            .cloned()
    }

    /// Exact-key membership (O(1); budget compared by f64 bits).
    pub fn contains(&self, model: &str, method: &str, frac: f64, seed: u64) -> bool {
        self.keys.contains(&record_key(model, method, frac, seed))
    }

    /// Exact-key fetch (budget compared by f64 bits) — the resume path's
    /// lookup, consistent with [`contains`](Self::contains) so two
    /// budgets closer than any print tolerance never alias.
    pub fn find_exact(
        &self,
        model: &str,
        method: &str,
        frac: f64,
        seed: u64,
    ) -> Option<RunRecord> {
        self.records
            .iter()
            .find(|r| {
                r.model == model
                    && r.method == method
                    && r.budget_frac.to_bits() == frac.to_bits()
                    && r.seed == seed
            })
            .cloned()
    }

    /// Find a record by key.  Kept tolerant (budget within 1e-9) for
    /// callers holding budgets that went through lossy formatting; new
    /// code should prefer [`find_exact`](Self::find_exact).
    pub fn find(&self, model: &str, method: &str, frac: f64, seed: u64) -> Option<RunRecord> {
        self.records
            .iter()
            .find(|r| {
                r.model == model
                    && r.method == method
                    && (r.budget_frac - frac).abs() < 1e-9
                    && r.seed == seed
            })
            .cloned()
    }

    pub fn append(&mut self, rec: &RunRecord) -> crate::Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", rec.to_json().to_string_compact())?;
        self.keys
            .insert(record_key(&rec.model, &rec.method, rec.budget_frac, rec.seed));
        self.records.push(rec.clone());
        Ok(())
    }

    pub fn records(&self) -> &[RunRecord] {
        &self.records
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> RunRecord {
        RunRecord {
            model: "m".into(),
            method: "eagl".into(),
            budget_frac: 0.7,
            seed: 3,
            metric: 0.91,
            loss: 0.3,
            groups_at_lo: 5,
            compression: 9.1,
            gbops: 1.25,
            wall_s: 2.0,
        }
    }

    #[test]
    fn result_store_round_trip_and_resume() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        store.append(&sample_record()).unwrap();
        // Reopen → record still there.
        let store2 = ResultStore::open(&path).unwrap();
        let found = store2.find("m", "eagl", 0.7, 3).unwrap();
        assert!((found.metric - 0.91).abs() < 1e-12);
        assert!(store2.find("m", "eagl", 0.7, 4).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn partial_trailing_line_is_truncated_and_append_stays_clean() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_partial_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // One complete record followed by a mid-write kill's partial line
        // (no trailing newline).
        let full = sample_record().to_json().to_string_compact();
        std::fs::write(&path, format!("{full}\n{{\"model\":\"sim_ti")).unwrap();
        let mut store = ResultStore::open(&path).unwrap();
        assert_eq!(store.records().len(), 1);
        // The partial tail is gone from the file, so a new append starts
        // on a clean line boundary instead of concatenating.
        let mut rec2 = sample_record();
        rec2.seed = 9;
        store.append(&rec2).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(RunRecord::from_json(&jsonio::parse(line).unwrap()).is_some(), "{line}");
        }
        let store2 = ResultStore::open(&path).unwrap();
        assert_eq!(store2.records().len(), 2);
        assert!(store2.contains("m", "eagl", 0.7, 9));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_counts_and_survives_skipped_and_defaulted_lines() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_diag_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let good = sample_record().to_json().to_string_compact();
        let content = format!(
            "{good}\n\
             {{not json at all\n\
             {{\"model\":\"m\",\"method\":\"eagl\",\"metric\":0.5}}\n\
             {{\"model\":\"m\",\"method\":\"alps\",\"budget_frac\":0.6,\"seed\":2,\"metric\":0.7}}\n"
        );
        std::fs::write(&path, content).unwrap();
        let store = ResultStore::open(&path).unwrap();
        // good + the defaulted-fields record survive; the malformed line
        // and the missing-required-fields record are skipped, counted.
        assert_eq!(store.records().len(), 2);
        assert_eq!(
            store.load_issues(),
            LoadIssues {
                skipped_lines: 2,
                // loss, groups_at_lo, compression, gbops, wall_s
                defaulted_fields: 5,
            }
        );
        // A clean store reports zero issues.
        let clean = dir.join(format!("store_diag_clean_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&clean);
        std::fs::write(&clean, format!("{good}\n")).unwrap();
        assert_eq!(ResultStore::open(&clean).unwrap().load_issues(), LoadIssues::default());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&clean);
    }

    #[test]
    fn best_at_budget_picks_max_metric_with_deterministic_ties() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_best_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        let mut mk = |method: &str, budget: f64, seed: u64, metric: f64| {
            let mut r = sample_record();
            r.method = method.into();
            r.budget_frac = budget;
            r.seed = seed;
            r.metric = metric;
            store.append(&r).unwrap();
        };
        mk("eagl", 0.7, 0, 0.90);
        mk("alps", 0.7, 1, 0.94);
        mk("eagl", 0.7, 2, 0.94); // tie on metric → lower seed wins
        mk("hawq_v3", 0.6, 0, 0.99);
        drop(mk);
        let best = store.best_at_budget("m", 0.7).unwrap();
        assert_eq!((best.method.as_str(), best.seed), ("alps", 1));
        // No exact budget 0.62 → fall back to the nearest stored budget
        // (0.6; unambiguous — 0.65 would tie-break on f64 rounding noise).
        let near = store.best_at_budget("m", 0.62).unwrap();
        assert_eq!(near.method, "hawq_v3");
        // Unknown model → None.
        assert!(store.best_at_budget("nope", 0.7).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn best_at_budget_ignores_non_finite_budgets_and_queries() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_nan_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        // A corrupt row: NaN budget with the best metric in the store.
        let mut bad = sample_record();
        bad.budget_frac = f64::NAN;
        bad.metric = 0.999;
        store.append(&bad).unwrap();
        let mut good = sample_record();
        good.budget_frac = 0.8;
        good.seed = 1;
        good.metric = 0.85;
        store.append(&good).unwrap();
        // The nearest-budget fallback must resolve to the finite record,
        // never the NaN row, at any queried budget.
        let hit = store.best_at_budget("m", 0.5).unwrap();
        assert_eq!((hit.budget_frac, hit.seed), (0.8, 1));
        // A non-finite query matches nothing — including the NaN record
        // itself (whose bits would exact-match a NaN query).
        assert!(store.best_at_budget("m", f64::NAN).is_none());
        assert!(store.best_at_budget("m", f64::INFINITY).is_none());
        // A store holding only non-finite budgets has no best record.
        let path2 = dir.join(format!("store_nan2_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path2);
        let mut only_bad = ResultStore::open(&path2).unwrap();
        only_bad.append(&bad).unwrap();
        assert!(only_bad.best_at_budget("m", 0.7).is_none());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&path2);
    }

    #[test]
    fn best_at_budget_equidistant_tie_resolves_to_lower_budget() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_tie_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        // 0.5 and 1.0 are *exactly* equidistant from 0.75 (all three are
        // exact binary fractions, both distances are the same f64).  The
        // higher budget carries the higher metric, so a metric-first
        // comparison across both budgets would pick 1.0.
        let mut lo = sample_record();
        lo.budget_frac = 0.5;
        lo.seed = 0;
        lo.metric = 0.80;
        store.append(&lo).unwrap();
        let mut hi = sample_record();
        hi.budget_frac = 1.0;
        hi.seed = 0;
        hi.metric = 0.95;
        store.append(&hi).unwrap();
        assert_eq!((0.5f64 - 0.75).abs().to_bits(), (1.0f64 - 0.75).abs().to_bits());
        let best = store.best_at_budget("m", 0.75).unwrap();
        assert_eq!(
            best.budget_frac, 0.5,
            "equidistant nearest-budget tie must resolve to the lower budget"
        );
        // Within the winning budget, the usual metric ordering applies.
        let mut lo2 = sample_record();
        lo2.budget_frac = 0.5;
        lo2.seed = 5;
        lo2.metric = 0.90;
        store.append(&lo2).unwrap();
        assert_eq!(store.best_at_budget("m", 0.75).unwrap().seed, 5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn find_exact_never_aliases_nearby_budgets() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_exact_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        let mut a = sample_record();
        a.metric = 0.90;
        store.append(&a).unwrap();
        let mut b = sample_record();
        b.budget_frac = 0.7 + 1e-13; // within find()'s 1e-9 tolerance
        b.metric = 0.80;
        store.append(&b).unwrap();
        let hit = store.find_exact("m", "eagl", b.budget_frac, 3).unwrap();
        assert!((hit.metric - 0.80).abs() < 1e-12, "must fetch the exact cell");
        assert!(store.find_exact("m", "eagl", 0.7 + 2e-13, 3).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn contains_uses_exact_budget_bits() {
        let dir = std::env::temp_dir().join("mpq_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_bits_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut store = ResultStore::open(&path).unwrap();
        store.append(&sample_record()).unwrap();
        assert!(store.contains("m", "eagl", 0.7, 3));
        assert!(!store.contains("m", "eagl", 0.7, 4));
        // A budget that prints like 0.7000 but differs in bits is distinct.
        let near = 0.7 + 1e-13;
        assert_ne!(near.to_bits(), 0.7f64.to_bits());
        assert!(!store.contains("m", "eagl", near, 3));
        // After a JSONL round-trip the exact key still matches.
        let store2 = ResultStore::open(&path).unwrap();
        assert!(store2.contains("m", "eagl", 0.7, 3));
        let _ = std::fs::remove_file(&path);
    }
}
