//! Experiment coordinator — the paper's evaluation framework (Fig. 1) as a
//! runnable pipeline, generic over the execution [`Backend`].
//!
//! For a (model, method, budget, seed) tuple the coordinator:
//!
//! 1. obtains the trained `b_hi`-bit base checkpoint (trained once per
//!    model, cached on disk along with the quasi-full-precision reference);
//! 2. obtains the method's per-layer gain estimate (computed once per
//!    (model, method), cached — a budget sweep reuses it, exactly as the
//!    paper's framework separates estimation from optimization);
//! 3. runs the 0-1 knapsack at the budget → per-layer precision choice;
//! 4. transforms the checkpoint (step-size rescale on dropped layers) and
//!    fine-tunes with LSQ for the configured number of steps;
//! 5. evaluates and appends a [`RunRecord`] to the JSONL result store
//!    (append-only; reruns resume by skipping already-present records).
//!
//! The backend is anything implementing [`Backend`]: the hermetic
//! [`SimBackend`] (default — no artifacts needed) or the pjrt artifact
//! runtime.  ALPS's per-group probe fine-tunes are independent jobs;
//! [`job_pool`] fans independent work out over worker threads, each owning
//! its own backend (PJRT clients are not Sync). On the single-core CI
//! testbed this degenerates to sequential execution without code changes.

pub mod store;

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::time::Instant;

pub use store::ResultStore;

use crate::backend::{self, Backend, BackendKind, KernelChoice, SimBackend, TrainState};
use crate::ckpt::Checkpoint;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::jsonio::{self, Json};
use crate::methods::{self, GainEstimate, MethodConfig, MethodKind};
use crate::quant::{self, BitsConfig};
use crate::train::{evaluate, finetune, EvalResult, TrainConfig};

/// Factory that re-opens the coordinator's backend for worker threads
/// (see [`crate::backend::BackendFactory`] and [`job_pool`]).
pub type Spawner = Box<dyn Fn() -> crate::Result<Box<dyn Backend>> + Send + Sync>;

/// Default sweep parallelism: the `MPQ_WORKERS` env override wins, else
/// the machine's available parallelism, else 1.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MPQ_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Everything needed to run experiments for one model on one backend.
pub struct Coordinator<B: Backend> {
    pub model: String,
    pub results_dir: PathBuf,
    pub rt: B,
    pub graph: Graph,
    pub data: Dataset,
    pub mcfg: MethodConfig,
    /// Fine-tune steps for base-checkpoint training.
    pub base_steps: usize,
    /// Fine-tune steps per mixed-precision run.
    pub ft_steps: usize,
    /// Eval batches per evaluation.
    pub eval_batches: usize,
    /// Worker threads for the embarrassingly-parallel gain sweeps (ALPS
    /// per-group probes, HAWQ Hutchinson draws).  `1` forces the
    /// sequential path; results are bit-identical either way.
    pub workers: usize,
    /// Re-opens a fresh backend per worker; `None` (e.g. a custom
    /// [`with_backend`](Coordinator::with_backend) coordinator without a
    /// registered spawner) also forces the sequential path.
    spawner: Option<Spawner>,
    gain_cache: BTreeMap<&'static str, GainEstimate>,
}

/// One row of the result store.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub model: String,
    pub method: String,
    pub budget_frac: f64,
    pub seed: u64,
    pub metric: f64,
    pub loss: f64,
    pub groups_at_lo: usize,
    pub compression: f64,
    pub gbops: f64,
    pub wall_s: f64,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("method", Json::str(&self.method)),
            ("budget_frac", Json::num(self.budget_frac)),
            ("seed", Json::num(self.seed as f64)),
            ("metric", Json::num(self.metric)),
            ("loss", Json::num(self.loss)),
            ("groups_at_lo", Json::num(self.groups_at_lo as f64)),
            ("compression", Json::num(self.compression)),
            ("gbops", Json::num(self.gbops)),
            ("wall_s", Json::num(self.wall_s)),
        ])
    }

    /// Parse a record; `None` when a required field (model, method,
    /// budget, seed, metric) is missing or non-finite — non-finite floats
    /// serialize as JSON `null` (see [`crate::jsonio`]), so NaN/Inf
    /// metrics are rejected here rather than corrupting the store.
    pub fn from_json(v: &Json) -> Option<RunRecord> {
        Self::from_json_diag(v).record
    }

    /// [`from_json`](Self::from_json) with field-level diagnostics.
    ///
    /// Optional numeric fields used to be absorbed silently via
    /// `unwrap_or` defaults, so a corrupted store fed zeros straight into
    /// frontier math with no trace.  This variant reports exactly which
    /// required fields killed a record and which optional fields fell
    /// back to a default; [`ResultStore::open`] logs both with the JSONL
    /// line number and counts them.  A field that is *present and valid*
    /// is never flagged — `wall_s: 0` (what the experiment scheduler
    /// deliberately persists for byte-identical stores) parses cleanly.
    pub fn from_json_diag(v: &Json) -> RecordParse {
        let mut missing: Vec<&'static str> = Vec::new();
        let mut defaulted: Vec<&'static str> = Vec::new();

        let model = v.at(&["model"]).as_str();
        if model.is_none() {
            missing.push("model");
        }
        let method = v.at(&["method"]).as_str();
        if method.is_none() {
            missing.push("method");
        }
        let budget_frac = v.at(&["budget_frac"]).as_f64();
        if budget_frac.is_none() {
            missing.push("budget_frac");
        }
        let seed = v.at(&["seed"]).as_f64();
        if seed.is_none() {
            missing.push("seed");
        }
        let metric = v.at(&["metric"]).as_f64().filter(|m| m.is_finite());
        if metric.is_none() {
            missing.push("metric");
        }

        let loss = match v.at(&["loss"]).as_f64() {
            Some(x) => x,
            None => {
                defaulted.push("loss");
                f64::NAN
            }
        };
        let groups_at_lo = match v.at(&["groups_at_lo"]).as_usize() {
            Some(x) => x,
            None => {
                defaulted.push("groups_at_lo");
                0
            }
        };
        let compression = match v.at(&["compression"]).as_f64() {
            Some(x) => x,
            None => {
                defaulted.push("compression");
                0.0
            }
        };
        let gbops = match v.at(&["gbops"]).as_f64() {
            Some(x) => x,
            None => {
                defaulted.push("gbops");
                0.0
            }
        };
        let wall_s = match v.at(&["wall_s"]).as_f64() {
            Some(x) => x,
            None => {
                defaulted.push("wall_s");
                0.0
            }
        };

        if !missing.is_empty() {
            return RecordParse {
                record: None,
                missing,
                defaulted,
            };
        }
        RecordParse {
            record: Some(RunRecord {
                model: model.unwrap().to_string(),
                method: method.unwrap().to_string(),
                budget_frac: budget_frac.unwrap(),
                seed: seed.unwrap() as u64,
                metric: metric.unwrap(),
                loss,
                groups_at_lo,
                compression,
                gbops,
                wall_s,
            }),
            missing,
            defaulted,
        }
    }
}

/// Field-level outcome of parsing one JSONL record (see
/// [`RunRecord::from_json_diag`]).
pub struct RecordParse {
    /// The record, or `None` when any required field was missing/invalid.
    pub record: Option<RunRecord>,
    /// Required fields that were missing or invalid.
    pub missing: Vec<&'static str>,
    /// Optional fields that were missing/malformed and got a default.
    pub defaulted: Vec<&'static str>,
}

/// Canonical results directory for a (backend kind, model): next to the
/// artifacts dir for pjrt, under [`crate::results_root`] for sim (which
/// walks up like `find_artifacts`, so sweeps resume from the same store
/// regardless of the cwd).  Shared by [`Coordinator::open`] and the
/// experiment registry so both always point at the same JSONL store.
pub fn results_dir_for(kind: BackendKind, model: &str) -> PathBuf {
    match kind {
        BackendKind::Pjrt => crate::artifacts_dir()
            .parent()
            .unwrap_or(Path::new("."))
            .join("results")
            .join(model),
        BackendKind::Sim => crate::results_root().join(model),
    }
}

impl Coordinator<Box<dyn Backend>> {
    /// Open a coordinator on a boxed backend chosen by `kind` (the CLI
    /// path).  Results go to [`results_dir_for`]`(kind, model)`.
    pub fn open(kind: BackendKind, model: &str, data_seed: u64) -> crate::Result<Self> {
        Self::open_at(kind, model, data_seed, results_dir_for(kind, model))
    }

    /// [`open`](Self::open) with an explicit [`KernelChoice`] (the CLI's
    /// `--kernel` flag), propagated to the worker spawner so parallel
    /// ALPS/HAWQ sweeps execute with the same kernels as the main
    /// backend.
    pub fn open_kernel(
        kind: BackendKind,
        model: &str,
        data_seed: u64,
        kernel: KernelChoice,
    ) -> crate::Result<Self> {
        Self::open_kernel_at(kind, model, data_seed, results_dir_for(kind, model), kernel)
    }

    /// [`open`](Self::open) with an explicit results directory (the
    /// experiment scheduler redirects whole sweeps into isolated roots).
    pub fn open_at(
        kind: BackendKind,
        model: &str,
        data_seed: u64,
        results_dir: PathBuf,
    ) -> crate::Result<Self> {
        Self::open_kernel_at(kind, model, data_seed, results_dir, KernelChoice::Reference)
    }

    /// [`open_kernel`](Self::open_kernel) with explicit packed-path
    /// tuning (variant + gemm-threads), applied to the main backend and
    /// every parallel-sweep worker.
    pub fn open_tuned(
        kind: BackendKind,
        model: &str,
        data_seed: u64,
        kernel: KernelChoice,
        tuning: backend::KernelTuning,
    ) -> crate::Result<Self> {
        Self::open_tuned_at(kind, model, data_seed, results_dir_for(kind, model), kernel, tuning)
    }

    /// The fully explicit constructor behind [`open`](Self::open) /
    /// [`open_kernel`](Self::open_kernel) / [`open_at`](Self::open_at).
    pub fn open_kernel_at(
        kind: BackendKind,
        model: &str,
        data_seed: u64,
        results_dir: PathBuf,
        kernel: KernelChoice,
    ) -> crate::Result<Self> {
        Self::open_tuned_at(
            kind,
            model,
            data_seed,
            results_dir,
            kernel,
            backend::KernelTuning::default(),
        )
    }

    /// [`open_kernel_at`](Self::open_kernel_at) plus packed-path tuning.
    pub fn open_tuned_at(
        kind: BackendKind,
        model: &str,
        data_seed: u64,
        results_dir: PathBuf,
        kernel: KernelChoice,
        tuning: backend::KernelTuning,
    ) -> crate::Result<Self> {
        let be = backend::open_tuned(kind, model, kernel, tuning)?;
        let mut co = Coordinator::with_backend(be, data_seed, results_dir)?;
        let model_s = model.to_string();
        co.spawner = Some(Box::new(move || {
            backend::open_tuned(kind, &model_s, kernel, tuning)
        }));
        Ok(co)
    }

    /// Open with automatic backend resolution (artifacts + pjrt feature →
    /// pjrt, else sim).
    pub fn open_auto(model: &str, data_seed: u64) -> crate::Result<Self> {
        Self::open(backend::resolve(None, model)?, model, data_seed)
    }
}

impl Coordinator<SimBackend> {
    /// Hermetic sim coordinator (no artifacts); results under
    /// `<results_root>/<model>` (see [`crate::results_root`]).
    pub fn sim(model: &str, data_seed: u64) -> crate::Result<Self> {
        Self::sim_kernel(model, data_seed, KernelChoice::Reference)
    }

    /// [`sim`](Self::sim) with an explicit [`KernelChoice`], applied to
    /// the main backend and every parallel-sweep worker.
    pub fn sim_kernel(
        model: &str,
        data_seed: u64,
        kernel: KernelChoice,
    ) -> crate::Result<Self> {
        Self::sim_tuned(model, data_seed, kernel, backend::KernelTuning::default())
    }

    /// [`sim_kernel`](Self::sim_kernel) plus packed-path tuning.
    pub fn sim_tuned(
        model: &str,
        data_seed: u64,
        kernel: KernelChoice,
        tuning: backend::KernelTuning,
    ) -> crate::Result<Self> {
        let mut co = Coordinator::with_backend(
            SimBackend::with_tuning(model, kernel, tuning)?,
            data_seed,
            crate::results_root().join(model),
        )?;
        let model_s = model.to_string();
        co.spawner = Some(Box::new(move || -> crate::Result<Box<dyn Backend>> {
            Ok(Box::new(SimBackend::with_tuning(&model_s, kernel, tuning)?))
        }));
        Ok(co)
    }
}

impl<B: Backend> Coordinator<B> {
    /// Build a coordinator around an already-open backend.  The graph is
    /// derived from the backend's manifest; `results_dir` holds cached
    /// checkpoints, gains, and the JSONL store.
    pub fn with_backend(rt: B, data_seed: u64, results_dir: PathBuf) -> crate::Result<Self> {
        let graph = Graph::from_manifest(&rt.manifest().raw)?;
        let data = Dataset::for_task(rt.manifest().task, data_seed);
        let model = rt.manifest().model.clone();
        std::fs::create_dir_all(&results_dir)?;
        Ok(Coordinator {
            model,
            results_dir,
            rt,
            graph,
            data,
            mcfg: MethodConfig::default(),
            base_steps: 400,
            ft_steps: 150,
            eval_batches: 4,
            workers: default_workers(),
            spawner: None,
            gain_cache: BTreeMap::new(),
        })
    }

    /// Register a backend factory enabling the parallel ALPS/HAWQ path
    /// (constructors that know their backend — [`Coordinator::open`],
    /// [`Coordinator::sim`] — register one automatically).
    pub fn set_spawner(&mut self, spawner: Spawner) {
        self.spawner = Some(spawner);
    }

    // -- base checkpoints ----------------------------------------------------

    /// Trained `b_hi`-bit base checkpoint (train once, cache on disk).
    pub fn base_checkpoint(&mut self) -> crate::Result<Checkpoint> {
        let path = self.results_dir.join(format!("base{}.ckpt", self.mcfg.b_hi));
        if path.exists() {
            return Checkpoint::load(&path);
        }
        crate::info!(
            "training {}-bit base checkpoint ({} steps)",
            self.mcfg.b_hi,
            self.base_steps
        );
        let ck = self.train_uniform(self.mcfg.b_hi, self.base_steps, 0)?;
        ck.save(&path)?;
        Ok(ck)
    }

    /// Quasi-full-precision reference (8-bit uniform — lossless for these
    /// tasks; stands in for the paper's FP32 baselines, DESIGN.md §3).
    pub fn reference_checkpoint(&mut self) -> crate::Result<Checkpoint> {
        let path = self.results_dir.join("ref8.ckpt");
        if path.exists() {
            return Checkpoint::load(&path);
        }
        crate::info!("training 8-bit reference checkpoint ({} steps)", self.base_steps);
        let ck = self.train_uniform(8, self.base_steps, 0)?;
        ck.save(&path)?;
        Ok(ck)
    }

    fn train_uniform(&mut self, b: u32, steps: usize, seed: u64) -> crate::Result<Checkpoint> {
        let bits = BitsConfig::uniform(&self.graph, b);
        let init = self.rt.init_checkpoint()?;
        let mut state = TrainState::new(init);
        let cfg = TrainConfig {
            steps,
            lr0: 0.02,
            seed,
            ..TrainConfig::default()
        };
        let log_ = finetune(&mut self.rt, &mut state, &self.data, &bits.to_f32(), &cfg)?;
        crate::info!(
            "base {}-bit: final train loss {:.4} metric {:.4}",
            b,
            log_.losses.last().copied().unwrap_or(f32::NAN),
            log_.metrics.last().copied().unwrap_or(f32::NAN)
        );
        Ok(state.params)
    }

    /// Evaluate a checkpoint at a uniform precision.
    pub fn eval_uniform(&mut self, ck: &Checkpoint, b: u32) -> crate::Result<EvalResult> {
        let bits = BitsConfig::uniform(&self.graph, b);
        evaluate(&mut self.rt, ck, &self.data, &bits.to_f32(), self.eval_batches)
    }

    // -- gains -----------------------------------------------------------------

    /// Method gains, computed once per (model, method) and cached in memory
    /// + on disk (`results/<model>/gains_<method>.json`).
    pub fn gains(&mut self, kind: MethodKind) -> crate::Result<GainEstimate> {
        if let Some(g) = self.gain_cache.get(kind.name()) {
            return Ok(g.clone());
        }
        let path = self.results_dir.join(format!("gains_{}.json", kind.name()));
        if path.exists() {
            let v = jsonio::parse_file(&path)?;
            let est = GainEstimate {
                method: kind,
                per_layer: v
                    .at(&["per_layer"])
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .collect(),
                wall_seconds: v.at(&["wall_seconds"]).as_f64().unwrap_or(0.0),
            };
            if est.per_layer.len() == self.graph.layers.len() {
                self.gain_cache.insert(kind.name(), est.clone());
                return Ok(est);
            }
        }
        let ckpt4 = self.base_checkpoint()?;
        // ALPS probes and HAWQ draws are independent jobs: fan them out
        // over per-worker backends when a spawner is registered and more
        // than one worker is configured.  Bit-identical either way.
        let parallelizable = matches!(kind, MethodKind::Alps | MethodKind::HawqV3);
        let est = match (&self.spawner, parallelizable && self.workers > 1) {
            (Some(spawner), true) => {
                crate::info!(
                    "estimating {} gains on {} workers",
                    kind.name(),
                    self.workers
                );
                methods::estimate_gains_parallel(
                    kind,
                    spawner,
                    self.rt.manifest().task,
                    &self.graph,
                    &ckpt4,
                    &self.data,
                    &self.mcfg,
                    self.workers,
                )?
            }
            _ => methods::estimate_gains(
                kind,
                &mut self.rt,
                &self.graph,
                &ckpt4,
                &self.data,
                &self.mcfg,
            )?,
        };
        let payload = Json::obj(vec![
            (
                "per_layer",
                Json::arr(est.per_layer.iter().map(|&g| Json::num(g))),
            ),
            ("wall_seconds", Json::num(est.wall_seconds)),
        ]);
        std::fs::write(&path, payload.to_string_compact())?;
        self.gain_cache.insert(kind.name(), est.clone());
        Ok(est)
    }

    // -- full pipeline -----------------------------------------------------------

    /// Select bits for (method, budget fraction of the 4-bit cost).
    pub fn select(&mut self, kind: MethodKind, budget_frac: f64) -> crate::Result<BitsConfig> {
        let budget = self.graph.budget_at(budget_frac, self.mcfg.b_hi);
        let gains = if kind.is_gain_based() {
            Some(self.gains(kind)?.per_layer)
        } else {
            None
        };
        let (bits, _) = methods::select(kind, &self.graph, gains.as_deref(), budget, &self.mcfg)?;
        Ok(bits)
    }

    /// Resolve the winning stored run at `budget` into its
    /// [`BitsConfig`] — the `mpq serve --bits-from` path.  Picks the
    /// best-metric record for this model at the exact budget (falling
    /// back to the nearest stored budget with a warning) and re-derives
    /// the knapsack selection from that record's method, reusing the
    /// on-disk gain cache the sweep left behind.
    pub fn bits_from_store(
        &mut self,
        store: &ResultStore,
        budget: f64,
    ) -> crate::Result<(RunRecord, BitsConfig)> {
        let rec = store.best_at_budget(&self.model, budget).ok_or_else(|| {
            crate::err!(
                "no run records for model '{}' in {} — run `mpq sweep` or `mpq exp` first",
                self.model,
                store.path().display()
            )
        })?;
        if rec.budget_frac.to_bits() != budget.to_bits() {
            crate::warn!(
                "no stored run at budget {budget}; using nearest stored budget {}",
                rec.budget_frac
            );
        }
        let kind = MethodKind::parse(&rec.method)?;
        let bits = self.select(kind, rec.budget_frac)?;
        Ok((rec, bits))
    }

    /// Resolve the store's whole accuracy/cost frontier for this model
    /// into servable configs — the `mpq serve --frontier-from` path.
    ///
    /// One entry per distinct finite stored budget `>= floor`, sorted by
    /// budget **descending** (level 0 = most expensive = most accurate),
    /// each resolved like [`bits_from_store`](Self::bits_from_store): the
    /// best-metric record at that budget, knapsack selection re-derived
    /// from its method.  The SLO controller walks *down* this list under
    /// overload and back *up* when calm.
    pub fn frontier_from_store(
        &mut self,
        store: &ResultStore,
        floor: f64,
    ) -> crate::Result<Vec<(RunRecord, BitsConfig)>> {
        let mut budgets: Vec<f64> = store
            .records()
            .iter()
            .filter(|r| r.model == self.model && r.budget_frac.is_finite())
            .map(|r| r.budget_frac)
            .filter(|&b| b >= floor)
            .collect();
        budgets.sort_by(|a, b| b.partial_cmp(a).unwrap());
        budgets.dedup_by(|a, b| a.to_bits() == b.to_bits());
        crate::ensure!(
            !budgets.is_empty(),
            "no stored budgets >= {floor} for model '{}' in {} — run `mpq sweep` first",
            self.model,
            store.path().display()
        );
        let mut out = Vec::with_capacity(budgets.len());
        for b in budgets {
            out.push(self.bits_from_store(store, b)?);
        }
        Ok(out)
    }

    /// Run one (method, budget, seed) experiment end to end.
    pub fn run_one(
        &mut self,
        kind: MethodKind,
        budget_frac: f64,
        seed: u64,
    ) -> crate::Result<RunRecord> {
        let t0 = Instant::now();
        let bits = self.select(kind, budget_frac)?;
        let ckpt4 = self.base_checkpoint()?;
        let ck = methods::prepare_mp_checkpoint(&ckpt4, &self.graph, &bits, self.mcfg.b_hi)?;
        let mut state = TrainState::new(ck);
        let tcfg = TrainConfig {
            steps: self.ft_steps,
            lr0: 0.005,
            seed,
            ..TrainConfig::default()
        };
        finetune(&mut self.rt, &mut state, &self.data, &bits.to_f32(), &tcfg)?;
        let eval = evaluate(
            &mut self.rt,
            &state.params,
            &self.data,
            &bits.to_f32(),
            self.eval_batches,
        )?;
        let groups_at_lo = self
            .graph
            .groups
            .iter()
            .filter(|g| {
                let li = g.layer_idx[0];
                bits.bits[self.graph.layers[li].qindex] == self.mcfg.b_lo
            })
            .count();
        Ok(RunRecord {
            model: self.model.clone(),
            method: kind.name().to_string(),
            budget_frac,
            seed,
            metric: eval.metric,
            loss: eval.loss,
            groups_at_lo,
            compression: quant::compression_ratio(&self.graph, &bits),
            gbops: quant::gbops(&self.graph, &bits),
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Budget × seed sweep for a set of methods, with JSONL resume.
    pub fn sweep(
        &mut self,
        kinds: &[MethodKind],
        budget_fracs: &[f64],
        seeds: &[u64],
        store: &mut ResultStore,
    ) -> crate::Result<Vec<RunRecord>> {
        let mut out = Vec::new();
        for &kind in kinds {
            for &frac in budget_fracs {
                for &seed in seeds {
                    if let Some(existing) = store.find(&self.model, kind.name(), frac, seed) {
                        out.push(existing);
                        continue;
                    }
                    crate::info!(
                        "run {} {} budget={:.0}% seed={}",
                        self.model,
                        kind.name(),
                        frac * 100.0,
                        seed
                    );
                    let rec = self.run_one(kind, frac, seed)?;
                    store.append(&rec)?;
                    out.push(rec);
                }
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Job pool: fan independent jobs over worker threads
// ---------------------------------------------------------------------------

/// Run `jobs` of independent work items across `workers` threads.  Each
/// worker invokes `make_worker_state` once (e.g. to open its own backend —
/// PJRT clients are not Sync) and then processes items off a shared
/// **FIFO** queue (front-pop, so a long-running head job never strands
/// the tail on one worker).  Results are returned in input order.
///
/// Error semantics: the first error wins and ends the pool early — every
/// worker checks the error slot *before* popping its next item, so the
/// remaining queue is abandoned rather than drained.
pub fn job_pool<T, S, R>(
    items: Vec<T>,
    workers: usize,
    make_worker_state: impl Fn() -> crate::Result<S> + Sync,
    run: impl Fn(&mut S, T) -> crate::Result<R> + Sync,
) -> crate::Result<Vec<R>>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    let queue =
        std::sync::Mutex::new(items.into_iter().enumerate().collect::<VecDeque<_>>());
    let results = std::sync::Mutex::new(Vec::<(usize, R)>::with_capacity(n));
    let err = std::sync::Mutex::new(None::<crate::error::Error>);
    // Never spawn more workers than jobs: surplus workers would pay
    // make_worker_state (a full backend open) just to pop an empty queue.
    let n_workers = workers.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| {
                let mut state = match make_worker_state() {
                    Ok(s) => s,
                    Err(e) => {
                        err.lock().unwrap().get_or_insert(e);
                        return;
                    }
                };
                loop {
                    // Bail before popping: once any worker records an
                    // error the rest of the queue must not be drained.
                    if err.lock().unwrap().is_some() {
                        return;
                    }
                    let item = { queue.lock().unwrap().pop_front() };
                    let Some((idx, item)) = item else { return };
                    match run(&mut state, item) {
                        Ok(r) => results.lock().unwrap().push((idx, r)),
                        Err(e) => {
                            err.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    }
                }
            });
        }
    });
    if let Some(e) = err.into_inner().unwrap() {
        return Err(e);
    }
    let mut results = results.into_inner().unwrap();
    crate::ensure!(results.len() == n, "job pool lost results");
    results.sort_by_key(|(i, _)| *i);
    Ok(results.into_iter().map(|(_, r)| r).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> RunRecord {
        RunRecord {
            model: "m".into(),
            method: "eagl".into(),
            budget_frac: 0.7,
            seed: 3,
            metric: 0.91,
            loss: 0.3,
            groups_at_lo: 5,
            compression: 9.1,
            gbops: 1.25,
            wall_s: 2.0,
        }
    }

    #[test]
    fn run_record_json_round_trip() {
        let rec = sample_record();
        let line = rec.to_json().to_string_compact();
        let back = RunRecord::from_json(&jsonio::parse(&line).unwrap()).unwrap();
        assert_eq!(back.model, rec.model);
        assert_eq!(back.method, rec.method);
        assert_eq!(back.seed, rec.seed);
        assert!((back.metric - rec.metric).abs() < 1e-12);
        assert!((back.loss - rec.loss).abs() < 1e-12);
        assert_eq!(back.groups_at_lo, rec.groups_at_lo);
        assert!((back.compression - rec.compression).abs() < 1e-12);
    }

    #[test]
    fn run_record_rejects_nan_metric() {
        let mut rec = sample_record();
        rec.metric = f64::NAN;
        // Non-finite numbers serialize as null...
        let line = rec.to_json().to_string_compact();
        assert!(line.contains("\"metric\":null"), "{line}");
        // ...and a null/missing required field parses to None.
        let v = jsonio::parse(&line).unwrap();
        assert!(RunRecord::from_json(&v).is_none());
    }

    #[test]
    fn run_record_missing_optional_fields_default() {
        // Only required fields present: loss defaults to NaN, counters to 0.
        let v = jsonio::parse(
            r#"{"model":"m","method":"eagl","budget_frac":0.5,"seed":1,"metric":0.8}"#,
        )
        .unwrap();
        let rec = RunRecord::from_json(&v).unwrap();
        assert!(rec.loss.is_nan());
        assert_eq!(rec.groups_at_lo, 0);
        assert_eq!(rec.compression, 0.0);
        // Missing a required field → None.
        let v = jsonio::parse(r#"{"model":"m","method":"eagl","metric":0.8}"#).unwrap();
        assert!(RunRecord::from_json(&v).is_none());
    }

    #[test]
    fn from_json_diag_names_missing_and_defaulted_fields() {
        // Missing required fields are listed and kill the record.
        let v = jsonio::parse(r#"{"model":"m","method":"eagl","metric":0.8}"#).unwrap();
        let p = RunRecord::from_json_diag(&v);
        assert!(p.record.is_none());
        assert_eq!(p.missing, vec!["budget_frac", "seed"]);
        // Missing optional fields are listed but defaulted.
        let v = jsonio::parse(
            r#"{"model":"m","method":"eagl","budget_frac":0.5,"seed":1,"metric":0.8,"loss":0.2}"#,
        )
        .unwrap();
        let p = RunRecord::from_json_diag(&v);
        let rec = p.record.unwrap();
        assert!(p.missing.is_empty());
        assert_eq!(p.defaulted, vec!["groups_at_lo", "compression", "gbops", "wall_s"]);
        assert!((rec.loss - 0.2).abs() < 1e-12);
        assert_eq!(rec.compression, 0.0);
        // A fully populated record flags nothing — including wall_s: 0,
        // which the experiment scheduler writes on purpose.
        let mut full = sample_record();
        full.wall_s = 0.0;
        let v = jsonio::parse(&full.to_json().to_string_compact()).unwrap();
        let p = RunRecord::from_json_diag(&v);
        assert!(p.missing.is_empty() && p.defaulted.is_empty());
    }

    #[test]
    fn job_pool_preserves_order() {
        let items: Vec<u32> = (0..37).collect();
        let out = job_pool(items, 4, || Ok(0u32), |_, x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn job_pool_is_fifo_with_one_worker() {
        let order = std::sync::Mutex::new(Vec::new());
        let items: Vec<u32> = (0..10).collect();
        let out = job_pool(
            items,
            1,
            || Ok(()),
            |_, x| {
                order.lock().unwrap().push(x);
                Ok(x)
            },
        )
        .unwrap();
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        // Front-pop: processed in submission order, not reversed.
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn job_pool_error_stops_draining() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ran = AtomicUsize::new(0);
        let items: Vec<u32> = (0..100).collect();
        let res = job_pool(
            items,
            1,
            || Ok(()),
            |_, x| {
                ran.fetch_add(1, Ordering::SeqCst);
                if x == 0 {
                    crate::bail!("boom")
                } else {
                    Ok(x)
                }
            },
        );
        assert!(res.is_err());
        // FIFO: the single worker hits the failing head item first and
        // must abandon the other 99 jobs instead of draining them.
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn job_pool_propagates_errors() {
        let items: Vec<u32> = (0..5).collect();
        let res = job_pool(
            items,
            2,
            || Ok(()),
            |_, x| {
                if x == 3 {
                    crate::bail!("boom")
                } else {
                    Ok(x)
                }
            },
        );
        assert!(res.is_err());
    }
}
