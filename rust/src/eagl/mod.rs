//! EAGL — Entropy Approximation Guided Layer selection (paper §3.3,
//! Algorithm 2, Appendix E).
//!
//! `G_l = H(p̂_l^b)`: the Shannon entropy of the empirical distribution of
//! layer *l*'s quantized weight codes at the checkpoint precision `b`.
//! Needs only the trained checkpoint — no training data, no accelerator —
//! which is exactly the paper's headline: 3.15 CPU-*seconds* for ResNet-50
//! vs hours of GPU time for ALPS/HAWQ (Table 3).
//!
//! This is the native host implementation; it is cross-checked against the
//! L1 Pallas histogram kernel through the `eagl_step` artifact
//! (rust/tests/runtime_integration.rs) and against the paper's Appendix E
//! reference semantics in unit tests here.
//!
//! Codes outside the quantizer's clamp range are a *caller* bug (the
//! in-repo producers clamp by construction), so they surface as a
//! [`crate::error::Error`] — not a release-mode index panic.

use crate::ckpt::Checkpoint;
use crate::graph::Graph;
use crate::quant::{qrange_signed, weight_codes_into};

/// Entropy (bits) of the empirical distribution of `codes`, each in
/// [-2^(b-1), 2^(b-1)-1].  Matches Appendix E: entropy of (p + eps).
/// Errors when a code falls outside the quantizer range.
pub fn entropy_of_codes(codes: &[i32], bits: u32) -> crate::Result<f64> {
    let mut hist = Vec::new();
    entropy_of_codes_into(codes, bits, &mut hist)
}

/// Scratch-buffer variant of [`entropy_of_codes`]: `hist` is cleared,
/// resized and reused here, so per-layer loops
/// ([`checkpoint_entropies`]) allocate nothing per call.
pub fn entropy_of_codes_into(
    codes: &[i32],
    bits: u32,
    hist: &mut Vec<u64>,
) -> crate::Result<f64> {
    let n_bins = 1usize << bits;
    let (qn, qp) = qrange_signed(bits);
    hist.clear();
    hist.resize(n_bins, 0);
    for &c in codes {
        crate::ensure!(
            c as f32 >= qn && c as f32 <= qp,
            "weight code {c} outside [{qn}, {qp}] for a {bits}-bit quantizer"
        );
        hist[(c - qn as i32) as usize] += 1;
    }
    let n = codes.len() as f64;
    let eps = 1e-10;
    let mut h = 0.0;
    for &count in hist.iter() {
        let p = count as f64 / n + eps;
        h -= p * p.log2();
    }
    Ok(h)
}

/// EAGL entropy of one weight tensor under its learned step size.
pub fn layer_entropy(w: &[f32], step: f32, bits: u32) -> crate::Result<f64> {
    let mut codes = Vec::with_capacity(w.len());
    let mut hist = Vec::new();
    layer_entropy_into(w, step, bits, &mut codes, &mut hist)
}

/// Scratch-buffer variant of [`layer_entropy`] — the single home of the
/// step normalization (`|s| clamped away from 0`), shared by the one-off
/// and per-layer-loop callers so the rule cannot fork.
pub fn layer_entropy_into(
    w: &[f32],
    step: f32,
    bits: u32,
    codes: &mut Vec<i32>,
    hist: &mut Vec<u64>,
) -> crate::Result<f64> {
    let s = step.abs().max(1e-8);
    weight_codes_into(w, s, bits, codes);
    entropy_of_codes_into(codes, bits, hist)
}

/// Per-layer EAGL entropies for a whole checkpoint, in qindex order
/// (Algorithm 2).  Fixed layers are scored at their pinned precision —
/// they never enter the knapsack, but the values are reported for Fig. 2.
/// The code and histogram buffers are hoisted out of the per-layer loop.
pub fn checkpoint_entropies(graph: &Graph, ck: &Checkpoint, ckpt_bits: u32) -> crate::Result<Vec<f64>> {
    let mut out = vec![0.0; graph.layers.len()];
    let mut codes: Vec<i32> = Vec::new();
    let mut hist: Vec<u64> = Vec::new();
    for layer in &graph.layers {
        let base = layer.name.replace('.', "/");
        let w = ck
            .get(&format!("{base}/w"))
            .ok_or_else(|| crate::err!("checkpoint missing {base}/w"))?;
        let s = ck
            .get(&format!("{base}/sw"))
            .ok_or_else(|| crate::err!("checkpoint missing {base}/sw"))?;
        let bits = layer.fixed_bits.unwrap_or(ckpt_bits);
        out[layer.qindex] = layer_entropy_into(w.f32s(), s.item(), bits, &mut codes, &mut hist)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn uniform_codes_have_max_entropy() {
        // All 16 4-bit codes equally often → H = 4 bits.
        let codes: Vec<i32> = (0..160).map(|i| (i % 16) - 8).collect();
        let h = entropy_of_codes(&codes, 4).unwrap();
        assert!((h - 4.0).abs() < 1e-6, "H = {h}");
    }

    #[test]
    fn constant_codes_have_zero_entropy() {
        let codes = vec![3i32; 1000];
        let h = entropy_of_codes(&codes, 4).unwrap();
        assert!(h.abs() < 1e-4, "H = {h}");
    }

    #[test]
    fn out_of_range_code_is_an_error_not_a_panic() {
        // 99 has no bin in a 4-bit histogram: must error in release and
        // debug alike (previously a debug_assert + release index panic).
        let err = entropy_of_codes(&[0, 99], 4).unwrap_err().to_string();
        assert!(err.contains("outside"), "{err}");
        let err = entropy_of_codes(&[-9], 4).unwrap_err().to_string();
        assert!(err.contains("outside"), "{err}");
        // Boundary codes are fine.
        assert!(entropy_of_codes(&[-8, 7], 4).is_ok());
    }

    #[test]
    fn scratch_buffer_reuse_matches_fresh() {
        let mut hist = Vec::new();
        let a: Vec<i32> = (0..64).map(|i| (i % 16) - 8).collect();
        let b = vec![0i32; 64];
        let ha = entropy_of_codes_into(&a, 4, &mut hist).unwrap();
        let hb = entropy_of_codes_into(&b, 4, &mut hist).unwrap();
        assert_eq!(ha, entropy_of_codes(&a, 4).unwrap());
        assert_eq!(hb, entropy_of_codes(&b, 4).unwrap());
    }

    #[test]
    fn entropy_monotone_in_spread() {
        // Narrow Gaussian (most mass in few bins) < wide Gaussian.
        let mut rng = Pcg32::new(1, 1);
        let narrow: Vec<f32> = (0..4096).map(|_| rng.normal() * 0.02).collect();
        let wide: Vec<f32> = (0..4096).map(|_| rng.normal() * 0.2).collect();
        let h_narrow = layer_entropy(&narrow, 0.1, 4).unwrap();
        let h_wide = layer_entropy(&wide, 0.1, 4).unwrap();
        assert!(
            h_narrow < h_wide,
            "narrow {h_narrow} should be < wide {h_wide}"
        );
    }

    #[test]
    fn entropy_bounded_by_bits() {
        let mut rng = Pcg32::new(2, 5);
        for &bits in &[2u32, 4, 8] {
            let w: Vec<f32> = (0..2048).map(|_| rng.normal()).collect();
            let h = layer_entropy(&w, 0.3, bits).unwrap();
            assert!(h >= 0.0 && h <= bits as f64 + 1e-9, "b={bits} H={h}");
        }
    }

    #[test]
    fn matches_hand_computed_distribution() {
        // p = [0.5, 0.25, 0.25] over codes {-2,-1,0} at 2 bits →
        // H = 1.5 bits.
        let codes = vec![-2, -2, -1, 0];
        let h = entropy_of_codes(&codes, 2).unwrap();
        assert!((h - 1.5).abs() < 1e-4, "H = {h}");
    }
}
