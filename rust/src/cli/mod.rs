//! CLI argument parsing substrate (offline environment — no clap).
//!
//! Supports `mpq <subcommand> [--flag value] [--switch]` with typed
//! accessors, defaults, and generated usage text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` / `--switch` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> crate::Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                crate::ensure!(!name.is_empty(), "bare '--' is not a flag");
                // `--key=value`, `--key value`, or boolean `--switch`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> crate::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| crate::err!("--{key} expects an integer: {e}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> crate::Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| crate::err!("--{key} expects an integer: {e}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| crate::err!("--{key} expects a number: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Comma-separated f64 list flag.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> crate::Result<Vec<f64>> {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|e| crate::err!("--{key}: bad number '{s}': {e}"))
                })
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    /// Reject flags a subcommand does not understand.  A misspelled flag
    /// (`mpq run --budgets 0.7`) silently falling back to the default is
    /// the worst failure mode a sweep CLI can have, so every subcommand
    /// validates its flag set; the error names the offender, suggests the
    /// closest valid flag, and lists what is accepted.
    pub fn ensure_known_flags(&self, subcommand: &str, allowed: &[&str]) -> crate::Result<()> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                let hint = match closest(key, allowed.iter().copied()) {
                    Some(s) => format!(" (did you mean --{s}?)"),
                    None => String::new(),
                };
                crate::bail!(
                    "unknown flag --{key} for '{subcommand}'{hint}\nvalid flags: {}",
                    allowed
                        .iter()
                        .map(|f| format!("--{f}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
            }
        }
        Ok(())
    }
}

/// The candidate closest to `needle` by edit distance, if any is close
/// enough to plausibly be a typo (distance ≤ 2, or ≤ half the length for
/// short names).  Shared by the flag validator and the experiment-manifest
/// parser's unknown-key errors.
pub fn closest<'a>(needle: &str, candidates: impl IntoIterator<Item = &'a str>) -> Option<&'a str> {
    let mut best: Option<(usize, &str)> = None;
    for cand in candidates {
        let d = edit_distance(needle, cand);
        if best.map(|(bd, _)| d < bd).unwrap_or(true) {
            best = Some((d, cand));
        }
    }
    let (d, cand) = best?;
    let max_d = 2.max(needle.len() / 2).min(3);
    (d <= max_d).then_some(cand)
}

/// Classic Levenshtein distance (two-row DP; flag names are short).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("sweep --model qresnet20 --seeds 3 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.str("model", "x"), "qresnet20");
        assert_eq!(a.usize("seeds", 1).unwrap(), 3);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn equals_syntax_and_lists() {
        let a = parse("run --budgets=0.9,0.7 --methods eagl,alps");
        assert_eq!(a.f64_list("budgets", &[]).unwrap(), vec![0.9, 0.7]);
        assert_eq!(a.list("methods", &[]), vec!["eagl", "alps"]);
    }

    #[test]
    fn defaults() {
        let a = parse("info");
        assert_eq!(a.f64("lr", 0.01).unwrap(), 0.01);
        assert_eq!(a.str("model", "qresnet20"), "qresnet20");
        assert_eq!(a.list("methods", &["eagl"]), vec!["eagl"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("report file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn unknown_flags_are_rejected_with_suggestion() {
        let a = parse("run --budgets 0.7");
        let err = a
            .ensure_known_flags("run", &["budget", "seed", "method"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--budgets"), "{err}");
        assert!(err.contains("did you mean --budget?"), "{err}");
        assert!(err.contains("valid flags"), "{err}");
        // Known flags pass.
        let a = parse("run --budget 0.7 --seed 3");
        assert!(a.ensure_known_flags("run", &["budget", "seed", "method"]).is_ok());
    }

    #[test]
    fn closest_suggests_only_plausible_typos() {
        assert_eq!(closest("budgets", ["budget", "seed"]), Some("budget"));
        assert_eq!(closest("modle", ["model", "method"]), Some("model"));
        assert_eq!(closest("zzzzzz", ["budget", "seed"]), None);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("budgets", "budget"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
