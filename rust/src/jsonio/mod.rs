//! Minimal JSON substrate (parser + emitter).
//!
//! The build environment is offline, so serde is unavailable; manifests
//! (`artifacts/*.manifest.json`) and the coordinator's result store are
//! small and schema-stable, which this hand-rolled implementation covers.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (not produced by our Python emitter).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Numbers are kept as f64 (adequate: our manifests
/// only carry shapes, counts and float metrics).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` → `vec![1usize,2,3]`.
    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> crate::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        crate::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> crate::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {}: {e}", path.display()))?;
    parse(&text)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> crate::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| crate::err!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        let got = self.bump()?;
        if got != b {
            crate::bail!(
                "expected '{}' got '{}' at byte {}",
                b as char,
                got as char,
                self.pos - 1
            );
        }
        Ok(())
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => crate::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            crate::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => crate::bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => crate::bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| crate::err!("bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => crate::bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| crate::err!("invalid UTF-8 in string"))?;
                    out.push_str(chunk);
                }
            }
        }
        Ok(out)
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| crate::err!("bad number '{text}': {e}"))
    }
}

// ---------------------------------------------------------------------------
// Emitting
// ---------------------------------------------------------------------------

impl Json {
    /// Compact single-line serialization (used by the JSONL result store).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf; emit null so the line stays
                    // parseable (readers treat null as "missing").
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert!(v.at(&["a"]).as_arr().unwrap()[2].get("b").unwrap().is_null());
        assert_eq!(v.at(&["c"]).as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_emit_null_and_do_not_parse() {
        // Emission: NaN/Inf become null so every emitted line re-parses.
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string_compact(), "null");
        }
        let obj = Json::obj(vec![("a", Json::num(f64::NAN)), ("b", Json::num(1.5))]);
        let text = obj.to_string_compact();
        assert_eq!(text, r#"{"a":null,"b":1.5}"#);
        let back = parse(&text).unwrap();
        assert!(back.at(&["a"]).is_null());
        // Parsing: bare NaN/Infinity are not JSON.
        assert!(parse("NaN").is_err());
        assert!(parse("Infinity").is_err());
        assert!(parse("-Infinity").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse("\"caf\u{00e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }

    #[test]
    fn usize_vec_helper() {
        let v = parse("[3, 4, 5]").unwrap();
        assert_eq!(v.usize_vec(), vec![3, 4, 5]);
    }
}
