//! Logging substrate (offline environment — no `log` crate).
//!
//! Level-filtered stderr logging via the [`info!`](crate::info),
//! [`warn!`](crate::warn) and [`debug!`](crate::debug) macros.  The
//! filter is read once from `MPQ_LOG` and can be overridden
//! programmatically with [`set_level`].
//!
//! `MPQ_LOG` is a comma-separated spec: a bare level word sets the
//! default, `target=level` entries set per-module levels, where a target
//! matches a module path segment-wise (`serve` matches
//! `mpq::serve::engine`; `serve::controller` matches exactly that
//! subtree).  The most specific (longest) matching target wins.
//!
//! ```text
//! MPQ_LOG=debug                   # everything at debug
//! MPQ_LOG=warn,serve=debug        # quiet, except the serve subsystem
//! MPQ_LOG=info,serve::http=error  # silence front-door chatter only
//! ```
//!
//! This keeps `--trace-out` / `--latency-out` runs clean: subsystem
//! progress chatter goes through these macros (stderr, filterable),
//! while machine-parsed gate lines (`serve OK`, report tables) stay on
//! stdout.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

pub const ERROR: u8 = 1;
pub const WARN: u8 = 2;
pub const INFO: u8 = 3;
pub const DEBUG: u8 = 4;

/// Parsed `MPQ_LOG` filter: a default level plus per-target overrides.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Filter {
    /// Level applied when no target matches.
    pub default: u8,
    /// `(target, level)` pairs; targets are `::`-separated module-path
    /// fragments (leading `mpq::` optional).
    pub targets: Vec<(String, u8)>,
}

impl Filter {
    /// Effective level for a `module_path!()` string: the longest
    /// matching target wins, else the default.
    pub fn level_for(&self, module: &str) -> u8 {
        let mut best: Option<(usize, u8)> = None;
        for (target, lvl) in &self.targets {
            if target_matches(target, module) {
                let len = target.len();
                if best.map_or(true, |(blen, _)| len > blen) {
                    best = Some((len, *lvl));
                }
            }
        }
        best.map(|(_, l)| l).unwrap_or(self.default)
    }
}

/// Does `target` name `module` or one of its ancestors?  Targets match
/// segment-wise anywhere in the path, so `serve` matches
/// `mpq::serve::engine` and `serve::engine` matches it too, but `erve`
/// does not.
fn target_matches(target: &str, module: &str) -> bool {
    if target.is_empty() {
        return false;
    }
    let mut hay = module;
    while let Some(pos) = hay.find(target) {
        let before_ok = pos == 0 || hay[..pos].ends_with("::");
        let after = &hay[pos + target.len()..];
        let after_ok = after.is_empty() || after.starts_with("::");
        if before_ok && after_ok {
            return true;
        }
        // Skip past this occurrence and keep scanning.
        match hay.get(pos + 1..) {
            Some(rest) => hay = rest,
            None => break,
        }
    }
    false
}

fn parse_level(word: &str) -> Option<u8> {
    match word {
        "error" => Some(ERROR),
        "warn" => Some(WARN),
        "info" => Some(INFO),
        "debug" => Some(DEBUG),
        _ => None,
    }
}

/// Parse an `MPQ_LOG` spec.  Unknown words are ignored (the default
/// stays `info`), so a typo degrades to noise, not a crash.
pub fn parse_spec(spec: &str) -> Filter {
    let mut f = Filter { default: INFO, targets: Vec::new() };
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        match entry.split_once('=') {
            None => {
                if let Some(l) = parse_level(entry) {
                    f.default = l;
                }
            }
            Some((target, word)) => {
                if let Some(l) = parse_level(word.trim()) {
                    f.targets.push((target.trim().to_string(), l));
                }
            }
        }
    }
    f
}

/// `set_level` override; 0 = none (use the `MPQ_LOG` filter).
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

static FILTER: OnceLock<Filter> = OnceLock::new();

fn filter() -> &'static Filter {
    FILTER.get_or_init(|| parse_spec(&std::env::var("MPQ_LOG").unwrap_or_default()))
}

/// Current default log level (the `set_level` override when active, else
/// the `MPQ_LOG` default).  Per-module targets may still differ — see
/// [`enabled`].
pub fn level() -> u8 {
    let o = OVERRIDE.load(Ordering::Relaxed); // relaxed-ok: single u8 level flag; no data is guarded by it
    if o != 0 {
        return o;
    }
    filter().default
}

/// Force the log level globally (tests, CLI flags).  Overrides both the
/// `MPQ_LOG` default and its per-target entries.
pub fn set_level(l: u8) {
    OVERRIDE.store(l, Ordering::Relaxed); // relaxed-ok: single u8 level flag; no data is guarded by it
}

/// Is `lvl` enabled for `module`?
pub fn enabled(lvl: u8, module: &str) -> bool {
    let o = OVERRIDE.load(Ordering::Relaxed); // relaxed-ok: single u8 level flag; no data is guarded by it
    if o != 0 {
        return lvl <= o;
    }
    lvl <= filter().level_for(module)
}

/// Macro back end: emit one line to stderr if `lvl` is enabled for
/// `module` (a `module_path!()` string; the crate prefix is stripped on
/// output).
pub fn log(lvl: u8, name: &str, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(lvl, module) {
        let short = module.strip_prefix("mpq::").unwrap_or(module);
        eprintln!("[{name} {short}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logging::log(
            $crate::logging::INFO, "INFO", module_path!(), format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logging::log(
            $crate::logging::WARN, "WARN", module_path!(), format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logging::log(
            $crate::logging::DEBUG, "DEBUG", module_path!(), format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(ERROR < WARN && WARN < INFO && INFO < DEBUG);
    }

    #[test]
    fn set_level_wins() {
        set_level(WARN);
        assert_eq!(level(), WARN);
        assert!(!enabled(DEBUG, "mpq::serve::engine"));
        // Disabled level is a no-op (must not panic).
        crate::debug!("hidden {}", 1);
        set_level(INFO);
        crate::info!("shown {}", 2);
    }

    #[test]
    fn spec_parses_default_and_targets() {
        let f = parse_spec("warn,serve=debug,serve::http=error");
        assert_eq!(f.default, WARN);
        assert_eq!(f.level_for("mpq::serve::engine"), DEBUG);
        assert_eq!(f.level_for("mpq::serve::http"), ERROR);
        assert_eq!(f.level_for("mpq::serve::http::parser"), ERROR);
        assert_eq!(f.level_for("mpq::kernels::packed"), WARN);
        // Unknown words degrade to the default, never crash.
        assert_eq!(parse_spec("loud,nope=verbose").default, INFO);
        assert_eq!(parse_spec("").default, INFO);
        assert_eq!(parse_spec("debug").default, DEBUG);
    }

    #[test]
    fn target_matching_is_segment_wise() {
        assert!(target_matches("serve", "mpq::serve::engine"));
        assert!(target_matches("serve::engine", "mpq::serve::engine"));
        assert!(target_matches("mpq::serve", "mpq::serve::engine"));
        assert!(target_matches("engine", "mpq::serve::engine"));
        assert!(!target_matches("erve", "mpq::serve::engine"));
        assert!(!target_matches("serve::eng", "mpq::serve::engine"));
        assert!(!target_matches("", "mpq::serve"));
        // Longest match wins over a shorter one.
        let f = parse_spec("serve=error,serve::engine=debug");
        assert_eq!(f.level_for("mpq::serve::engine"), DEBUG);
        assert_eq!(f.level_for("mpq::serve::batcher"), ERROR);
    }
}
