//! Logging substrate (offline environment — no `log` crate).
//!
//! Level-filtered stderr logging via the [`info!`](crate::info),
//! [`warn!`](crate::warn) and [`debug!`](crate::debug) macros.  The level
//! is read once from `MPQ_LOG` (`debug|info|warn|error`, default `info`)
//! and can be overridden programmatically with [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 1;
pub const WARN: u8 = 2;
pub const INFO: u8 = 3;
pub const DEBUG: u8 = 4;

/// 0 = not yet initialized from the environment.
static LEVEL: AtomicU8 = AtomicU8::new(0);

/// Current log level, lazily initialized from `MPQ_LOG`.
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 0 {
        return l;
    }
    let l = match std::env::var("MPQ_LOG").as_deref() {
        Ok("debug") => DEBUG,
        Ok("warn") => WARN,
        Ok("error") => ERROR,
        _ => INFO,
    };
    LEVEL.store(l, Ordering::Relaxed);
    l
}

/// Force the log level (tests, CLI flags).
pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

/// Macro back end: emit one line to stderr if `lvl` is enabled.
pub fn log(lvl: u8, name: &str, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        eprintln!("[{name}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::INFO, "INFO", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::WARN, "WARN", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::DEBUG, "DEBUG", format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(ERROR < WARN && WARN < INFO && INFO < DEBUG);
    }

    #[test]
    fn set_level_wins() {
        set_level(WARN);
        assert_eq!(level(), WARN);
        // Disabled level is a no-op (must not panic).
        crate::debug!("hidden {}", 1);
        set_level(INFO);
        crate::info!("shown {}", 2);
    }
}
