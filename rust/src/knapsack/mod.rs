//! 0-1 Integer Knapsack solver — the optimization step of the evaluation
//! framework (paper §3.1).
//!
//! Items are the selectable link groups; the item *value* is the method's
//! accuracy-gain estimate `G_l` (summed over linked layers), the *weight*
//! is the extra BMAC cost of staying at `b_hi` instead of `b_lo`, and the
//! capacity is `budget − base_cost` (every group pays the `b_lo` cost
//! regardless).
//!
//! As in the paper (footnote 2), floating-point gains are quantized to
//! integers in 1..=10000 before the DP — the solution is ε-optimal with
//! ε = 1e-5 of the gain range — and the DP is the classic O(capacity ·
//! items) table with bitset backtracking.  Capacity is rescaled to keep
//! the DP table bounded (≤ `MAX_CAP` cells per item) without changing the
//! argmax in any practically distinguishable way.

/// Result of a knapsack run.
#[derive(Debug, Clone)]
pub struct Selection {
    /// selected[i] == true → item i stays at the higher precision.
    pub selected: Vec<bool>,
    /// Σ value over selected items (in the quantized integer scale).
    pub total_value: u64,
    /// Σ weight over selected items.
    pub total_weight: u64,
}

const GAIN_LEVELS: u64 = 10_000;
// DP column bound.  Weights are rescaled (÷ceil) when capacity exceeds
// this, bounding the table at n×256K cells.  The induced selection error
// is ≤ n·scale BMACs (≈0.02% of a ResNet-50-scale budget) — far below the
// paper's own 1e-4 gain-quantization granularity (footnote 2), so the
// solution stays ε-optimal in the paper's sense; formally,
// exact(capacity − n·scale) ≤ solve_01(capacity) ≤ exact(capacity), which
// rust/tests/prop_invariants.rs checks against an unscaled exact solver.
// Perf pass §3: 4M→256K took the 54-item/1M-BMAC paper-scale instance
// from 156 ms to 40 ms and the 1000-item stress case from 17.5 s to 1.5 s
// with identical selections in every regression test.
pub const MAX_CAP: usize = 1 << 18;

/// Quantize float gains to integers 1..=10000 (paper footnote 2).
/// All-equal gains map to the same mid value, preserving ties.
pub fn quantize_gains(gains: &[f64]) -> Vec<u64> {
    let lo = gains.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = gains.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi - lo).is_finite() || hi - lo < 1e-300 {
        return vec![GAIN_LEVELS / 2; gains.len()];
    }
    gains
        .iter()
        .map(|&g| 1 + ((g - lo) / (hi - lo) * (GAIN_LEVELS - 1) as f64).round() as u64)
        .collect()
}

/// Exact 0-1 knapsack via DP over capacity, O(cap · n) time, O(cap) value
/// array + n×cap bit matrix for backtracking.
pub fn solve_01(values: &[u64], weights: &[u64], capacity: u64) -> Selection {
    assert_eq!(values.len(), weights.len());
    let n = values.len();
    // Rescale weights if the capacity is too fine-grained for the DP table.
    let scale = (capacity as usize / MAX_CAP).max(1) as u64;
    let ws: Vec<u64> = weights.iter().map(|&w| (w + scale - 1) / scale).collect();
    let cap = (capacity / scale) as usize;

    let mut best = vec![0u64; cap + 1];
    // take[i] bit c set → item i taken at column c.
    let words = cap / 64 + 1;
    let mut take = vec![0u64; n * words];
    for i in 0..n {
        let w = ws[i] as usize;
        let v = values[i];
        if w > cap {
            continue;
        }
        // Descending so each item is used at most once.  take[i]'s row
        // starts zeroed and each (i, c) cell is visited exactly once, so
        // only the improving branch needs a write (perf pass §3: dropping
        // the else-branch clear removed a read-modify-write from the
        // not-taken path — ~1.9x on the 54-item paper-scale instance).
        for c in (w..=cap).rev() {
            let cand = best[c - w] + v;
            if cand > best[c] {
                best[c] = cand;
                take[i * words + c / 64] |= 1 << (c % 64);
            }
        }
    }
    // Backtrack.
    let mut selected = vec![false; n];
    let mut c = cap;
    let mut total_weight = 0u64;
    for i in (0..n).rev() {
        if take[i * words + c / 64] >> (c % 64) & 1 == 1 {
            selected[i] = true;
            total_weight += weights[i];
            c -= ws[i] as usize;
        }
    }
    Selection {
        selected,
        total_value: best[cap],
        total_weight,
    }
}

/// The full layer-selection entry point: float gains → quantize → DP.
pub fn select_layers(gains: &[f64], weights: &[u64], capacity: u64) -> Selection {
    let values = quantize_gains(gains);
    solve_01(&values, weights, capacity)
}

// ---------------------------------------------------------------------------
// Greedy baselines (used by the paper's comparison, §4.1/§4.3)
// ---------------------------------------------------------------------------

/// Keep items at high precision following `order`; drop (i.e. deselect)
/// prefix items of `order` greedily until within capacity.  `order` lists
/// item indices in drop priority (first dropped first).
pub fn greedy_drop(order: &[usize], weights: &[u64], capacity: u64) -> Selection {
    let n = weights.len();
    let mut selected = vec![true; n];
    let mut total: u64 = weights.iter().sum();
    for &i in order {
        if total <= capacity {
            break;
        }
        selected[i] = false;
        total -= weights[i];
    }
    // If still above capacity (shouldn't happen when order covers all), drop rest.
    Selection {
        total_value: 0,
        total_weight: if total <= capacity { total } else { 0 },
        selected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_cases() {
        let s = solve_01(&[10], &[5], 4);
        assert!(!s.selected[0]);
        let s = solve_01(&[10], &[5], 5);
        assert!(s.selected[0]);
        assert_eq!(s.total_value, 10);
    }

    #[test]
    fn picks_optimal_subset() {
        // Classic: values 60,100,120 weights 10,20,30 cap 50 → take 2+3 = 220.
        let s = solve_01(&[60, 100, 120], &[10, 20, 30], 50);
        assert_eq!(s.selected, vec![false, true, true]);
        assert_eq!(s.total_value, 220);
        assert_eq!(s.total_weight, 50);
    }

    #[test]
    fn beats_greedy_by_value_density_trap() {
        // Greedy-by-density would take item 0 (density 6) then fail;
        // optimal takes items 1+2.
        let s = solve_01(&[30, 28, 28], &[5, 4, 4], 8);
        assert_eq!(s.total_value, 56);
    }

    #[test]
    fn quantize_preserves_order_and_ties() {
        let q = quantize_gains(&[0.0, 0.5, 0.5, 1.0]);
        assert_eq!(q[0], 1);
        assert_eq!(q[3], 10_000);
        assert_eq!(q[1], q[2]);
        assert!(q[1] > q[0] && q[3] > q[1]);
    }

    #[test]
    fn quantize_handles_constant_gains() {
        let q = quantize_gains(&[3.3; 5]);
        assert!(q.iter().all(|&v| v == q[0]));
    }

    #[test]
    fn capacity_zero_selects_nothing() {
        let s = solve_01(&[5, 5], &[1, 1], 0);
        assert!(!s.selected.iter().any(|&b| b));
    }

    #[test]
    fn exhaustive_small_instances_match_brute_force() {
        // Property check against brute force for all subsets, n<=12.
        let mut rng = crate::rng::Pcg32::new(7, 1);
        for _ in 0..50 {
            let n = 1 + rng.below(12) as usize;
            let values: Vec<u64> = (0..n).map(|_| rng.below(100) as u64 + 1).collect();
            let weights: Vec<u64> = (0..n).map(|_| rng.below(50) as u64 + 1).collect();
            let cap = rng.below(150) as u64;
            let s = solve_01(&values, &weights, cap);
            // brute force
            let mut best = 0u64;
            for mask in 0..(1u32 << n) {
                let (mut v, mut w) = (0u64, 0u64);
                for i in 0..n {
                    if mask >> i & 1 == 1 {
                        v += values[i];
                        w += weights[i];
                    }
                }
                if w <= cap {
                    best = best.max(v);
                }
            }
            assert_eq!(s.total_value, best, "v={values:?} w={weights:?} cap={cap}");
            // Reported selection is consistent and feasible.
            let w_sel: u64 = (0..n).filter(|&i| s.selected[i]).map(|i| weights[i]).sum();
            let v_sel: u64 = (0..n).filter(|&i| s.selected[i]).map(|i| values[i]).sum();
            assert!(w_sel <= cap);
            assert_eq!(v_sel, s.total_value);
        }
    }

    #[test]
    fn greedy_drop_respects_order() {
        let weights = vec![10, 10, 10, 10];
        let s = greedy_drop(&[0, 1, 2, 3], &weights, 25);
        assert_eq!(s.selected, vec![false, false, true, true]);
    }
}

pub mod mckp;
