//! Multiple-Choice Knapsack (MCKP) — the paper's §5 extension: "both
//! methods can be used with more than two precision choices by changing
//! the optimizer".
//!
//! Each selectable group becomes a *class* with one item per precision
//! choice (e.g. {2, 4, 8} bits); exactly one item per class must be
//! picked.  value(item) = gain estimate scaled by the precision's headroom
//! (see [`gain_at`]), weight(item) = BMACs at that precision.  Solved with
//! the classic DP over (class, capacity) in O(capacity · Σ choices),
//! with the same capacity rescaling bound as the 0-1 solver.

/// One precision option inside a class.
#[derive(Debug, Clone, Copy)]
pub struct Choice {
    /// Value of picking this option (already quantized to an integer).
    pub value: u64,
    /// Weight (BMACs) of this option.
    pub weight: u64,
}

/// Result: one chosen option index per class.
#[derive(Debug, Clone)]
pub struct McSelection {
    pub choice_per_class: Vec<usize>,
    pub total_value: u64,
    pub total_weight: u64,
}

const MAX_CAP: usize = 1 << 18;

/// Solve MCKP exactly (after capacity rescaling): maximize Σ value s.t.
/// Σ weight ≤ capacity, exactly one choice per class.  Returns None when
/// even the lightest choice per class exceeds capacity.
pub fn solve_mckp(classes: &[Vec<Choice>], capacity: u64) -> Option<McSelection> {
    let scale = (capacity as usize / MAX_CAP).max(1) as u64;
    let cap = (capacity / scale) as usize;
    let n = classes.len();
    if n == 0 {
        return Some(McSelection {
            choice_per_class: vec![],
            total_value: 0,
            total_weight: 0,
        });
    }
    const NEG: i64 = i64::MIN / 4;
    // dp[c] = best value at weight ≤ c after processing k classes.
    let mut dp = vec![NEG; cap + 1];
    dp[0] = 0;
    // chosen[k][c]: option picked for class k at column c.
    let mut chosen = vec![vec![u8::MAX; cap + 1]; n];
    for (k, class) in classes.iter().enumerate() {
        assert!(class.len() < u8::MAX as usize, "too many choices per class");
        let mut next = vec![NEG; cap + 1];
        for (oi, opt) in class.iter().enumerate() {
            let w = ((opt.weight + scale - 1) / scale) as usize;
            if w > cap {
                continue;
            }
            for c in w..=cap {
                if dp[c - w] == NEG {
                    continue;
                }
                let cand = dp[c - w] + opt.value as i64;
                if cand > next[c] {
                    next[c] = cand;
                    chosen[k][c] = oi as u8;
                }
            }
        }
        dp = next;
    }
    // Best reachable column.
    let (mut c, _best) = dp
        .iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .map(|(i, &v)| (i, v))?;
    if dp[c] == NEG {
        return None;
    }
    let total_value = dp[c] as u64;
    // Backtrack.
    let mut picks = vec![0usize; n];
    let mut total_weight = 0u64;
    for k in (0..n).rev() {
        let oi = chosen[k][c];
        if oi == u8::MAX {
            return None; // unreachable state — no feasible assignment
        }
        picks[k] = oi as usize;
        let opt = classes[k][oi as usize];
        total_weight += opt.weight;
        c -= ((opt.weight + scale - 1) / scale) as usize;
    }
    Some(McSelection {
        choice_per_class: picks,
        total_value,
        total_weight,
    })
}

/// Scale a per-layer gain estimate to a precision choice's value.
///
/// The binary formulation's gain `G_l` measures the benefit of `b_hi`
/// over `b_lo`.  For k choices we interpolate on the paper's own axis —
/// entropy headroom: value(b) = G_l · (b − b_min) / (b_max − b_min),
/// quantized to the standard 1..=10000 grid.  This preserves the binary
/// case exactly (value(b_lo) = 0, value(b_hi) = G).
pub fn gain_at(gain_q: u64, bits: u32, b_min: u32, b_max: u32) -> u64 {
    if b_max == b_min {
        return gain_q;
    }
    gain_q * (bits - b_min) as u64 / (b_max - b_min) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cls(opts: &[(u64, u64)]) -> Vec<Choice> {
        opts.iter().map(|&(value, weight)| Choice { value, weight }).collect()
    }

    #[test]
    fn picks_one_per_class() {
        let classes = vec![
            cls(&[(0, 2), (5, 4), (9, 8)]),
            cls(&[(0, 2), (8, 4), (9, 8)]),
        ];
        // capacity 8: best is class0 low (0,2) wait — options: (0,2)+(8,4)=8
        // w=6; (5,4)+(8,4)=13 w=8; (9,8)+(0,2)=9 w=10 infeasible.
        let sel = solve_mckp(&classes, 8).unwrap();
        assert_eq!(sel.choice_per_class, vec![1, 1]);
        assert_eq!(sel.total_value, 13);
        assert_eq!(sel.total_weight, 8);
    }

    #[test]
    fn infeasible_returns_none() {
        let classes = vec![cls(&[(1, 10)]), cls(&[(1, 10)])];
        assert!(solve_mckp(&classes, 5).is_none());
    }

    #[test]
    fn reduces_to_01_knapsack() {
        // Two choices per class with low-weight zero-value option == 0-1
        // knapsack on the deltas.
        let gains = [30u64, 28, 28];
        let extra = [5u64, 4, 4];
        let classes: Vec<Vec<Choice>> = gains
            .iter()
            .zip(&extra)
            .map(|(&g, &w)| cls(&[(0, 1), (g, 1 + w)]))
            .collect();
        // base weight 3; capacity 3 + 8 = 11 → same as 0-1 cap 8 → items 2+3.
        let sel = solve_mckp(&classes, 11).unwrap();
        assert_eq!(sel.choice_per_class, vec![0, 1, 1]);
        assert_eq!(sel.total_value, 56);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = crate::rng::Pcg32::new(13, 4);
        for _ in 0..60 {
            let n = 1 + rng.below(6) as usize;
            let classes: Vec<Vec<Choice>> = (0..n)
                .map(|_| {
                    let k = 1 + rng.below(4) as usize;
                    (0..k)
                        .map(|_| Choice {
                            value: rng.below(50) as u64,
                            weight: 1 + rng.below(20) as u64,
                        })
                        .collect()
                })
                .collect();
            let cap = rng.below(60) as u64;
            let got = solve_mckp(&classes, cap);
            // Brute force over all assignments.
            let mut best: Option<(u64, u64)> = None;
            let counts: Vec<usize> = classes.iter().map(|c| c.len()).collect();
            let total: usize = counts.iter().product();
            for mut idx in 0..total {
                let (mut v, mut w) = (0u64, 0u64);
                for (k, class) in classes.iter().enumerate() {
                    let oi = idx % counts[k];
                    idx /= counts[k];
                    v += class[oi].value;
                    w += class[oi].weight;
                }
                if w <= cap && best.map(|(bv, _)| v > bv).unwrap_or(true) {
                    best = Some((v, w));
                }
            }
            match (got, best) {
                (None, None) => {}
                (Some(s), Some((bv, _))) => {
                    assert_eq!(s.total_value, bv, "classes {classes:?} cap {cap}");
                    assert!(s.total_weight <= cap);
                }
                (g, b) => panic!("feasibility mismatch: {g:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn gain_interpolation_endpoints() {
        assert_eq!(gain_at(10_000, 2, 2, 8), 0);
        assert_eq!(gain_at(10_000, 8, 2, 8), 10_000);
        assert_eq!(gain_at(9_000, 4, 2, 8), 3_000);
    }
}
