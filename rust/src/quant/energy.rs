//! Energy model for mixed-precision inference accounting.
//!
//! The paper motivates mixed precision by NorthPole-style deployment
//! (Modha et al., 2023): integer MAC energy scales roughly with the
//! product of operand widths (≈ b² for matched weight/activation
//! precision), and weight movement scales linearly with bits.  This
//! module provides that first-order model so reports can rank
//! configurations by estimated energy as well as BMACs — the paper's
//! "lower power, higher throughput solutions" framing (§5).
//!
//! Units are normalized to an 8-bit MAC = 1.0; absolute joules depend on
//! silicon and are out of scope (DESIGN.md §3 NorthPole substitution).

use crate::graph::Graph;
use crate::quant::BitsConfig;

/// First-order energy coefficients (relative to an 8-bit MAC).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Energy of one b-bit × b-bit MAC relative to 8×8: (b/8)².
    pub mac_exponent: f64,
    /// Relative cost of moving one weight bit (per MAC-amortized access).
    pub weight_move_per_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mac_exponent: 2.0,
            weight_move_per_bit: 0.05,
        }
    }
}

impl EnergyModel {
    /// Estimated energy of one forward pass (normalized 8-bit-MAC units).
    pub fn forward_energy(&self, graph: &Graph, bits: &BitsConfig) -> f64 {
        let mut total = 0.0;
        for layer in &graph.layers {
            let b = bits.bits[layer.qindex] as f64;
            let mac = (b / 8.0).powf(self.mac_exponent);
            total += mac * layer.macs as f64;
            total += self.weight_move_per_bit * b * layer.weight_params as f64;
        }
        total
    }

    /// Energy ratio vs an all-`b_ref` network (>1 ⇒ cheaper than ref).
    pub fn savings_vs(&self, graph: &Graph, bits: &BitsConfig, b_ref: u32) -> f64 {
        let ref_cfg = BitsConfig::uniform(graph, b_ref);
        self.forward_energy(graph, &ref_cfg) / self.forward_energy(graph, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn toy() -> Graph {
        Graph::from_manifest(
            &jsonio::parse(
                r#"{"model":"toy","layers":[
              {"name":"a","kind":"conv","qindex":0,"link_group":"a",
               "macs":1000,"weight_params":100,"fixed_bits":null},
              {"name":"b","kind":"conv","qindex":1,"link_group":"b",
               "macs":1000,"weight_params":100,"fixed_bits":null}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn quadratic_mac_scaling() {
        let g = toy();
        let m = EnergyModel::default();
        let e8 = m.forward_energy(&g, &BitsConfig::uniform(&g, 8));
        let e4 = m.forward_energy(&g, &BitsConfig::uniform(&g, 4));
        let e2 = m.forward_energy(&g, &BitsConfig::uniform(&g, 2));
        assert!(e8 > e4 && e4 > e2);
        // MAC term dominates here: 4-bit ≈ ¼ of 8-bit MAC energy.
        let mac8 = 2000.0;
        let mac4 = 2000.0 * 0.25;
        assert!((e8 - (mac8 + 0.05 * 8.0 * 200.0)).abs() < 1e-9);
        assert!((e4 - (mac4 + 0.05 * 4.0 * 200.0)).abs() < 1e-9);
        let _ = e2;
    }

    #[test]
    fn savings_monotone_in_dropped_layers() {
        let g = toy();
        let m = EnergyModel::default();
        let all4 = BitsConfig::uniform(&g, 4);
        let mixed = BitsConfig::from_selection(&g, &[true, false], 4, 2);
        let all2 = BitsConfig::from_selection(&g, &[false, false], 4, 2);
        let s4 = m.savings_vs(&g, &all4, 8);
        let sm = m.savings_vs(&g, &mixed, 8);
        let s2 = m.savings_vs(&g, &all2, 8);
        assert!(s2 > sm && sm > s4 && s4 > 1.0, "{s4} {sm} {s2}");
    }
}
