//! Quantization primitives on the host side.
//!
//! Mirrors the L1/L2 LSQ fake-quantizer (`python/compile/quantizer.py`) so
//! the coordinator can compute weight codes (for EAGL), quantization error
//! norms (for HAWQ-v3's `||Q4(W) - Q2(W)||²` factor), compression ratios
//! and BMAC costs without touching the accelerator.

use crate::ckpt::Checkpoint;
use crate::graph::Graph;

/// (qn, qp) clamp bounds for a signed symmetric b-bit quantizer.
pub fn qrange_signed(bits: u32) -> (f32, f32) {
    let half = 1i64 << (bits - 1);
    (-(half as f32), (half - 1) as f32)
}

/// (qn, qp) for an unsigned b-bit quantizer (post-ReLU activations).
pub fn qrange_unsigned(bits: u32) -> (f32, f32) {
    (0.0, ((1i64 << bits) - 1) as f32)
}

/// LSQ forward: clamp(round(v/s), qn, qp) * s.
pub fn fake_quant(v: f32, s: f32, qn: f32, qp: f32) -> f32 {
    (v / s).round().clamp(qn, qp) * s
}

/// Smallest packed storage field (2, 4, or 8 bits) that holds every code
/// of a signed `bits`-bit quantizer in two's complement — the field width
/// of [`crate::kernels::packed`]'s bit-packed weight layout (4 codes/byte
/// at ≤2-bit, 2 at ≤4-bit, 1 otherwise).
pub fn storage_field_bits(bits: u32) -> u32 {
    if bits <= 2 {
        2
    } else if bits <= 4 {
        4
    } else {
        8
    }
}

/// Integer code of a weight under a signed b-bit quantizer (paper App. E).
pub fn weight_code(v: f32, s: f32, bits: u32) -> i32 {
    let (qn, qp) = qrange_signed(bits);
    (v / s).round().clamp(qn, qp) as i32
}

/// All codes of a weight tensor.
pub fn weight_codes(w: &[f32], s: f32, bits: u32) -> Vec<i32> {
    let mut out = Vec::new();
    weight_codes_into(w, s, bits, &mut out);
    out
}

/// Scratch-buffer variant of [`weight_codes`]: clears and refills `out`,
/// so per-layer loops (e.g. [`crate::eagl::checkpoint_entropies`]) reuse
/// one allocation.
pub fn weight_codes_into(w: &[f32], s: f32, bits: u32, out: &mut Vec<i32>) {
    out.clear();
    out.reserve(w.len());
    out.extend(w.iter().map(|&v| weight_code(v, s, bits)));
}

/// ||Q_b1(W) - Q_b2(W)||² — the perturbation factor in HAWQ-v3's gain
/// estimate (Appendix C).  Step sizes follow the HAWQ init rule the paper
/// describes: range/2^(b-1) with the range symmetrized about 0.
pub fn quant_error_norm2(w: &[f32], b1: u32, b2: u32) -> f64 {
    let s1 = hawq_step_size(w, b1);
    let s2 = hawq_step_size(w, b2);
    let (qn1, qp1) = qrange_signed(b1);
    let (qn2, qp2) = qrange_signed(b2);
    w.iter()
        .map(|&v| {
            let d = fake_quant(v, s1, qn1, qp1) - fake_quant(v, s2, qn2, qp2);
            (d as f64) * (d as f64)
        })
        .sum()
}

/// HAWQ step-size init: max(|min|, |max|) / 2^(b-1) (Appendix C).
pub fn hawq_step_size(w: &[f32], bits: u32) -> f32 {
    let mx = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let denom = (1i64 << (bits - 1)) as f32;
    (mx / denom).max(1e-12)
}

/// A per-layer precision assignment, indexed by `qindex`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitsConfig {
    pub bits: Vec<u32>,
}

impl BitsConfig {
    /// All selectable layers at `b`; fixed layers pinned per the graph.
    pub fn uniform(graph: &Graph, b: u32) -> BitsConfig {
        let bits = graph
            .layers
            .iter()
            .map(|l| l.fixed_bits.unwrap_or(b))
            .collect();
        BitsConfig { bits }
    }

    /// From a knapsack selection over the graph's selectable link groups:
    /// `selected[g] == true` → group g at `b_hi`, else `b_lo`.
    pub fn from_selection(graph: &Graph, selected: &[bool], b_hi: u32, b_lo: u32) -> BitsConfig {
        let mut cfg = BitsConfig::uniform(graph, b_hi);
        for (g, group) in graph.groups.iter().enumerate() {
            let b = if selected[g] { b_hi } else { b_lo };
            for &li in &group.layer_idx {
                if graph.layers[li].fixed_bits.is_none() {
                    cfg.bits[graph.layers[li].qindex] = b;
                }
            }
        }
        cfg
    }

    /// The runtime f32 vector the artifacts consume.
    pub fn to_f32(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| b as f32).collect()
    }

    /// Number of selectable layers at each precision (diagnostics/Fig. 9).
    pub fn count_at(&self, graph: &Graph, b: u32) -> usize {
        graph
            .layers
            .iter()
            .filter(|l| l.fixed_bits.is_none() && self.bits[l.qindex] == b)
            .count()
    }
}

/// Model compression ratio w.r.t. FP32 weights (paper Tables 1-2): total
/// weight bits at FP32 / total weight bits at the mixed precision config.
pub fn compression_ratio(graph: &Graph, cfg: &BitsConfig) -> f64 {
    let fp32: f64 = graph.layers.iter().map(|l| 32.0 * l.weight_params as f64).sum();
    let mp: f64 = graph
        .layers
        .iter()
        .map(|l| cfg.bits[l.qindex] as f64 * l.weight_params as f64)
        .sum();
    fp32 / mp
}

/// Giga-bit-operations of one forward pass (paper's BOPS column):
/// BMAC = b_weights * b_acts * MAC, b_w == b_a per layer (§3.4.1), so
/// BOPs = Σ b² · MACs.
pub fn gbops(graph: &Graph, cfg: &BitsConfig) -> f64 {
    graph
        .layers
        .iter()
        .map(|l| {
            let b = cfg.bits[l.qindex] as f64;
            b * b * l.macs as f64
        })
        .sum::<f64>()
        / 1e9
}

/// Rescale a layer's learned LSQ step sizes when dropping its precision
/// from `b_from` to `b_to` (paper §3.4.3: "initial quantization step-size
/// ... is set to 4s" for 4→2; generally scale by 2^(b_from - b_to)).
pub fn rescale_steps_for_drop(
    ck: &mut Checkpoint,
    layer_name: &str,
    b_from: u32,
    b_to: u32,
) -> crate::Result<()> {
    let factor = 2f32.powi(b_from as i32 - b_to as i32);
    for suffix in ["sw", "sa"] {
        let key = format!("{}/{}", layer_name.replace('.', "/"), suffix);
        let t = ck
            .get_mut(&key)
            .ok_or_else(|| crate::err!("missing step size {key}"))?;
        for v in t.f32s_mut() {
            *v *= factor;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qranges() {
        assert_eq!(qrange_signed(4), (-8.0, 7.0));
        assert_eq!(qrange_signed(2), (-2.0, 1.0));
        assert_eq!(qrange_unsigned(4), (0.0, 15.0));
        assert_eq!(qrange_unsigned(8), (0.0, 255.0));
    }

    #[test]
    fn storage_fields_cover_signed_ranges() {
        for &(bits, field) in &[(1u32, 2u32), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8)] {
            assert_eq!(storage_field_bits(bits), field, "bits={bits}");
            // The field's two's-complement range covers the quantizer's.
            let (qn, qp) = qrange_signed(bits);
            let half = 1i64 << (field - 1);
            assert!(qn >= -(half as f32) && qp <= (half - 1) as f32);
        }
    }

    #[test]
    fn fake_quant_matches_formula() {
        // v=0.33, s=0.1 -> round(3.3)=3 -> 0.3
        assert!((fake_quant(0.33, 0.1, -8.0, 7.0) - 0.3).abs() < 1e-6);
        // Saturation.
        assert!((fake_quant(5.0, 0.1, -8.0, 7.0) - 0.7).abs() < 1e-6);
        assert!((fake_quant(-5.0, 0.1, -8.0, 7.0) + 0.8).abs() < 1e-6);
    }

    #[test]
    fn codes_in_range() {
        let w: Vec<f32> = (-100..100).map(|i| i as f32 * 0.013).collect();
        for &b in &[2u32, 4, 8] {
            let (qn, qp) = qrange_signed(b);
            for c in weight_codes(&w, 0.07, b) {
                assert!(c as f32 >= qn && c as f32 <= qp);
            }
        }
    }

    #[test]
    fn quant_error_zero_same_bits() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.01).collect();
        assert_eq!(quant_error_norm2(&w, 4, 4), 0.0);
        assert!(quant_error_norm2(&w, 4, 2) > 0.0);
    }

    #[test]
    fn hawq_step_symmetric() {
        let w = [0.5f32, -1.0, 0.25];
        assert!((hawq_step_size(&w, 2) - 0.5).abs() < 1e-6);
        assert!((hawq_step_size(&w, 4) - 0.125).abs() < 1e-6);
    }
}

pub mod energy;
