//! # mpq — Mixed Precision Quantization framework
//!
//! Rust + JAX + Pallas reproduction of *"Efficient and Effective Methods for
//! Mixed Precision Neural Network Quantization for Faster, Energy-efficient
//! Inference"* (Bablani, McKinstry et al., 2023).
//!
//! The paper's contribution is a layer-precision-selection pipeline:
//!
//! 1. estimate a per-layer **accuracy gain** `G_l` for keeping layer *l* at
//!    the higher precision — via [`methods`]`::Eagl` (weight-distribution
//!    entropy, Algorithm 2), `::Alps` (one-epoch per-layer fine-tune,
//!    Algorithm 1), or the re-implemented comparators (`::HawqV3`,
//!    topological and uniform baselines, the Appendix-B regression oracle);
//! 2. pick per-layer precisions under a BMAC budget with the 0-1 integer
//!    [`knapsack`] solver (§3.1);
//! 3. fine-tune the resulting mixed-precision network with LSQ
//!    ([`train`], executing AOT-lowered JAX/Pallas artifacts through
//!    [`runtime`]) and report task metrics along the whole
//!    accuracy–throughput frontier ([`coordinator`], [`report`]).
//!
//! Python/JAX/Pallas only ever runs at build time (`make artifacts`); this
//! crate is the entire runtime (DESIGN.md §2).
//!
//! Substrate modules ([`jsonio`], [`rng`], [`tensor`], [`cli`], [`bench`],
//! [`prop`], [`ckpt`]) are built from scratch — the build environment is
//! offline with only the `xla` dependency tree vendored.

pub mod bench;
pub mod ckpt;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eagl;
pub mod graph;
pub mod jsonio;
pub mod knapsack;
pub mod methods;
pub mod prop;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod train;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts directory (override with `MPQ_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Some(p) = std::env::var_os("MPQ_ARTIFACTS") {
        return std::path::PathBuf::from(p);
    }
    // Walk up from cwd until an `artifacts/` directory is found so examples,
    // tests and benches work from any subdirectory.
    let mut dir = std::env::current_dir().unwrap_or_default();
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return std::path::PathBuf::from("artifacts");
        }
    }
}
