//! # mpq — Mixed Precision Quantization framework
//!
//! Rust + JAX + Pallas reproduction of *"Efficient and Effective Methods for
//! Mixed Precision Neural Network Quantization for Faster, Energy-efficient
//! Inference"* (Bablani, McKinstry et al., 2023).
//!
//! The paper's contribution is a layer-precision-selection pipeline:
//!
//! 1. estimate a per-layer **accuracy gain** `G_l` for keeping layer *l* at
//!    the higher precision — via [`methods`]`::Eagl` (weight-distribution
//!    entropy, Algorithm 2), `::Alps` (one-epoch per-layer fine-tune,
//!    Algorithm 1), or the re-implemented comparators (`::HawqV3`,
//!    topological and uniform baselines, the Appendix-B regression oracle);
//! 2. pick per-layer precisions under a BMAC budget with the 0-1 integer
//!    [`knapsack`] solver (§3.1);
//! 3. fine-tune the resulting mixed-precision network with LSQ
//!    ([`train`]) and report task metrics along the whole
//!    accuracy–throughput frontier ([`coordinator`], [`report`]).
//!
//! Whole evaluation matrices (models × methods × budgets × seeds) are
//! expressed as declarative JSON manifests and executed by the resumable
//! multi-model scheduler in [`experiment`] (`mpq exp --manifest m.json`).
//!
//! The resulting (checkpoint, precision assignment) pairs are *served*
//! by the batched inference engine in [`serve`] (`mpq serve`): a dynamic
//! micro-batching queue fanned over per-worker backends, with responses
//! bit-identical to direct single-request evaluation and a deterministic
//! load generator measuring requests/s and latency percentiles.
//!
//! ## Execution backends
//!
//! Every step that touches a network executes through the [`backend`]
//! abstraction — [`backend::Backend`] exposes `execute(entry, inputs)`,
//! `init_checkpoint()` and manifest access, plus the typed entry points
//! (`train_step`, `eval_step`, `vhv_step`, `eagl_step`) built on top.
//! Two implementations ship:
//!
//! * [`backend::SimBackend`] — the **hermetic pure-Rust reference
//!   executor** (default).  It synthesizes small proxy models with
//!   seeded-RNG weights, honors per-layer [`quant::BitsConfig`]
//!   quantization in forward/backward, and makes the full EAGL/ALPS
//!   pipeline runnable and testable with zero external build steps.
//!   All of its compute routes through the [`kernels`] subsystem:
//!   blocked GEMM tiles with preallocated scratch plus quantized-weight
//!   and featurizer caches, bit-identical to the reference math.
//! * `backend::PjrtBackend` (`--features pjrt`) — the AOT path: loads
//!   HLO-text artifacts produced by the Python build (`make artifacts`)
//!   and executes them through a PJRT CPU client.  Requires the vendored
//!   `xla` crate; see `rust/Cargo.toml`.
//!
//! The CLI selects a backend with `--backend sim|pjrt|auto` (auto prefers
//! artifacts when present and compiled-in, else falls back to sim).
//!
//! Substrate modules ([`error`], [`logging`], [`jsonio`], [`rng`],
//! [`tensor`], [`cli`], [`bench`], [`prop`], [`ckpt`]) are built from
//! scratch — the default build has **no external dependencies** at all.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod ckpt;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eagl;
pub mod error;
pub mod experiment;
pub mod graph;
pub mod jsonio;
pub mod kernels;
pub mod knapsack;
pub mod logging;
pub mod methods;
pub mod prop;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tensor;
pub mod train;

/// Crate-wide result type.
pub type Result<T> = crate::error::Result<T>;

/// Locate the AOT artifacts directory, if any: the `MPQ_ARTIFACTS`
/// override wins (returned even if missing, so errors can name it),
/// otherwise walk up from the cwd looking for an `artifacts/` directory.
pub fn find_artifacts() -> Option<std::path::PathBuf> {
    if let Some(p) = std::env::var_os("MPQ_ARTIFACTS") {
        return Some(std::path::PathBuf::from(p));
    }
    let mut dir = std::env::current_dir().unwrap_or_default();
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Root of the artifacts directory (override with `MPQ_ARTIFACTS`).
/// Falls back to `artifacts` when nothing is found; prefer
/// [`find_artifacts`] when "absent" must be distinguishable.
pub fn artifacts_dir() -> std::path::PathBuf {
    find_artifacts().unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Root of the results directory: the `MPQ_RESULTS` override wins,
/// otherwise walk up from the cwd looking for an existing `results/`
/// (so sweeps resume from the same store regardless of the invocation
/// directory, mirroring [`find_artifacts`]); falls back to `./results`.
pub fn results_root() -> std::path::PathBuf {
    if let Some(p) = std::env::var_os("MPQ_RESULTS") {
        return std::path::PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_default();
    loop {
        let cand = dir.join("results");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return std::path::PathBuf::from("results");
        }
    }
}
