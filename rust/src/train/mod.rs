//! Fine-tune driver: the LSQ quantization-aware training loop (paper
//! §3.4.3), generic over the execution [`Backend`].
//!
//! The loop is intentionally thin — every FLOP of fwd/bwd/update lives in
//! the backend's fused `train_step` (an AOT HLO executable on pjrt, the
//! reference implementation on sim); the host only generates batches
//! (deterministic [`Dataset`] streams), schedules the cosine learning
//! rate, and accumulates metrics.

use crate::backend::{Backend, Task, TrainState};
use crate::ckpt::Checkpoint;
use crate::data::{span_f1, Dataset, Split};

/// Fine-tuning hyperparameters.  Defaults mirror the paper's recipe scaled
/// to the synthetic testbed (cosine decay, SGD momentum 0.9, wd 1e-4).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr0: f32,
    pub wd: f32,
    /// Cosine-decay floor as a fraction of lr0.
    pub lr_floor: f32,
    /// Seed for the batch stream (the paper's 5-seed protocol varies this).
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr0: 0.01,
            wd: 1e-4,
            lr_floor: 0.01,
            seed: 0,
        }
    }
}

/// Aggregates from one fine-tune run.
#[derive(Debug, Clone)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub metrics: Vec<f32>,
    /// Mean train metric over the run — ALPS's accuracy signal (Alg. 1).
    pub mean_metric: f64,
    /// Mean train loss over the run — ALPS's loss signal for segmentation.
    pub mean_loss: f64,
}

/// Cosine learning-rate schedule (Loshchilov & Hutter, as in §3.4.3).
pub fn cosine_lr(step: usize, total: usize, lr0: f32, floor_frac: f32) -> f32 {
    if total <= 1 {
        return lr0;
    }
    let t = step as f32 / (total - 1) as f32;
    let floor = lr0 * floor_frac;
    floor + 0.5 * (lr0 - floor) * (1.0 + (std::f32::consts::PI * t).cos())
}

/// Run `cfg.steps` fused fine-tune steps, updating `state` in place.
pub fn finetune<B: Backend>(
    rt: &mut B,
    state: &mut TrainState,
    data: &Dataset,
    bits: &[f32],
    cfg: &TrainConfig,
) -> crate::Result<TrainLog> {
    let batch = rt.manifest().train_batch;
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut metrics = Vec::with_capacity(cfg.steps);
    // Distinct seeds shift the batch stream so the paper's N-seed protocol
    // sees different data orderings.
    let stream_base = cfg.seed.wrapping_mul(1_000_003);
    for step in 0..cfg.steps {
        let (x, y) = data.batch(Split::Train, stream_base + step as u64, batch);
        let lr = cosine_lr(step, cfg.steps, cfg.lr0, cfg.lr_floor);
        let (loss, metric) = rt.train_step(state, &x, &y, lr, cfg.wd, bits)?;
        crate::ensure!(loss.is_finite(), "diverged at step {step}: loss {loss}");
        losses.push(loss);
        metrics.push(metric);
    }
    let mean_metric = metrics.iter().map(|&m| m as f64).sum::<f64>() / metrics.len().max(1) as f64;
    let mean_loss = losses.iter().map(|&l| l as f64).sum::<f64>() / losses.len().max(1) as f64;
    Ok(TrainLog {
        losses,
        metrics,
        mean_metric,
        mean_loss,
    })
}

/// Evaluation result with the task-appropriate headline metric.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f64,
    /// cls: top-1 accuracy; seg: mIoU; span: token-overlap F1. In [0,1].
    pub metric: f64,
}

/// Evaluate over `n_batches` deterministic eval batches.
pub fn evaluate<B: Backend>(
    rt: &mut B,
    params: &Checkpoint,
    data: &Dataset,
    bits: &[f32],
    n_batches: usize,
) -> crate::Result<EvalResult> {
    let batch = rt.manifest().eval_batch;
    let task = rt.manifest().task;
    let mut loss_sum = 0.0f64;
    // Accumulators per task.
    let mut correct = 0.0f64;
    let mut seen = 0usize;
    let mut inter = vec![0.0f64; 16];
    let mut union = vec![0.0f64; 16];
    let mut f1_sum = 0.0f64;
    for i in 0..n_batches {
        let (x, y) = data.batch(Split::Eval, i as u64, batch);
        let (loss, out) = rt.eval_step(params, &x, &y, bits)?;
        loss_sum += loss as f64;
        seen += batch;
        match task {
            Task::Cls => correct += out.item() as f64,
            Task::Seg => {
                let c = out.shape[1];
                let v = out.f32s();
                for k in 0..c {
                    inter[k] += v[k] as f64;
                    union[k] += v[c + k] as f64;
                }
            }
            Task::Span => {
                let preds = out.f32s();
                let gold = y.i32s();
                let pairs: Vec<(i32, i32)> = (0..batch)
                    .map(|b| (preds[b * 2] as i32, preds[b * 2 + 1] as i32))
                    .collect();
                let gpairs: Vec<(i32, i32)> =
                    (0..batch).map(|b| (gold[b * 2], gold[b * 2 + 1])).collect();
                f1_sum += span_f1(&pairs, &gpairs) * batch as f64;
            }
        }
    }
    let metric = match task {
        Task::Cls => correct / seen as f64,
        Task::Seg => {
            let c = rt.manifest().evalout_shape[1];
            let ious: Vec<f64> = (0..c)
                .map(|k| if union[k] > 0.0 { inter[k] / union[k] } else { 1.0 })
                .collect();
            ious.iter().sum::<f64>() / c as f64
        }
        Task::Span => f1_sum / seen as f64,
    };
    Ok(EvalResult {
        loss: loss_sum / n_batches as f64,
        metric,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::graph::Graph;
    use crate::quant::BitsConfig;

    #[test]
    fn cosine_schedule_endpoints() {
        let lr0 = 0.1;
        assert!((cosine_lr(0, 100, lr0, 0.01) - lr0).abs() < 1e-7);
        let end = cosine_lr(99, 100, lr0, 0.01);
        assert!((end - 0.001).abs() < 1e-7, "end {end}");
        // Monotone decreasing.
        let mut prev = f32::INFINITY;
        for s in 0..100 {
            let lr = cosine_lr(s, 100, lr0, 0.01);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn cosine_single_step() {
        assert_eq!(cosine_lr(0, 1, 0.05, 0.1), 0.05);
    }

    #[test]
    fn finetune_and_evaluate_on_sim() {
        let mut be = SimBackend::new("sim_tiny").unwrap();
        let graph = Graph::from_manifest(&be.manifest().raw).unwrap();
        let data = Dataset::for_task(be.manifest().task, 3);
        let bits = BitsConfig::uniform(&graph, 4).to_f32();
        let mut state = TrainState::new(be.init_checkpoint().unwrap());
        let cfg = TrainConfig { steps: 5, lr0: 0.02, ..TrainConfig::default() };
        let log = finetune(&mut be, &mut state, &data, &bits, &cfg).unwrap();
        assert_eq!(log.losses.len(), 5);
        assert!(log.losses.iter().all(|l| l.is_finite()));
        assert!(log.metrics.iter().all(|m| (0.0..=1.0).contains(m)));
        let eval = evaluate(&mut be, &state.params, &data, &bits, 2).unwrap();
        assert!(eval.loss.is_finite());
        assert!((0.0..=1.0).contains(&eval.metric));
    }
}
