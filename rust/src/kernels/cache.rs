//! Memoization layer for the sim executor's two recomputation hot spots:
//! LSQ weight codes (re-quantized on every forward) and Gabor-energy
//! features (re-correlated for every batch).
//!
//! Both caches are **semantically transparent**: a hit returns exactly
//! the buffer a miss would have computed (the kernels in [`super::gemm`]
//! are deterministic), so cached and uncached executions are bit
//! identical — asserted in `rust/tests/kernel_cache_parallel.rs`.
//!
//! Keys are content fingerprints rather than identities: the backend
//! receives plain tensors with no provenance, but every input it sees is
//! deterministic — checkpoints come from seeded RNG + deterministic
//! training, batches from [`crate::data::Dataset::batch`]'s
//! (seed, split, index, batch) streams — so equal content *is* equal
//! identity, and a fingerprint match after a train step updates the
//! weights is exactly the invalidation condition we need.

use std::collections::VecDeque;
use std::sync::Arc;

use super::gemm;
use super::packed;

/// 64-bit content fingerprint of an f32 slice: two word-wise FNV/murmur
/// style streams over the IEEE bit patterns, length-separated and folded
/// into one 64-bit value.  Not cryptographic — per-pair collision odds
/// are ~2⁻⁶⁴, so over the handful of distinct tensors a run touches the
/// aggregate risk stays negligible; revisit the fold (e.g. keep both
/// words) before keying orders of magnitude more content.
pub fn fingerprint_f32(xs: &[f32]) -> u64 {
    let mut h1: u64 = 0xcbf2_9ce4_8422_2325 ^ (xs.len() as u64);
    let mut h2: u64 = 0x9e37_79b9_7f4a_7c15;
    for &v in xs {
        let b = v.to_bits() as u64;
        h1 = (h1 ^ b).wrapping_mul(0x0000_0100_0000_01B3);
        h2 = (h2 ^ b).wrapping_mul(0xff51_afd7_ed55_8ccd);
    }
    h1 ^ h2.rotate_left(32)
}

/// One cached quantization of one layer's weights.
#[derive(Default)]
struct Entry {
    /// (bits, sw bit pattern, weight fingerprint) the buffers were built
    /// for; `None` until first use.
    key: Option<(u32, u32, u64)>,
    /// Fake-quantized weights, transposed layout `[fan_out][fan_in]`.
    wt: Vec<f32>,
    /// Clipped-STE in-range mask, parameter layout `[fan_in][fan_out]`.
    w_in: Vec<bool>,
}

/// Two-way per-layer set: the vHv finite-difference probe alternates
/// base and perturbed weights within every draw, so two entries keep
/// the frozen base codes resident across a whole HAWQ sweep instead of
/// thrashing a single slot.
#[derive(Default)]
struct Slot {
    entries: [Entry; 2],
    /// Index of the most-recently ensured entry.
    mru: usize,
}

/// Per-layer memo of LSQ weight codes keyed by
/// `(bits, step size, weight fingerprint)`.
///
/// A train step rewrites the weights, which changes the fingerprint and
/// invalidates on the next touch; eval loops, ALPS probes and HAWQ
/// sweeps over a frozen checkpoint hit on every call.
pub struct WeightCache {
    slots: Vec<Slot>,
    pub hits: u64,
    pub misses: u64,
}

impl WeightCache {
    pub fn new(n_layers: usize) -> WeightCache {
        WeightCache {
            slots: (0..n_layers).map(|_| Slot::default()).collect(),
            hits: 0,
            misses: 0,
        }
    }

    /// Transposed quantized weights + in-range mask for layer `li`,
    /// recomputing only when `(bits, sw, w)` misses both resident
    /// entries (the colder entry is evicted).
    #[allow(clippy::too_many_arguments)]
    pub fn ensure(
        &mut self,
        li: usize,
        bits: u32,
        sw: f32,
        w: &[f32],
        fan_in: usize,
        fan_out: usize,
        qn: f32,
        qp: f32,
    ) -> (&[f32], &[bool]) {
        let key = (bits, sw.to_bits(), fingerprint_f32(w));
        let hit = {
            let slot = &self.slots[li];
            if slot.entries[slot.mru].key == Some(key) {
                Some(slot.mru)
            } else if slot.entries[1 - slot.mru].key == Some(key) {
                Some(1 - slot.mru)
            } else {
                None
            }
        };
        match hit {
            Some(i) => {
                self.hits += 1;
                self.slots[li].mru = i;
            }
            None => {
                self.misses += 1;
                let slot = &mut self.slots[li];
                let i = 1 - slot.mru;
                let e = &mut slot.entries[i];
                e.wt.clear();
                e.wt.resize(fan_in * fan_out, 0.0);
                e.w_in.clear();
                e.w_in.resize(fan_in * fan_out, false);
                gemm::quantize_weights_wt(w, sw, qn, qp, &mut e.wt, &mut e.w_in, fan_in, fan_out);
                e.key = Some(key);
                slot.mru = i;
            }
        }
        self.peek(li)
    }

    /// The most-recently ensured entry for `li`, without re-hashing the
    /// weights.  Valid only when the caller knows the weights are
    /// unchanged since the matching [`ensure`](WeightCache::ensure) —
    /// e.g. the backward half of one forward/backward pass, which would
    /// otherwise fingerprint every weight tensor a second time.
    pub fn peek(&self, li: usize) -> (&[f32], &[bool]) {
        let slot = &self.slots[li];
        let e = &slot.entries[slot.mru];
        (&e.wt, &e.w_in)
    }
}

/// Per-layer memo of bit-packed weight codes ([`packed::PackedLayer`]),
/// keyed exactly like [`WeightCache`] (`(bits, sw bits, weight
/// fingerprint)` — the same content-fingerprint invalidation, so a train
/// step that rewrites the weights misses on the next packed touch).
///
/// Entries live behind `Arc` so a packed layer can outlive the slot that
/// built it (the serving engine's share-across-workers path hands whole
/// [`packed::PackedNet`]s around via `Backend::prepare_shared` /
/// `adopt_shared`, pinned outside this cache entirely).  One entry per
/// layer (no two-way set): the packed path serves frozen checkpoints,
/// where every call after the first is a hit.
pub struct PackedWeightCache {
    slots: Vec<(Option<(u32, u32, u64)>, Option<Arc<packed::PackedLayer>>)>,
    pub hits: u64,
    pub misses: u64,
}

impl PackedWeightCache {
    pub fn new(n_layers: usize) -> PackedWeightCache {
        PackedWeightCache {
            slots: (0..n_layers).map(|_| (None, None)).collect(),
            hits: 0,
            misses: 0,
        }
    }

    /// Packed codes for layer `li`, re-packing only when `(bits, sw, w)`
    /// misses the resident entry.
    pub fn ensure(
        &mut self,
        li: usize,
        bits: u32,
        sw: f32,
        w: &[f32],
        fan_in: usize,
        fan_out: usize,
    ) -> crate::Result<Arc<packed::PackedLayer>> {
        let key = (bits, sw.to_bits(), fingerprint_f32(w));
        let slot = &mut self.slots[li];
        if slot.0 == Some(key) {
            if let Some(pk) = &slot.1 {
                self.hits += 1;
                return Ok(Arc::clone(pk));
            }
        }
        self.misses += 1;
        let pk = Arc::new(packed::pack(w, sw, bits, fan_in, fan_out)?);
        *slot = (Some(key), Some(Arc::clone(&pk)));
        Ok(pk)
    }
}

/// Memo of featurizer outputs keyed by the input batch's content
/// fingerprint (+ element count).
///
/// `Dataset::batch` is deterministic per (seed, split, index, batch), so
/// the fingerprint identifies the batch; repeated train steps and eval
/// loops over the same batch skip the O(batch · features · pixels) Gabor
/// correlation entirely.  FIFO eviction at `cap`; entries are tiny
/// (batch × n_features f32s).
pub struct FeatCache {
    entries: VecDeque<(u64, usize, Vec<f32>)>,
    cap: usize,
    pub hits: u64,
    pub misses: u64,
}

impl FeatCache {
    pub fn new(cap: usize) -> FeatCache {
        FeatCache {
            entries: VecDeque::new(),
            cap: cap.max(1),
            hits: 0,
            misses: 0,
        }
    }

    /// Index of the cached entry for `(fingerprint, input length)`, if
    /// present; bumps the hit/miss counters.
    pub fn find(&mut self, fp: u64, len: usize) -> Option<usize> {
        let pos = self
            .entries
            .iter()
            .position(|(f, l, _)| *f == fp && *l == len);
        if pos.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        pos
    }

    /// Insert a freshly computed feature batch (evicting the oldest entry
    /// at capacity) and return its index.
    pub fn insert(&mut self, fp: u64, len: usize, feats: Vec<f32>) -> usize {
        if self.entries.len() >= self.cap {
            self.entries.pop_front();
        }
        self.entries.push_back((fp, len, feats));
        self.entries.len() - 1
    }

    /// The cached feature slice at `idx` (valid until the next insert).
    pub fn feats(&self, idx: usize) -> &[f32] {
        &self.entries[idx].2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_sensitive_to_content_and_length() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![1.0f32, 2.0, 3.0 + 1e-7];
        let c = vec![1.0f32, 2.0];
        assert_eq!(fingerprint_f32(&a), fingerprint_f32(&a));
        assert_ne!(fingerprint_f32(&a), fingerprint_f32(&b));
        assert_ne!(fingerprint_f32(&a), fingerprint_f32(&c));
        // -0.0 and 0.0 have different bit patterns — distinct on purpose
        // (the cache keys raw content, not numeric equality).
        assert_ne!(fingerprint_f32(&[0.0]), fingerprint_f32(&[-0.0]));
    }

    #[test]
    fn weight_cache_hits_and_invalidates() {
        let mut wc = WeightCache::new(1);
        let w = vec![0.1f32, -0.2, 0.3, 0.05];
        let (wt1, _) = wc.ensure(0, 4, 0.1, &w, 2, 2, -8.0, 7.0);
        let wt1 = wt1.to_vec();
        assert_eq!(wc.misses, 1);
        let (wt2, _) = wc.ensure(0, 4, 0.1, &w, 2, 2, -8.0, 7.0);
        assert_eq!(wt1, wt2);
        assert_eq!(wc.hits, 1);
        // Changed weights → miss → fresh codes.
        let w2 = vec![0.4f32, -0.2, 0.3, 0.05];
        let (wt3, _) = wc.ensure(0, 4, 0.1, &w2, 2, 2, -8.0, 7.0);
        assert_ne!(wt1, wt3);
        assert_eq!(wc.misses, 2);
        // Changed bits → miss even with identical weights.
        wc.ensure(0, 2, 0.1, &w2, 2, 2, -2.0, 1.0);
        assert_eq!(wc.misses, 3);
    }

    #[test]
    fn weight_cache_two_way_keeps_base_resident() {
        // The vHv access pattern: base / perturbed / base must cost two
        // quantizations, not three, and peek must see the last ensure.
        let mut wc = WeightCache::new(1);
        let base = vec![0.1f32, -0.2, 0.3, 0.05];
        let pert = vec![0.11f32, -0.19, 0.31, 0.06];
        wc.ensure(0, 4, 0.1, &base, 2, 2, -8.0, 7.0); // miss
        wc.ensure(0, 4, 0.1, &pert, 2, 2, -8.0, 7.0); // miss, other way
        let (wt_base, _) = wc.ensure(0, 4, 0.1, &base, 2, 2, -8.0, 7.0); // hit
        assert_eq!(wt_base[0], 0.1);
        assert_eq!(wc.hits, 1);
        assert_eq!(wc.misses, 2);
        let (wt_peek, _) = wc.peek(0);
        assert_eq!(wt_peek[0], 0.1);
    }

    #[test]
    fn packed_cache_hits_and_invalidates() {
        let mut pc = PackedWeightCache::new(1);
        let w = vec![0.1f32, -0.2, 0.3, 0.05];
        let p1 = pc.ensure(0, 4, 0.1, &w, 2, 2).unwrap();
        assert_eq!(pc.misses, 1);
        let p2 = pc.ensure(0, 4, 0.1, &w, 2, 2).unwrap();
        assert_eq!(pc.hits, 1);
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the resident entry");
        // Changed weights or bits → miss → fresh codes.
        let w2 = vec![0.4f32, -0.2, 0.3, 0.05];
        pc.ensure(0, 4, 0.1, &w2, 2, 2).unwrap();
        assert_eq!(pc.misses, 2);
        pc.ensure(0, 2, 0.1, &w2, 2, 2).unwrap();
        assert_eq!(pc.misses, 3);
    }

    #[test]
    fn feat_cache_fifo_eviction() {
        let mut fc = FeatCache::new(2);
        assert!(fc.find(1, 3).is_none());
        let i1 = fc.insert(1, 3, vec![1.0; 3]);
        assert_eq!(fc.feats(i1), &[1.0; 3][..]);
        fc.insert(2, 3, vec![2.0; 3]);
        fc.insert(3, 3, vec![3.0; 3]); // evicts fp=1
        assert!(fc.find(1, 3).is_none());
        let hit = fc.find(3, 3).unwrap();
        assert_eq!(fc.feats(hit), &[3.0; 3][..]);
        assert_eq!(fc.hits, 1);
    }
}
