//! Kernel core for the hermetic sim executor: blocked GEMM-style
//! forward/backward tiles, preallocated workspaces, and the memoization
//! layer that keeps the hot path from recomputing invariants.
//!
//! PR 1 made [`crate::backend::SimBackend`] the substrate every
//! experiment and test runs on, but its compute was scalar nested loops
//! that allocated a fresh buffer chain per call, re-fake-quantized every
//! weight on every forward, and re-featurized every image on every step.
//! This module is the dedicated home for that compute:
//!
//! * [`gemm`] — the tile kernels (forward GEMM over transposed quantized
//!   weights, clipped-STE backward, softmax CE, Gabor featurizer), each
//!   documenting the exact f32 accumulation order it preserves.  The
//!   order contract makes every optimization here *bit-invisible*:
//!   results are identical to the reference loops, only faster.
//! * [`packed`] — bit-packed integer weight codes (4 codes/byte at
//!   2-bit) with LUT-decode and integer-MAC GEMMs for the serve hot
//!   path; the LUT kernel preserves the reference accumulation order
//!   bit-for-bit, the scale-in-epilogue kernels carry a documented
//!   epsilon contract ([`packed::PACKED_LOGIT_EPS`]).  Each tile ships
//!   scalar/unrolled/simd variants ([`packed::PackedVariant`]) and
//!   optional row-band parallelism, all inside the same contracts.
//! * [`cache`] — content-fingerprint memos for LSQ weight codes (per
//!   `(layer, bits, step, weights)`), their bit-packed counterparts
//!   ([`PackedWeightCache`], same invalidation), and Gabor-energy
//!   feature batches (deterministic [`crate::data::Dataset::batch`]
//!   streams make content identity equal batch identity).
//! * [`Workspace`] / [`GradWs`] — reusable scratch for activations,
//!   masks, and gradients, so steady-state `train_step`/`eval_step`
//!   execute with no per-call buffer churn beyond the output tensors
//!   the [`crate::backend::Backend`] contract requires.
//!
//! The parallel ALPS/HAWQ sweeps ([`crate::methods`]) rely on the same
//! determinism: per-worker backends with independent caches produce bit
//! identical gains to a single sequential backend.

pub mod cache;
pub mod gemm;
pub mod ltrace;
pub mod packed;

pub use cache::{fingerprint_f32, FeatCache, PackedWeightCache, WeightCache};

/// Per-layer forward buffers, reused across calls; the backward pass
/// reads them in place (no clone chain between forward and backward).
#[derive(Default)]
pub struct LayerWs {
    /// Pre-activations `[batch * fan_out]`.
    pub z: Vec<f32>,
    /// Layer output activations `[batch * fan_out]` (logits for the head).
    pub out: Vec<f32>,
    /// Activation-below-clamp STE mask; empty for the head layer.
    pub act_in: Vec<bool>,
    /// `u8` activation codes for the integer-MAC path
    /// ([`packed::quantize_acts_u8`] output) — hoisted here so the serve
    /// hot path reuses one buffer per layer instead of reallocating per
    /// request.
    pub acodes: Vec<u8>,
}

/// Reusable scratch for one forward/backward sweep.
#[derive(Default)]
pub struct Workspace {
    /// One [`LayerWs`] per layer, grown on first use.
    pub fwd: Vec<LayerWs>,
    /// Running output-side gradient (starts as dlogits).
    pub d: Vec<f32>,
    /// Input-side gradient of the current layer.
    pub d_in: Vec<f32>,
    /// Gradient at the pre-activation (after the STE mask).
    pub dbr: Vec<f32>,
    /// Featurizer grayscale scratch.
    pub gray: Vec<f32>,
}

impl Workspace {
    /// Grow `fwd` to `n_layers` slots (idempotent) and return the slice —
    /// the one call sites need before walking a packed/integer forward so
    /// per-layer scratch (including [`LayerWs::acodes`]) persists across
    /// requests.
    pub fn ensure_layers(&mut self, n_layers: usize) -> &mut [LayerWs] {
        while self.fwd.len() < n_layers {
            self.fwd.push(LayerWs::default());
        }
        &mut self.fwd[..n_layers]
    }
}

/// Per-layer gradient buffers (reused; two live instances let the
/// finite-difference vHv probe hold both endpoints without copies).
#[derive(Default)]
pub struct GradWs {
    /// `dw[layer]` in parameter layout `[fan_in * fan_out]`.
    pub dw: Vec<Vec<f32>>,
    /// `db[layer]`, `[fan_out]`.
    pub db: Vec<Vec<f32>>,
}

impl GradWs {
    /// Grow to `n_layers` slots (idempotent).
    pub fn ensure(&mut self, n_layers: usize) {
        while self.dw.len() < n_layers {
            self.dw.push(Vec::new());
        }
        while self.db.len() < n_layers {
            self.db.push(Vec::new());
        }
    }
}
