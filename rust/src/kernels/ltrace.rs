//! Thread-local per-layer GEMM timing capture for the serve tracer.
//!
//! The serve worker enables a capture window around a backend forward
//! pass ([`begin`] … [`take`]); while the window is open, every GEMM
//! entry point in this crate ([`super::packed::gemm_bias_packed_v`],
//! [`super::packed::gemm_bias_packed_epilogue_v`],
//! [`super::packed::gemm_bias_packed_i32_v`],
//! [`super::gemm::gemm_bias_wt`]) records one [`GemmTiming`] on the
//! calling thread.  Forward passes execute layers in order, so the nth
//! captured timing *is* layer n — the kernels need no layer-index
//! plumbing, and code outside a capture window (training loops, sweeps,
//! tests) pays exactly one thread-local `Cell<bool>` read per GEMM.
//!
//! Timestamps are nanoseconds **relative to the capture window's
//! start**; the worker adds its own sink-relative base when it turns
//! timings into [`crate::serve::trace::Stage::LayerGemm`] spans.  Row-
//! parallel kernels fan out worker threads internally, but enter/exit
//! wrap the whole banded call on the *calling* thread, so the span
//! covers the full layer regardless of `threads`.

use std::cell::{Cell, RefCell};
use std::time::Instant;

/// One GEMM call inside a capture window.
#[derive(Clone, Copy, Debug)]
pub struct GemmTiming {
    /// Call order inside the window == layer index of the forward pass.
    pub seq: usize,
    /// Layer precision (packed kernels; 0 for the f32 `wt` path, which
    /// has no per-layer code width at the kernel level).
    pub bits: u32,
    /// Kernel variant name (`"scalar"`/`"unrolled"`/`"simd"`, or
    /// `"f32"` for the dense transposed-weight kernel).
    pub variant: &'static str,
    /// Window-relative start, ns.
    pub t_start_ns: u64,
    /// Window-relative end, ns.
    pub t_end_ns: u64,
}

thread_local! {
    /// Fast gate read by every GEMM call; only true inside a window.
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static WINDOW: RefCell<Option<Window>> = const { RefCell::new(None) };
}

struct Window {
    start: Instant,
    timings: Vec<GemmTiming>,
}

/// Open a capture window on the current thread (replacing any prior
/// window).  Pair with [`take`].
pub fn begin() {
    WINDOW.with(|w| {
        *w.borrow_mut() = Some(Window { start: Instant::now(), timings: Vec::new() })
    });
    ACTIVE.with(|a| a.set(true));
}

/// Is a capture window open on this thread?
pub fn active() -> bool {
    ACTIVE.with(|a| a.get())
}

/// Close the window and return its timings (empty if none was open).
pub fn take() -> Vec<GemmTiming> {
    ACTIVE.with(|a| a.set(false));
    WINDOW.with(|w| w.borrow_mut().take().map(|w| w.timings).unwrap_or_default())
}

/// GEMM prologue: window-relative start timestamp, `None` when capture
/// is off (the disabled-path cost: one `Cell` read).
#[inline]
pub fn enter() -> Option<u64> {
    if !active() {
        return None;
    }
    WINDOW.with(|w| {
        w.borrow()
            .as_ref()
            .map(|win| win.start.elapsed().as_nanos() as u64)
    })
}

/// GEMM epilogue: record the call that [`enter`] opened.
pub fn exit(t_start_ns: u64, bits: u32, variant: &'static str) {
    WINDOW.with(|w| {
        if let Some(win) = w.borrow_mut().as_mut() {
            let t_end_ns = win.start.elapsed().as_nanos() as u64;
            let seq = win.timings.len();
            win.timings.push(GemmTiming {
                seq,
                bits,
                variant,
                t_start_ns,
                t_end_ns: t_end_ns.max(t_start_ns),
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_is_off_by_default_and_scoped_to_the_window() {
        assert!(!active());
        assert_eq!(enter(), None);
        begin();
        assert!(active());
        let t0 = enter().expect("window open");
        exit(t0, 4, "unrolled");
        let t1 = enter().unwrap();
        exit(t1, 2, "scalar");
        let timings = take();
        assert!(!active());
        assert_eq!(timings.len(), 2);
        assert_eq!(timings[0].seq, 0);
        assert_eq!(timings[1].seq, 1);
        assert_eq!(timings[0].bits, 4);
        assert_eq!(timings[1].variant, "scalar");
        assert!(timings[0].t_end_ns >= timings[0].t_start_ns);
        assert!(timings[1].t_start_ns >= timings[0].t_start_ns);
        // Closed window: recording is a no-op again.
        assert_eq!(enter(), None);
        assert!(take().is_empty());
    }

    #[test]
    fn instrumented_gemms_record_only_inside_a_window() {
        let (batch, fi, fo) = (2usize, 3usize, 2usize);
        let a = vec![0.5f32; batch * fi];
        let wt = vec![0.25f32; fo * fi];
        let bias = vec![0.0f32; fo];
        let mut z = vec![0f32; batch * fo];
        crate::kernels::gemm::gemm_bias_wt(&a, &wt, &bias, &mut z, batch, fi, fo);
        assert!(take().is_empty(), "no window, no timings");
        begin();
        crate::kernels::gemm::gemm_bias_wt(&a, &wt, &bias, &mut z, batch, fi, fo);
        let t = take();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].variant, "f32");
        assert_eq!(t[0].bits, 0);
    }
}
