//! Blocked GEMM-style compute kernels for the sim executor's hot path.
//!
//! Every kernel here operates on caller-provided, preallocated buffers
//! (no allocation on the hot path) and documents its exact f32
//! accumulation order.  That order is a **contract**: it matches the
//! scalar reference loops the kernels replaced, element for element, so
//! swapping the kernels in changes wall-clock only — never a single bit
//! of any result.  The cache layer ([`super::cache`]) and the parallel
//! sweeps in [`crate::methods`] both lean on this bit-identity.
//!
//! Weight layout: quantized weights are stored **transposed** —
//! `wt[o * fan_in + i]` (output-major) — so the forward pass is a row
//! dot-product over two contiguous slices and the input-gradient pass is
//! a contiguous axpy sweep.  Gradients stay in the parameter layout
//! `dw[i * fan_out + o]` so the SGD update walks `w`, `dw`, and the
//! momentum buffer in lockstep.
//!
//! The bit-packed counterparts in [`super::packed`] share
//! [`gemm_bias_wt`]'s accumulation contract: the packed LUT kernel
//! replays the identical add sequence over identical operand bits
//! (`lut[code] == wt[o,i]` bit for bit), which is what lets the packed
//! evaluation path claim bit-identity rather than an epsilon.

/// Fake-quantize a weight matrix into the transposed layout plus the
/// clipped-STE in-range mask (parameter layout, for gradient masking).
///
/// Elementwise: `code = round(w/sw)`, `w_in = qn ≤ code ≤ qp`,
/// `wt[o,i] = clamp(code) · sw` — identical math to the reference loop.
pub fn quantize_weights_wt(
    w: &[f32],
    sw: f32,
    qn: f32,
    qp: f32,
    wt: &mut [f32],
    w_in: &mut [bool],
    fan_in: usize,
    fan_out: usize,
) {
    for i in 0..fan_in {
        for o in 0..fan_out {
            let idx = i * fan_out + o;
            let code = (w[idx] / sw).round();
            w_in[idx] = code >= qn && code <= qp;
            wt[o * fan_in + i] = code.clamp(qn, qp) * sw;
        }
    }
}

/// Forward tile: `z[b,o] = bias[o] + Σ_i a[b,i] · wt[o,i]`.
///
/// Accumulation starts at the bias and runs `i` ascending with an exact
/// skip of zero activations (common after ReLU + unsigned quantization)
/// — the same add sequence as the reference loop, over two contiguous
/// rows per output.
pub fn gemm_bias_wt(
    a: &[f32],
    wt: &[f32],
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    fan_in: usize,
    fan_out: usize,
) {
    let lt = super::ltrace::enter();
    for bi in 0..batch {
        let arow = &a[bi * fan_in..(bi + 1) * fan_in];
        let zrow = &mut z[bi * fan_out..(bi + 1) * fan_out];
        for (o, zv) in zrow.iter_mut().enumerate() {
            let wrow = &wt[o * fan_in..(o + 1) * fan_in];
            let mut acc = bias[o];
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * wrow[i];
                }
            }
            *zv = acc;
        }
    }
    if let Some(t0) = lt {
        super::ltrace::exit(t0, 0, "f32");
    }
}

/// ReLU → unsigned LSQ fake-quant with the clipped-STE mask, fused with
/// the optional residual combine `out = a_in + gamma · hq`.
pub fn relu_quant_act(
    z: &[f32],
    sa: f32,
    aqp: f32,
    residual: Option<&[f32]>,
    gamma: f32,
    out: &mut [f32],
    act_in: &mut [bool],
) {
    for (idx, &zv) in z.iter().enumerate() {
        let h = zv.max(0.0);
        let code = (h / sa).round();
        act_in[idx] = h / sa <= aqp;
        let hq = code.clamp(0.0, aqp) * sa;
        out[idx] = match residual {
            Some(a_in) => a_in[idx] + gamma * hq,
            None => hq,
        };
    }
}

/// Softmax cross-entropy over logits: (mean loss, correct count), with
/// the gradient `(p - onehot)/batch` written into `dlogits` when given
/// (the eval path skips the gradient entirely).
pub fn softmax_ce(
    logits: &[f32],
    y: &[i32],
    batch: usize,
    classes: usize,
    mut dlogits: Option<&mut Vec<f32>>,
) -> (f32, usize) {
    if let Some(d) = dlogits.as_mut() {
        d.clear();
        d.resize(batch * classes, 0.0);
    }
    let mut loss = 0f64;
    let mut correct = 0usize;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let mut mx = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                argmax = k;
            }
        }
        let mut denom = 0f64;
        for &v in row {
            denom += ((v - mx) as f64).exp();
        }
        let yi = y[b] as usize;
        let p_y = ((row[yi] - mx) as f64).exp() / denom;
        loss -= (p_y + 1e-12).ln();
        if argmax == yi {
            correct += 1;
        }
        if let Some(d) = dlogits.as_mut() {
            for k in 0..classes {
                let p = ((row[k] - mx) as f64).exp() / denom;
                d[b * classes + k] =
                    ((p - if k == yi { 1.0 } else { 0.0 }) / batch as f64) as f32;
            }
        }
    }
    ((loss / batch as f64) as f32, correct)
}

/// Gradient at the pre-activation: `dbr = d · scale` where the ReLU was
/// active and the quantizer unclipped, else 0 (clipped STE).
pub fn ste_backprop_mask(d: &[f32], z: &[f32], act_in: &[bool], scale: f32, dbr: &mut [f32]) {
    for (idx, dv) in dbr.iter_mut().enumerate() {
        *dv = if act_in[idx] && z[idx] > 0.0 {
            d[idx] * scale
        } else {
            0.0
        };
    }
}

/// Weight/bias gradient tile: `dw[i,o] += Σ_b a[b,i] · dbr[b,o]` and
/// `db[o] += Σ_b dbr[b,o]`, batch-major with the zero-activation skip —
/// the reference accumulation order, contiguous in `dw` and `dbr`.
/// `dw`/`db` must be pre-zeroed.
pub fn accumulate_grads(
    a: &[f32],
    dbr: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    batch: usize,
    fan_in: usize,
    fan_out: usize,
) {
    for bi in 0..batch {
        let arow = &a[bi * fan_in..(bi + 1) * fan_in];
        let drow = &dbr[bi * fan_out..(bi + 1) * fan_out];
        for (o, &dv) in drow.iter().enumerate() {
            db[o] += dv;
        }
        for (i, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let wrow = &mut dw[i * fan_out..(i + 1) * fan_out];
                for (o, &dv) in drow.iter().enumerate() {
                    wrow[o] += av * dv;
                }
            }
        }
    }
}

/// Zero gradient entries whose weight code saturated (clipped STE).
pub fn mask_grads(dw: &mut [f32], w_in: &[bool]) {
    for (g, &inside) in dw.iter_mut().zip(w_in) {
        if !inside {
            *g = 0.0;
        }
    }
}

/// Input-gradient tile over the transposed weights:
/// `d_in[b,i] += Σ_o dbr[b,o] · wt[o,i]` as an axpy sweep with `o`
/// ascending — per element the identical add sequence as the reference
/// dot loop, but contiguous in both `wt` and `d_in`.  `d_in` must be
/// pre-zeroed.
pub fn gemm_din_wt(
    dbr: &[f32],
    wt: &[f32],
    d_in: &mut [f32],
    batch: usize,
    fan_in: usize,
    fan_out: usize,
) {
    for bi in 0..batch {
        let drow = &dbr[bi * fan_out..(bi + 1) * fan_out];
        let irow = &mut d_in[bi * fan_in..(bi + 1) * fan_in];
        for (o, &dv) in drow.iter().enumerate() {
            let wrow = &wt[o * fan_in..(o + 1) * fan_in];
            for (i, iv) in irow.iter_mut().enumerate() {
                *iv += dv * wrow[i];
            }
        }
    }
}

/// Gabor-energy featurizer tile: per image, grayscale reduction then one
/// correlation (f64 accumulators, `i` ascending) against each class
/// grating — the matched-filter "GEMM" of the sim front end.  `gray` is
/// reused scratch; `feats` must hold `batch * n_features` slots.
#[allow(clippy::too_many_arguments)]
pub fn gabor_energies(
    xs: &[f32],
    basis_cos: &[f32],
    basis_sin: &[f32],
    gray: &mut Vec<f32>,
    batch: usize,
    px: usize,
    n_features: usize,
    scale: f32,
    feats: &mut [f32],
) {
    gray.clear();
    gray.resize(px, 0.0);
    for b in 0..batch {
        for (i, gv) in gray.iter_mut().enumerate() {
            let o = (b * px + i) * 3;
            *gv = (xs[o] + xs[o + 1] + xs[o + 2]) / 3.0 - 0.5;
        }
        for k in 0..n_features {
            let (mut c, mut s) = (0f64, 0f64);
            let cb = &basis_cos[k * px..(k + 1) * px];
            let sb = &basis_sin[k * px..(k + 1) * px];
            for i in 0..px {
                c += (gray[i] * cb[i]) as f64;
                s += (gray[i] * sb[i]) as f64;
            }
            feats[b * n_features + k] =
                ((c * c + s * s).sqrt() as f32) * (2.0 / px as f32) * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive reference: z = a @ W + bias with W in parameter layout.
    fn reference_forward(
        a: &[f32],
        w: &[f32],
        bias: &[f32],
        batch: usize,
        fi: usize,
        fo: usize,
    ) -> Vec<f32> {
        let mut z = vec![0f32; batch * fo];
        for bi in 0..batch {
            let zrow = &mut z[bi * fo..(bi + 1) * fo];
            zrow.copy_from_slice(bias);
            for i in 0..fi {
                let av = a[bi * fi + i];
                if av != 0.0 {
                    for o in 0..fo {
                        zrow[o] += av * w[i * fo + o];
                    }
                }
            }
        }
        z
    }

    #[test]
    fn forward_matches_reference_bitwise() {
        let (batch, fi, fo) = (3, 5, 4);
        let mut rng = crate::rng::Pcg32::new(1, 2);
        let a: Vec<f32> = (0..batch * fi)
            .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..fi * fo).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
        // Quantize (identity-ish step so values stay interesting).
        let mut wt = vec![0f32; fi * fo];
        let mut w_in = vec![false; fi * fo];
        quantize_weights_wt(&w, 0.01, -128.0, 127.0, &mut wt, &mut w_in, fi, fo);
        let wq_param: Vec<f32> = {
            // Reconstruct parameter layout from the transpose for the ref.
            let mut v = vec![0f32; fi * fo];
            for i in 0..fi {
                for o in 0..fo {
                    v[i * fo + o] = wt[o * fi + i];
                }
            }
            v
        };
        let mut z = vec![0f32; batch * fo];
        gemm_bias_wt(&a, &wt, &bias, &mut z, batch, fi, fo);
        let zr = reference_forward(&a, &wq_param, &bias, batch, fi, fo);
        assert_eq!(z, zr, "kernel must be bit-identical to the reference loop");
    }

    #[test]
    fn din_matches_reference_bitwise() {
        let (batch, fi, fo) = (2, 6, 3);
        let mut rng = crate::rng::Pcg32::new(7, 9);
        let dbr: Vec<f32> = (0..batch * fo).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..fi * fo).map(|_| rng.normal()).collect();
        let mut d_in = vec![0f32; batch * fi];
        gemm_din_wt(&dbr, &wt, &mut d_in, batch, fi, fo);
        // Reference: per-element dot with o ascending.
        for bi in 0..batch {
            for i in 0..fi {
                let mut acc = 0f32;
                for o in 0..fo {
                    acc += dbr[bi * fo + o] * wt[o * fi + i];
                }
                assert_eq!(acc, d_in[bi * fi + i]);
            }
        }
    }

    #[test]
    fn softmax_grad_optional_does_not_change_loss() {
        let logits = vec![1.0f32, 2.0, 0.5, -1.0, 0.0, 3.0];
        let y = vec![1i32, 2];
        let (l1, c1) = softmax_ce(&logits, &y, 2, 3, None);
        let mut d = Vec::new();
        let (l2, c2) = softmax_ce(&logits, &y, 2, 3, Some(&mut d));
        assert_eq!(l1, l2);
        assert_eq!(c1, c2);
        assert_eq!(d.len(), 6);
        // Gradient rows sum to ~0 (softmax minus one-hot, / batch).
        let s: f32 = d[..3].iter().sum();
        assert!(s.abs() < 1e-6, "{s}");
    }

    #[test]
    fn quantize_marks_saturated_codes() {
        let w = vec![0.0f32, 0.05, 10.0, -10.0];
        let mut wt = vec![0f32; 4];
        let mut w_in = vec![false; 4];
        quantize_weights_wt(&w, 0.1, -2.0, 1.0, &mut wt, &mut w_in, 2, 2);
        assert_eq!(w_in, vec![true, true, false, false]);
        // Transposed positions: wt[o*fi+i] for (i,o) of w[i*fo+o].
        assert_eq!(wt[0], 0.0); // (0,0)
        assert_eq!(wt[2], 0.1 * 1.0); // (0,1) saturated hi? w=0.05 -> code 1 (round 0.5) -> 0.1
        assert_eq!(wt[1], 0.1 * 1.0); // (1,0): 10.0 clamps to qp=1
        assert_eq!(wt[3], 0.1 * -2.0); // (1,1): -10 clamps to qn=-2
    }
}
