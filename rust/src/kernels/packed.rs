//! Bit-packed integer weight-code kernels — the serve hot path's storage
//! and compute format.
//!
//! The reference kernels ([`super::gemm`]) materialize every quantized
//! layer as full `f32` fake-quant weights (`wt[o,i] = code·sw`): 32 bits
//! per weight regardless of the 2-/4-bit precision the selection pipeline
//! fought for.  This module stores the LSQ weight **codes themselves**,
//! bit-packed into `u8` words, and executes the forward GEMM directly
//! over the packed rows — so a 2-bit layer's working set is 16× smaller
//! than its fake-quant image and stays cache-resident while serving.
//!
//! ## Packing layout
//!
//! Codes are stored **transposed** (output-major, matching the reference
//! `wt` layout): row `o` holds layer input `i = 0..fan_in` contiguously.
//! Each code occupies a fixed *storage field* of 2, 4, or 8 bits — the
//! smallest that holds the quantizer's signed range in two's complement
//! ([`crate::quant::storage_field_bits`]): 4 codes/byte at 2-bit, 2 at
//! 4-bit, 1 at 8-bit.  Fields fill each byte LSB-first.
//!
//! **Tail padding rule:** every row is independently padded to a whole
//! byte (`row_bytes = ceil(fan_in · field / 8)`), so row starts are
//! byte-aligned at any `fan_in`.  Padding fields hold the bit pattern
//! `0`, which decodes to code `0` (value `0.0`); the kernels iterate
//! `i < fan_in` and never read it, so padding can never contribute to a
//! dot product even if a future kernel over-reads a whole tail byte.
//!
//! ## Kernels and their accuracy contracts
//!
//! * [`gemm_bias_packed`] — decodes each field through the per-layer
//!   `lut[pattern] = fl(code · sw)` table and accumulates in `f32` with
//!   the **exact reference order** (bias first, `i` ascending, zero
//!   activations skipped).  Because `lut[p]` is bit-for-bit the value the
//!   reference `wt` holds for that code, this kernel is **bit-identical**
//!   to [`super::gemm::gemm_bias_wt`] — ε = 0.  It is the packed path's
//!   workhorse for every layer whose output feeds an activation
//!   quantizer: `round(h/sa)` is discontinuous, so even a 1-ulp
//!   reassociation difference in `z` could flip a code near a rounding
//!   boundary and shift downstream logits by O(sa) — which is why the
//!   scale-in-epilogue kernels below are *not* used there.
//! * [`gemm_bias_packed_epilogue`] — accumulates `Σ aᵢ·codeᵢ` in `f32`
//!   and applies the per-layer LSQ scale **once in the epilogue**
//!   (`z = bias + sw·acc`).  Used for the logits layer of the packed
//!   inference path, where nothing requantizes downstream: the
//!   reassociation error is bounded by ~`(fan_in+2)·ε_f32·(|bias| +
//!   Σ|aᵢ·wᵢ|)` ≈ 1e-5 for sim-scale layers; [`PACKED_LOGIT_EPS`]
//!   documents the contract with two orders of magnitude of margin.
//! * [`gemm_bias_packed_i32`] — the fully integer MAC: `u8` activation
//!   codes × packed weight codes with exact `i32` accumulation and one
//!   `f32` scale multiply (`sa·sw`) in the epilogue.  The integer dot is
//!   *exact*; the whole error budget is the single scale rounding (same
//!   bound as above).  This is the deployment-numerics kernel for
//!   integer hardware; on the sim proxy models the residual branches mix
//!   activations off the integer grid (`out = a_in + γ·hq`), so end to
//!   end it is exercised at the kernel/bench level while
//!   [`gemm_bias_packed`] carries the in-model packed path.
//! * [`quantize_acts_u8`] — the activation side of the integer MAC:
//!   ReLU → unsigned LSQ rounding, the identical rule as
//!   [`super::gemm::relu_quant_act`], but keeping the integer code
//!   instead of the rescaled `f32` value.
//!
//! [`PackedNet`] bundles one model's packed layers behind `Arc`s so the
//! serving engine can materialize codes **once** and share them across
//! all N workers (see `Backend::prepare_shared` / `adopt_shared`).

use std::sync::Arc;

use crate::quant;

/// Documented per-logit bound for the scale-in-epilogue kernels
/// ([`gemm_bias_packed_epilogue`], [`gemm_bias_packed_i32`]) against the
/// reference fake-quant accumulation, at sim-model scales (fan-in ≤ a few
/// hundred, activations and weights O(1)).  The measured reassociation
/// error is ~1e-5 worst-case; 1e-3 leaves two orders of margin.
/// [`gemm_bias_packed`] needs no epsilon: it is bit-identical (ε = 0).
pub const PACKED_LOGIT_EPS: f32 = 1e-3;

/// One layer's bit-packed weight codes plus decode tables.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    /// Logical quantizer width the codes were produced at.
    pub bits: u32,
    /// Storage field width (2, 4, or 8 bits; ≥ `bits`).
    pub field: u32,
    /// Codes per byte (`8 / field`).
    pub codes_per_byte: usize,
    /// `log2(codes_per_byte)` — the hot loops locate a code's byte with
    /// `i >> cpb_shift` and its in-byte slot with `i & (codes_per_byte -
    /// 1)`, so decode compiles to shifts/masks instead of a runtime
    /// divide/modulo per MAC.
    pub cpb_shift: u32,
    /// Bytes per output row (`ceil(fan_in / codes_per_byte)` — the tail
    /// padding rule).
    pub row_bytes: usize,
    /// Packed codes, `fan_out` rows × `row_bytes`.
    pub data: Vec<u8>,
    /// The layer's LSQ weight step size.
    pub sw: f32,
    /// `lut[pattern] = fl(clamp(code)·sw)` for every field pattern —
    /// bit-for-bit the reference `wt` value for that code.
    pub lut: Vec<f32>,
    /// `lut_code[pattern] = code as f32` (exact small integers) for the
    /// scale-in-epilogue kernels.
    pub lut_code: Vec<f32>,
}

/// Sign-extend a `field`-bit two's-complement pattern to `i32`.
#[inline]
fn sign_extend(pattern: u8, field: u32) -> i32 {
    ((pattern as i32) << (32 - field)) >> (32 - field)
}

/// Extract the `i`-th field pattern of a packed row.  `cpb_shift` is
/// `log2(codes_per_byte)` and `slot_mask` is `codes_per_byte - 1`
/// (codes-per-byte is always a power of two), so this is pure
/// shift/mask work on the hot path.
#[inline]
fn pattern_at(
    row: &[u8],
    i: usize,
    field: u32,
    cpb_shift: u32,
    slot_mask: usize,
    mask: u8,
) -> usize {
    let byte = row[i >> cpb_shift];
    ((byte >> (((i & slot_mask) as u32) * field)) & mask) as usize
}

impl PackedLayer {
    /// Decode one weight code (diagnostics/tests; the kernels inline the
    /// extraction).
    pub fn code(&self, o: usize, i: usize) -> i32 {
        let row = &self.data[o * self.row_bytes..(o + 1) * self.row_bytes];
        let mask = ((1u16 << self.field) - 1) as u8;
        sign_extend(
            pattern_at(row, i, self.field, self.cpb_shift, self.codes_per_byte - 1, mask) as u8,
            self.field,
        )
    }

    /// Total packed bytes (the working-set win over `4 · fan_in · fan_out`
    /// fake-quant bytes).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Bit-pack a weight tensor's LSQ codes at `bits` into the transposed
/// packed layout.  `w` is in parameter layout (`w[i·fan_out + o]`, as the
/// backends hold it); codes are computed exactly as the reference
/// quantizer does (`round(w/sw)` clamped to the signed range — see
/// [`crate::quant::weight_code`]).
pub fn pack(
    w: &[f32],
    sw: f32,
    bits: u32,
    fan_in: usize,
    fan_out: usize,
) -> crate::Result<PackedLayer> {
    crate::ensure!(
        (1..=8).contains(&bits),
        "packed kernels support 1..=8-bit weight codes, got {bits}-bit"
    );
    crate::ensure!(
        w.len() == fan_in * fan_out,
        "pack: weight tensor has {} elements, expected {}x{}",
        w.len(),
        fan_in,
        fan_out
    );
    let field = quant::storage_field_bits(bits);
    let codes_per_byte = (8 / field) as usize;
    let row_bytes = (fan_in + codes_per_byte - 1) / codes_per_byte;
    let mask = ((1u16 << field) - 1) as u8;
    let mut data = vec![0u8; fan_out * row_bytes];
    for o in 0..fan_out {
        let row = &mut data[o * row_bytes..(o + 1) * row_bytes];
        for i in 0..fan_in {
            let code = quant::weight_code(w[i * fan_out + o], sw, bits);
            let shift = ((i % codes_per_byte) as u32) * field;
            row[i / codes_per_byte] |= ((code as u8) & mask) << shift;
        }
    }
    // Decode tables over every field pattern.  Stored codes are already
    // in the quantizer range; the clamp makes even a corrupt pattern
    // decode to an in-range value.  For in-range codes `clamp` is the
    // identity, so `lut[p]` carries the exact f32 product the reference
    // `quantize_weights_wt` writes into `wt`.
    let (qn, qp) = quant::qrange_signed(bits);
    let mut lut = Vec::with_capacity(1 << field);
    let mut lut_code = Vec::with_capacity(1 << field);
    for p in 0..(1u16 << field) as usize {
        let c = (sign_extend(p as u8, field) as f32).clamp(qn, qp);
        lut.push(c * sw);
        lut_code.push(c);
    }
    Ok(PackedLayer {
        fan_in,
        fan_out,
        bits,
        field,
        codes_per_byte,
        cpb_shift: codes_per_byte.trailing_zeros(),
        row_bytes,
        data,
        sw,
        lut,
        lut_code,
    })
}

/// Forward tile over packed rows with LUT decode:
/// `z[b,o] = bias[o] + Σ_i a[b,i] · lut[code(o,i)]`.
///
/// Accumulation contract: bias first, `i` ascending, exact skip of zero
/// activations — the identical add sequence as
/// [`super::gemm::gemm_bias_wt`] over identical operand bits, so the
/// result is **bit-identical** to the reference fake-quant forward.
pub fn gemm_bias_packed(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
) {
    let (fi, fo) = (pk.fan_in, pk.fan_out);
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * fo..(bi + 1) * fo];
        for (o, zv) in zrow.iter_mut().enumerate() {
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = bias[o];
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * pk.lut[pattern_at(row, i, pk.field, shift, slot, mask)];
                }
            }
            *zv = acc;
        }
    }
}

/// Forward tile with the per-layer LSQ scale applied **once in the
/// epilogue**: `acc = Σ_i a[b,i] · code(o,i)` in f32 (codes are exact
/// small integers), then `z[b,o] = bias[o] + sw · acc`.
///
/// Not bit-identical to the reference — the scale reassociation costs a
/// bounded rounding difference ([`PACKED_LOGIT_EPS`]).  Safe only where
/// no activation quantizer consumes `z` (the logits layer).
pub fn gemm_bias_packed_epilogue(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
) {
    let (fi, fo) = (pk.fan_in, pk.fan_out);
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * fo..(bi + 1) * fo];
        for (o, zv) in zrow.iter_mut().enumerate() {
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = 0f32;
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * pk.lut_code[pattern_at(row, i, pk.field, shift, slot, mask)];
                }
            }
            *zv = bias[o] + pk.sw * acc;
        }
    }
}

/// The fully integer MAC tile: `u8` activation codes × packed weight
/// codes, **exact `i32` accumulation**, one scale multiply in the
/// epilogue:
///
/// `z[b,o] = bias[o] + scale · (Σ_i acode[b,i] · code(o,i))`
///
/// where `scale` is the product of the incoming activation step size and
/// this layer's weight step size (`sa_in · sw`).  The integer dot is
/// exact (no rounding at any accumulation step: |acc| ≤ fan_in·255·128
/// fits i32 for any fan_in ≤ 2¹⁶); the entire f32 error is the epilogue
/// multiply-add ([`PACKED_LOGIT_EPS`]).
pub fn gemm_bias_packed_i32(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
) {
    let (fi, fo) = (pk.fan_in, pk.fan_out);
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &acodes[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * fo..(bi + 1) * fo];
        for (o, zv) in zrow.iter_mut().enumerate() {
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = 0i32;
            for (i, &ac) in arow.iter().enumerate() {
                if ac != 0 {
                    let p = pattern_at(row, i, pk.field, shift, slot, mask);
                    acc += (ac as i32) * sign_extend(p as u8, pk.field);
                }
            }
            *zv = bias[o] + scale * acc as f32;
        }
    }
}

/// ReLU → unsigned LSQ activation **codes** — the same rounding rule as
/// [`super::gemm::relu_quant_act`] (`clamp(round(max(z,0)/sa), 0, aqp)`),
/// kept as integers for [`gemm_bias_packed_i32`].  `aqp` must be ≤ 255
/// (8-bit unsigned activations), which [`crate::quant::qrange_unsigned`]
/// guarantees for bits ≤ 8.
pub fn quantize_acts_u8(z: &[f32], sa: f32, aqp: f32, codes: &mut Vec<u8>) {
    debug_assert!(aqp <= 255.0);
    codes.clear();
    codes.reserve(z.len());
    codes.extend(
        z.iter()
            .map(|&zv| (zv.max(0.0) / sa).round().clamp(0.0, aqp) as u8),
    );
}

/// One model's packed layers at one (checkpoint, bits) configuration —
/// the immutable state the serving engine materializes once and shares
/// across its worker pool (`Backend::prepare_shared` / `adopt_shared`).
#[derive(Debug, Clone)]
pub struct PackedNet {
    /// Effective per-layer precision the codes were packed at (fixed
    /// layers pinned), used to fail closed on a config mismatch.
    pub bits_eff: Vec<u32>,
    pub layers: Vec<Arc<PackedLayer>>,
}

impl PackedNet {
    /// Total packed bytes across the model.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm;
    use crate::rng::Pcg32;

    fn random_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0x7061_636b);
        (0..n).map(|_| rng.normal() * 0.3).collect()
    }

    #[test]
    fn pack_round_trips_codes_at_any_fan_in() {
        for &bits in &[1u32, 2, 3, 4, 5, 8] {
            for &fan_in in &[1usize, 3, 4, 5, 7, 8, 13, 16] {
                let fan_out = 3;
                let w = random_weights(fan_in * fan_out, bits as u64 * 100 + fan_in as u64);
                let pk = pack(&w, 0.1, bits, fan_in, fan_out).unwrap();
                assert_eq!(pk.field, quant::storage_field_bits(bits));
                assert_eq!(
                    pk.row_bytes,
                    (fan_in + pk.codes_per_byte - 1) / pk.codes_per_byte
                );
                for o in 0..fan_out {
                    for i in 0..fan_in {
                        assert_eq!(
                            pk.code(o, i),
                            quant::weight_code(w[i * fan_out + o], 0.1, bits),
                            "bits={bits} fan_in={fan_in} (o={o}, i={i})"
                        );
                    }
                    // Tail padding rule: fields past fan_in are zero.
                    let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
                    let mask = ((1u16 << pk.field) - 1) as u8;
                    for i in fan_in..pk.row_bytes * pk.codes_per_byte {
                        assert_eq!(
                            pattern_at(row, i, pk.field, pk.cpb_shift, pk.codes_per_byte - 1, mask),
                            0,
                            "padding must be the zero pattern"
                        );
                    }
                }
            }
        }
        assert!(pack(&[0.0], 0.1, 9, 1, 1).is_err(), "bits > 8 must fail closed");
        assert!(pack(&[0.0; 3], 0.1, 4, 2, 2).is_err(), "shape mismatch must fail");
    }

    #[test]
    fn packed_bytes_shrink_with_precision() {
        let (fi, fo) = (16usize, 8usize);
        let w = random_weights(fi * fo, 9);
        let p2 = pack(&w, 0.1, 2, fi, fo).unwrap();
        let p4 = pack(&w, 0.1, 4, fi, fo).unwrap();
        let p8 = pack(&w, 0.1, 8, fi, fo).unwrap();
        assert_eq!(p2.packed_bytes(), fi * fo / 4);
        assert_eq!(p4.packed_bytes(), fi * fo / 2);
        assert_eq!(p8.packed_bytes(), fi * fo);
        // vs 4 bytes/weight fake-quant: 16x / 8x / 4x smaller.
        assert_eq!(4 * fi * fo / p2.packed_bytes(), 16);
    }

    /// LUT decode reproduces the reference fake-quant GEMM bit for bit,
    /// including at fan-ins that are not multiples of the packing factor.
    #[test]
    fn lut_gemm_is_bit_identical_to_reference() {
        let mut rng = Pcg32::new(5, 6);
        for &bits in &[2u32, 4, 8] {
            for &fi in &[5usize, 7, 8, 13] {
                let (fo, batch) = (6usize, 3usize);
                let w = random_weights(fi * fo, bits as u64 + fi as u64);
                let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
                let a: Vec<f32> = (0..batch * fi)
                    .map(|i| if i % 4 == 0 { 0.0 } else { rng.normal() })
                    .collect();
                let sw = 0.13f32;
                let (qn, qp) = quant::qrange_signed(bits);
                let mut wt = vec![0f32; fi * fo];
                let mut w_in = vec![false; fi * fo];
                gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
                let mut z_ref = vec![0f32; batch * fo];
                gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
                let pk = pack(&w, sw, bits, fi, fo).unwrap();
                let mut z_pk = vec![0f32; batch * fo];
                gemm_bias_packed(&a, &pk, &bias, &mut z_pk, batch);
                assert_eq!(z_pk, z_ref, "bits={bits} fan_in={fi}");
            }
        }
    }

    /// With power-of-two step sizes and small magnitudes every f32
    /// operation in both paths is exact, so the i32 kernel must agree
    /// with the reference *bitwise* — isolating packing/decode bugs from
    /// rounding noise.
    #[test]
    fn i32_gemm_is_exact_with_pow2_scales() {
        let (fi, fo, batch) = (13usize, 4usize, 2usize);
        let (sw, sa) = (0.25f32, 0.5f32);
        let bits = 4u32;
        let (_, aqp) = quant::qrange_unsigned(bits);
        let mut rng = Pcg32::new(11, 12);
        let w: Vec<f32> = (0..fi * fo).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..fo).map(|_| (rng.below(8) as f32) * 0.25).collect();
        let acodes: Vec<u8> = (0..batch * fi)
            .map(|_| rng.below(aqp as u32 + 1) as u8)
            .collect();
        let a: Vec<f32> = acodes.iter().map(|&c| c as f32 * sa).collect();
        let (qn, qp) = quant::qrange_signed(bits);
        let mut wt = vec![0f32; fi * fo];
        let mut w_in = vec![false; fi * fo];
        gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
        let mut z_ref = vec![0f32; batch * fo];
        gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
        let pk = pack(&w, sw, bits, fi, fo).unwrap();
        let mut z_pk = vec![0f32; batch * fo];
        gemm_bias_packed_i32(&acodes, &pk, &bias, sa * sw, &mut z_pk, batch);
        for (p, r) in z_pk.iter().zip(&z_ref) {
            assert_eq!(p.to_bits(), r.to_bits(), "pow2 scales must be exact");
        }
    }

    /// General scales: the integer dot is exact, so the only divergence
    /// from the reference is bounded rounding — well inside the
    /// documented epsilon.
    #[test]
    fn i32_and_epilogue_gemm_match_reference_within_epsilon() {
        let mut rng = Pcg32::new(21, 22);
        for &bits in &[2u32, 4, 8] {
            let (fi, fo, batch) = (15usize, 5usize, 3usize);
            let (sw, sa) = (0.13f32, 0.1f32);
            let (_, aqp) = quant::qrange_unsigned(bits.min(4));
            let w = random_weights(fi * fo, 31 + bits as u64);
            let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
            let acodes: Vec<u8> = (0..batch * fi)
                .map(|_| rng.below(aqp as u32 + 1) as u8)
                .collect();
            let a: Vec<f32> = acodes.iter().map(|&c| c as f32 * sa).collect();
            let (qn, qp) = quant::qrange_signed(bits);
            let mut wt = vec![0f32; fi * fo];
            let mut w_in = vec![false; fi * fo];
            gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
            let mut z_ref = vec![0f32; batch * fo];
            gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
            let pk = pack(&w, sw, bits, fi, fo).unwrap();
            let mut z_i32 = vec![0f32; batch * fo];
            gemm_bias_packed_i32(&acodes, &pk, &bias, sa * sw, &mut z_i32, batch);
            let mut z_epi = vec![0f32; batch * fo];
            gemm_bias_packed_epilogue(&a, &pk, &bias, &mut z_epi, batch);
            for idx in 0..batch * fo {
                assert!(
                    (z_i32[idx] - z_ref[idx]).abs() <= PACKED_LOGIT_EPS,
                    "bits={bits} i32 idx={idx}: {} vs {}",
                    z_i32[idx],
                    z_ref[idx]
                );
                assert!(
                    (z_epi[idx] - z_ref[idx]).abs() <= PACKED_LOGIT_EPS,
                    "bits={bits} epilogue idx={idx}: {} vs {}",
                    z_epi[idx],
                    z_ref[idx]
                );
            }
        }
    }

    #[test]
    fn quantize_acts_matches_relu_quant_rule() {
        let z = vec![-0.3f32, 0.0, 0.04, 0.06, 1.49, 100.0];
        let (sa, aqp) = (0.1f32, 15.0f32);
        let mut codes = Vec::new();
        quantize_acts_u8(&z, sa, aqp, &mut codes);
        // Reference rule via relu_quant_act: out = code·sa.
        let mut out = vec![0f32; z.len()];
        let mut act_in = vec![false; z.len()];
        gemm::relu_quant_act(&z, sa, aqp, None, 0.0, &mut out, &mut act_in);
        for (c, o) in codes.iter().zip(&out) {
            assert_eq!((*c as f32) * sa, *o);
        }
        assert_eq!(codes, vec![0, 0, 0, 1, 15, 15]);
    }
}
