//! Bit-packed integer weight-code kernels — the serve hot path's storage
//! and compute format.
//!
//! The reference kernels ([`super::gemm`]) materialize every quantized
//! layer as full `f32` fake-quant weights (`wt[o,i] = code·sw`): 32 bits
//! per weight regardless of the 2-/4-bit precision the selection pipeline
//! fought for.  This module stores the LSQ weight **codes themselves**,
//! bit-packed into `u8` words, and executes the forward GEMM directly
//! over the packed rows — so a 2-bit layer's working set is 16× smaller
//! than its fake-quant image and stays cache-resident while serving.
//!
//! ## Packing layout
//!
//! Codes are stored **transposed** (output-major, matching the reference
//! `wt` layout): row `o` holds layer input `i = 0..fan_in` contiguously.
//! Each code occupies a fixed *storage field* of 2, 4, or 8 bits — the
//! smallest that holds the quantizer's signed range in two's complement
//! ([`crate::quant::storage_field_bits`]): 4 codes/byte at 2-bit, 2 at
//! 4-bit, 1 at 8-bit.  Fields fill each byte LSB-first.
//!
//! **Tail padding rule:** every row is independently padded to a whole
//! byte (`row_bytes = ceil(fan_in · field / 8)`), so row starts are
//! byte-aligned at any `fan_in`.  Padding fields hold the bit pattern
//! `0`, which decodes to code `0` (value `0.0`); the kernels iterate
//! `i < fan_in` and never read it, so padding can never contribute to a
//! dot product even if a future kernel over-reads a whole tail byte.
//!
//! ## Kernels and their accuracy contracts
//!
//! * [`gemm_bias_packed`] — decodes each field through the per-layer
//!   `lut[pattern] = fl(code · sw)` table and accumulates in `f32` with
//!   the **exact reference order** (bias first, `i` ascending, zero
//!   activations skipped).  Because `lut[p]` is bit-for-bit the value the
//!   reference `wt` holds for that code, this kernel is **bit-identical**
//!   to [`super::gemm::gemm_bias_wt`] — ε = 0.  It is the packed path's
//!   workhorse for every layer whose output feeds an activation
//!   quantizer: `round(h/sa)` is discontinuous, so even a 1-ulp
//!   reassociation difference in `z` could flip a code near a rounding
//!   boundary and shift downstream logits by O(sa) — which is why the
//!   scale-in-epilogue kernels below are *not* used there.
//! * [`gemm_bias_packed_epilogue`] — accumulates `Σ aᵢ·codeᵢ` in `f32`
//!   and applies the per-layer LSQ scale **once in the epilogue**
//!   (`z = bias + sw·acc`).  Used for the logits layer of the packed
//!   inference path, where nothing requantizes downstream: the
//!   reassociation error is bounded by ~`(fan_in+2)·ε_f32·(|bias| +
//!   Σ|aᵢ·wᵢ|)` ≈ 1e-5 for sim-scale layers; [`PACKED_LOGIT_EPS`]
//!   documents the contract with two orders of magnitude of margin.
//! * [`gemm_bias_packed_i32`] — the fully integer MAC: `u8` activation
//!   codes × packed weight codes with exact `i32` accumulation and one
//!   `f32` scale multiply (`sa·sw`) in the epilogue.  The integer dot is
//!   *exact*; the whole error budget is the single scale rounding (same
//!   bound as above).  This is the deployment-numerics kernel for
//!   integer hardware; on the sim proxy models the residual branches mix
//!   activations off the integer grid (`out = a_in + γ·hq`), so end to
//!   end it is exercised at the kernel/bench level while
//!   [`gemm_bias_packed`] carries the in-model packed path.
//! * [`quantize_acts_u8`] — the activation side of the integer MAC:
//!   ReLU → unsigned LSQ rounding, the identical rule as
//!   [`super::gemm::relu_quant_act`], but keeping the integer code
//!   instead of the rescaled `f32` value.
//!
//! ## Variants and row-parallelism
//!
//! Every kernel dispatches over a [`PackedVariant`]:
//!
//! * `Scalar` — the original code-at-a-time loops (`pattern_at` per MAC).
//!   The accuracy baseline every other variant is tested against.
//! * `Unrolled` (default) — whole-byte decode through 256-entry per-byte
//!   tables ([`DECODE2`]/[`DECODE4`]: 4 sign-extended codes per lookup at
//!   2-bit, 2 at 4-bit) feeding explicit 8-wide inner blocks.  The `i32`
//!   tile uses 8 independent lane accumulators (i32 addition is
//!   associative, so this is **bit-identical** to scalar); the epilogue
//!   tile reassociates its f32 lanes inside the [`PACKED_LOGIT_EPS`]
//!   contract; the ε = 0 LUT tile accelerates **only the decode** — its
//!   add order (bias first, `i` ascending, zero-skip) is untouched, so it
//!   stays bit-identical to the reference at any variant.
//! * `Simd` (`--features simd`) — 16-wide blocks over the same decode
//!   tables, written as fixed-size-array lane code the autovectorizer
//!   maps onto SSE/AVX/NEON.  Same contracts as `Unrolled`; selecting it
//!   in a build without the feature fails closed at parse time.
//!
//! Each output element `z[b,o]` is produced by exactly one dot product,
//! so the `_v` entry points ([`gemm_bias_packed_v`] etc.) additionally
//! partition `fan_out` into contiguous row bands over
//! [`crate::coordinator::job_pool`] when `threads > 1`.  Every band runs
//! the unchanged arithmetic for its own rows and results are scattered
//! back in band order — row-parallel output is bit-identical at any
//! thread count for all three tiles by construction.  Keep `threads = 1`
//! inside serve workers (the engine already runs one worker per core);
//! `mpq infer`/eval paths default to the worker-pool width.
//!
//! [`PackedNet`] bundles one model's packed layers behind `Arc`s so the
//! serving engine can materialize codes **once** and share them across
//! all N workers (see `Backend::prepare_shared` / `adopt_shared`).

use std::sync::Arc;

use crate::quant;

/// Documented per-logit bound for the scale-in-epilogue kernels
/// ([`gemm_bias_packed_epilogue`], [`gemm_bias_packed_i32`]) against the
/// reference fake-quant accumulation, at sim-model scales (fan-in ≤ a few
/// hundred, activations and weights O(1)).  The measured reassociation
/// error is ~1e-5 worst-case; 1e-3 leaves two orders of margin.
/// [`gemm_bias_packed`] needs no epsilon: it is bit-identical (ε = 0).
pub const PACKED_LOGIT_EPS: f32 = 1e-3;

/// Which implementation of the packed GEMM tiles to run.  All variants
/// satisfy the same per-kernel accuracy contracts (see the module docs);
/// the choice only trades decode/accumulation strategy for speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PackedVariant {
    /// Code-at-a-time loops — the accuracy baseline.
    Scalar,
    /// Per-byte decode tables + 8-wide unrolled blocks (stable Rust, no
    /// feature flags).  The default.
    #[default]
    Unrolled,
    /// 16-wide lane blocks behind `--features simd`.  In a build without
    /// the feature, dispatch falls back to `Unrolled` (same contracts)
    /// and [`PackedVariant::parse`] fails closed.
    Simd,
}

impl PackedVariant {
    pub fn name(self) -> &'static str {
        match self {
            PackedVariant::Scalar => "scalar",
            PackedVariant::Unrolled => "unrolled",
            PackedVariant::Simd => "simd",
        }
    }

    /// Parse a `--packed-variant` value.  `simd` is only accepted when
    /// the build actually carries the simd tiles, so a serve fleet can
    /// never silently run a slower fallback than the flag promised.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "scalar" => Ok(PackedVariant::Scalar),
            "unrolled" => Ok(PackedVariant::Unrolled),
            "simd" => {
                #[cfg(feature = "simd")]
                {
                    Ok(PackedVariant::Simd)
                }
                #[cfg(not(feature = "simd"))]
                {
                    crate::bail!(
                        "packed variant 'simd' needs a build with --features simd \
                         (this build has scalar|unrolled)"
                    )
                }
            }
            other => crate::bail!(
                "unknown packed variant '{other}' (expected scalar|unrolled|simd)"
            ),
        }
    }
}

/// Resolve the GEMM row-parallelism width from `MPQ_GEMM_THREADS`,
/// falling back to `fallback` when the variable is unset, empty, or not
/// a positive integer.  CLI `--gemm-threads` overrides both.
pub fn gemm_threads_from_env(fallback: usize) -> usize {
    match std::env::var("MPQ_GEMM_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => fallback,
        },
        Err(_) => fallback,
    }
}

/// One layer's bit-packed weight codes plus decode tables.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    /// Logical quantizer width the codes were produced at.
    pub bits: u32,
    /// Storage field width (2, 4, or 8 bits; ≥ `bits`).
    pub field: u32,
    /// Codes per byte (`8 / field`).
    pub codes_per_byte: usize,
    /// `log2(codes_per_byte)` — the hot loops locate a code's byte with
    /// `i >> cpb_shift` and its in-byte slot with `i & (codes_per_byte -
    /// 1)`, so decode compiles to shifts/masks instead of a runtime
    /// divide/modulo per MAC.
    pub cpb_shift: u32,
    /// Bytes per output row (`ceil(fan_in / codes_per_byte)` — the tail
    /// padding rule).
    pub row_bytes: usize,
    /// Packed codes, `fan_out` rows × `row_bytes`.
    pub data: Vec<u8>,
    /// The layer's LSQ weight step size.
    pub sw: f32,
    /// `lut[pattern] = fl(clamp(code)·sw)` for every field pattern —
    /// bit-for-bit the reference `wt` value for that code.
    pub lut: Vec<f32>,
    /// `lut_code[pattern] = code as f32` (exact small integers) for the
    /// scale-in-epilogue kernels.
    pub lut_code: Vec<f32>,
}

/// Sign-extend a `field`-bit two's-complement pattern to `i32`.
#[inline]
fn sign_extend(pattern: u8, field: u32) -> i32 {
    ((pattern as i32) << (32 - field)) >> (32 - field)
}

/// Extract the `i`-th field pattern of a packed row.  `cpb_shift` is
/// `log2(codes_per_byte)` and `slot_mask` is `codes_per_byte - 1`
/// (codes-per-byte is always a power of two), so this is pure
/// shift/mask work on the hot path.
#[inline]
fn pattern_at(
    row: &[u8],
    i: usize,
    field: u32,
    cpb_shift: u32,
    slot_mask: usize,
    mask: u8,
) -> usize {
    let byte = row[i >> cpb_shift];
    ((byte >> (((i & slot_mask) as u32) * field)) & mask) as usize
}

/// Sign-extended code at position `i` of a packed row — the scalar-tail
/// decode the unrolled/simd tiles use past their last full block.
#[inline]
fn code_at(pk: &PackedLayer, row: &[u8], i: usize) -> i32 {
    let mask = ((1u16 << pk.field) - 1) as u8;
    sign_extend(
        pattern_at(row, i, pk.field, pk.cpb_shift, pk.codes_per_byte - 1, mask) as u8,
        pk.field,
    )
}

const fn build_decode2() -> [[i8; 4]; 256] {
    let mut t = [[0i8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut s = 0usize;
        while s < 4 {
            let v = ((b >> (2 * s)) & 0b11) as i8;
            t[b][s] = if v >= 2 { v - 4 } else { v };
            s += 1;
        }
        b += 1;
    }
    t
}

const fn build_decode4() -> [[i8; 2]; 256] {
    let mut t = [[0i8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut s = 0usize;
        while s < 2 {
            let v = ((b >> (4 * s)) & 0xF) as i8;
            t[b][s] = if v >= 8 { v - 16 } else { v };
            s += 1;
        }
        b += 1;
    }
    t
}

/// Whole-byte decode table at 2-bit fields: one lookup yields all 4
/// sign-extended codes of a byte (LSB-first slot order, matching the
/// packing layout).
static DECODE2: [[i8; 4]; 256] = build_decode2();
/// Whole-byte decode table at 4-bit fields: one lookup yields both
/// sign-extended codes of a byte.
static DECODE4: [[i8; 2]; 256] = build_decode4();

/// Decode 8 consecutive codes starting at `base` (a multiple of 8, so
/// every field width lands on a byte boundary) into `dst`.
#[inline]
fn decode8(row: &[u8], base: usize, field: u32, dst: &mut [i32; 8]) {
    match field {
        2 => {
            for s in 0..2 {
                let d = &DECODE2[row[(base >> 2) + s] as usize];
                for j in 0..4 {
                    dst[s * 4 + j] = d[j] as i32;
                }
            }
        }
        4 => {
            for s in 0..4 {
                let d = &DECODE4[row[(base >> 1) + s] as usize];
                dst[s * 2] = d[0] as i32;
                dst[s * 2 + 1] = d[1] as i32;
            }
        }
        _ => {
            for j in 0..8 {
                dst[j] = row[base + j] as i8 as i32;
            }
        }
    }
}

/// Decode 16 consecutive codes starting at `base` (a multiple of 16).
#[cfg(feature = "simd")]
#[inline]
fn decode16(row: &[u8], base: usize, field: u32, dst: &mut [i32; 16]) {
    match field {
        2 => {
            for s in 0..4 {
                let d = &DECODE2[row[(base >> 2) + s] as usize];
                for j in 0..4 {
                    dst[s * 4 + j] = d[j] as i32;
                }
            }
        }
        4 => {
            for s in 0..8 {
                let d = &DECODE4[row[(base >> 1) + s] as usize];
                dst[s * 2] = d[0] as i32;
                dst[s * 2 + 1] = d[1] as i32;
            }
        }
        _ => {
            for j in 0..16 {
                dst[j] = row[base + j] as i8 as i32;
            }
        }
    }
}

/// Fixed pairwise reduction of 16 f32 lanes — one deterministic tree
/// shape regardless of target, so simd results are reproducible.
#[cfg(feature = "simd")]
#[inline]
fn tree_sum16_f32(l: &[f32; 16]) -> f32 {
    let q0 = (l[0] + l[1]) + (l[2] + l[3]);
    let q1 = (l[4] + l[5]) + (l[6] + l[7]);
    let q2 = (l[8] + l[9]) + (l[10] + l[11]);
    let q3 = (l[12] + l[13]) + (l[14] + l[15]);
    (q0 + q1) + (q2 + q3)
}

impl PackedLayer {
    /// Decode one weight code (diagnostics/tests; the kernels inline the
    /// extraction).
    pub fn code(&self, o: usize, i: usize) -> i32 {
        let row = &self.data[o * self.row_bytes..(o + 1) * self.row_bytes];
        let mask = ((1u16 << self.field) - 1) as u8;
        sign_extend(
            pattern_at(row, i, self.field, self.cpb_shift, self.codes_per_byte - 1, mask) as u8,
            self.field,
        )
    }

    /// Total packed bytes (the working-set win over `4 · fan_in · fan_out`
    /// fake-quant bytes).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Bit-pack a weight tensor's LSQ codes at `bits` into the transposed
/// packed layout.  `w` is in parameter layout (`w[i·fan_out + o]`, as the
/// backends hold it); codes are computed exactly as the reference
/// quantizer does (`round(w/sw)` clamped to the signed range — see
/// [`crate::quant::weight_code`]).
pub fn pack(
    w: &[f32],
    sw: f32,
    bits: u32,
    fan_in: usize,
    fan_out: usize,
) -> crate::Result<PackedLayer> {
    crate::ensure!(
        (1..=8).contains(&bits),
        "packed kernels support 1..=8-bit weight codes, got {bits}-bit"
    );
    crate::ensure!(
        w.len() == fan_in * fan_out,
        "pack: weight tensor has {} elements, expected {}x{}",
        w.len(),
        fan_in,
        fan_out
    );
    let field = quant::storage_field_bits(bits);
    let codes_per_byte = (8 / field) as usize;
    let row_bytes = (fan_in + codes_per_byte - 1) / codes_per_byte;
    let mask = ((1u16 << field) - 1) as u8;
    let mut data = vec![0u8; fan_out * row_bytes];
    for o in 0..fan_out {
        let row = &mut data[o * row_bytes..(o + 1) * row_bytes];
        for i in 0..fan_in {
            let code = quant::weight_code(w[i * fan_out + o], sw, bits);
            let shift = ((i % codes_per_byte) as u32) * field;
            row[i / codes_per_byte] |= ((code as u8) & mask) << shift;
        }
    }
    // Decode tables over every field pattern.  Stored codes are already
    // in the quantizer range; the clamp makes even a corrupt pattern
    // decode to an in-range value.  For in-range codes `clamp` is the
    // identity, so `lut[p]` carries the exact f32 product the reference
    // `quantize_weights_wt` writes into `wt`.
    let (qn, qp) = quant::qrange_signed(bits);
    let mut lut = Vec::with_capacity(1 << field);
    let mut lut_code = Vec::with_capacity(1 << field);
    for p in 0..(1u16 << field) as usize {
        let c = (sign_extend(p as u8, field) as f32).clamp(qn, qp);
        lut.push(c * sw);
        lut_code.push(c);
    }
    Ok(PackedLayer {
        fan_in,
        fan_out,
        bits,
        field,
        codes_per_byte,
        cpb_shift: codes_per_byte.trailing_zeros(),
        row_bytes,
        data,
        sw,
        lut,
        lut_code,
    })
}

// ---------------------------------------------------------------------------
// Band implementations.  Every kernel body below computes rows `o0..o1`
// of the output into `z`, whose row stride is the band width `o1 - o0`
// (the full-output case is simply the band `0..fan_out`).  Keeping the
// tiles in band form is what makes row-parallelism bit-identical: each
// `z[b,o]` is produced by exactly one band running the unchanged
// arithmetic.
// ---------------------------------------------------------------------------

fn lut_scalar_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = bias[o];
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * pk.lut[pattern_at(row, i, pk.field, shift, slot, mask)];
                }
            }
            *zv = acc;
        }
    }
}

/// Decode-accelerated ε = 0 tile: whole-byte table lookups, but the add
/// sequence (bias first, `i` ascending, zero activations skipped) is the
/// scalar tile's exactly — only the pattern extraction changed, so the
/// bit-identity contract survives.  Shared by `Unrolled` and `Simd`
/// dispatch: the pinned add order leaves no wider formulation.
fn lut_unrolled_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = bias[o];
            match pk.field {
                2 => {
                    let full = fi >> 2;
                    for (ach, &byte) in arow[..full * 4].chunks_exact(4).zip(&row[..full]) {
                        let b = byte as usize;
                        if ach[0] != 0.0 {
                            acc += ach[0] * pk.lut[b & 3];
                        }
                        if ach[1] != 0.0 {
                            acc += ach[1] * pk.lut[(b >> 2) & 3];
                        }
                        if ach[2] != 0.0 {
                            acc += ach[2] * pk.lut[(b >> 4) & 3];
                        }
                        if ach[3] != 0.0 {
                            acc += ach[3] * pk.lut[(b >> 6) & 3];
                        }
                    }
                    for (i, &av) in arow.iter().enumerate().skip(full * 4) {
                        if av != 0.0 {
                            acc += av * pk.lut[pattern_at(row, i, 2, 2, 3, 0b11)];
                        }
                    }
                }
                4 => {
                    let full = fi >> 1;
                    for (ach, &byte) in arow[..full * 2].chunks_exact(2).zip(&row[..full]) {
                        let b = byte as usize;
                        if ach[0] != 0.0 {
                            acc += ach[0] * pk.lut[b & 0xF];
                        }
                        if ach[1] != 0.0 {
                            acc += ach[1] * pk.lut[(b >> 4) & 0xF];
                        }
                    }
                    for (i, &av) in arow.iter().enumerate().skip(full * 2) {
                        if av != 0.0 {
                            acc += av * pk.lut[pattern_at(row, i, 4, 1, 1, 0xF)];
                        }
                    }
                }
                _ => {
                    for (&av, &byte) in arow.iter().zip(&row[..fi]) {
                        if av != 0.0 {
                            acc += av * pk.lut[byte as usize];
                        }
                    }
                }
            }
            *zv = acc;
        }
    }
}

fn epi_scalar_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = 0f32;
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * pk.lut_code[pattern_at(row, i, pk.field, shift, slot, mask)];
                }
            }
            *zv = bias[o] + pk.sw * acc;
        }
    }
}

/// 8-lane unrolled epilogue tile.  f32 lanes reassociate the dot product
/// (fixed pairwise tree, zero-skip dropped) — allowed by the
/// [`PACKED_LOGIT_EPS`] contract, which bounds exactly this class of
/// reordering.
fn epi_unrolled_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let blocks = fi >> 3;
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut lanes = [0f32; 8];
            let mut w8 = [0i32; 8];
            for blk in 0..blocks {
                let base = blk * 8;
                decode8(row, base, pk.field, &mut w8);
                for j in 0..8 {
                    lanes[j] += arow[base + j] * w8[j] as f32;
                }
            }
            let mut acc = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
                + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
            for i in blocks * 8..fi {
                acc += arow[i] * code_at(pk, row, i) as f32;
            }
            *zv = bias[o] + pk.sw * acc;
        }
    }
}

fn i32_scalar_band(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let mask = ((1u16 << pk.field) - 1) as u8;
    let (shift, slot) = (pk.cpb_shift, pk.codes_per_byte - 1);
    for bi in 0..batch {
        let arow = &acodes[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut acc = 0i32;
            for (i, &ac) in arow.iter().enumerate() {
                if ac != 0 {
                    let p = pattern_at(row, i, pk.field, shift, slot, mask);
                    acc += (ac as i32) * sign_extend(p as u8, pk.field);
                }
            }
            *zv = bias[o] + scale * acc as f32;
        }
    }
}

/// 8-lane unrolled integer tile.  i32 addition is associative and the
/// zero-skip is a pure shortcut in integers (`0·w = 0` exactly), so lane
/// accumulators + unconditional MACs are **bit-identical** to the scalar
/// tile — full lane parallelism at ε = 0.
fn i32_unrolled_band(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let blocks = fi >> 3;
    for bi in 0..batch {
        let arow = &acodes[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut lanes = [0i32; 8];
            let mut w8 = [0i32; 8];
            for blk in 0..blocks {
                let base = blk * 8;
                decode8(row, base, pk.field, &mut w8);
                for j in 0..8 {
                    lanes[j] += arow[base + j] as i32 * w8[j];
                }
            }
            let mut acc: i32 = lanes.iter().sum();
            for i in blocks * 8..fi {
                acc += arow[i] as i32 * code_at(pk, row, i);
            }
            *zv = bias[o] + scale * acc as f32;
        }
    }
}

#[cfg(feature = "simd")]
fn epi_simd_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let blocks = fi >> 4;
    for bi in 0..batch {
        let arow = &a[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut lanes = [0f32; 16];
            let mut w16 = [0i32; 16];
            for blk in 0..blocks {
                let base = blk * 16;
                decode16(row, base, pk.field, &mut w16);
                for j in 0..16 {
                    lanes[j] += arow[base + j] * w16[j] as f32;
                }
            }
            let mut acc = tree_sum16_f32(&lanes);
            for i in blocks * 16..fi {
                acc += arow[i] * code_at(pk, row, i) as f32;
            }
            *zv = bias[o] + pk.sw * acc;
        }
    }
}

#[cfg(feature = "simd")]
fn i32_simd_band(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
) {
    let fi = pk.fan_in;
    let bw = o1 - o0;
    let blocks = fi >> 4;
    for bi in 0..batch {
        let arow = &acodes[bi * fi..(bi + 1) * fi];
        let zrow = &mut z[bi * bw..(bi + 1) * bw];
        for (k, zv) in zrow.iter_mut().enumerate() {
            let o = o0 + k;
            let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
            let mut lanes = [0i32; 16];
            let mut w16 = [0i32; 16];
            for blk in 0..blocks {
                let base = blk * 16;
                decode16(row, base, pk.field, &mut w16);
                for j in 0..16 {
                    lanes[j] += arow[base + j] as i32 * w16[j];
                }
            }
            let mut acc: i32 = lanes.iter().sum();
            for i in blocks * 16..fi {
                acc += arow[i] as i32 * code_at(pk, row, i);
            }
            *zv = bias[o] + scale * acc as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// Variant dispatch + row-band driver.
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn lut_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
    variant: PackedVariant,
) {
    match variant {
        PackedVariant::Scalar => lut_scalar_band(a, pk, bias, z, batch, o0, o1),
        // The ε = 0 contract pins the add order, so both wide variants
        // share the decode-accelerated, order-exact tile.
        PackedVariant::Unrolled | PackedVariant::Simd => {
            lut_unrolled_band(a, pk, bias, z, batch, o0, o1)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn epi_band(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
    variant: PackedVariant,
) {
    match variant {
        PackedVariant::Scalar => epi_scalar_band(a, pk, bias, z, batch, o0, o1),
        PackedVariant::Unrolled => epi_unrolled_band(a, pk, bias, z, batch, o0, o1),
        PackedVariant::Simd => {
            #[cfg(feature = "simd")]
            epi_simd_band(a, pk, bias, z, batch, o0, o1);
            #[cfg(not(feature = "simd"))]
            epi_unrolled_band(a, pk, bias, z, batch, o0, o1);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn i32_band(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
    o0: usize,
    o1: usize,
    variant: PackedVariant,
) {
    match variant {
        PackedVariant::Scalar => i32_scalar_band(acodes, pk, bias, scale, z, batch, o0, o1),
        PackedVariant::Unrolled => i32_unrolled_band(acodes, pk, bias, scale, z, batch, o0, o1),
        PackedVariant::Simd => {
            #[cfg(feature = "simd")]
            i32_simd_band(acodes, pk, bias, scale, z, batch, o0, o1);
            #[cfg(not(feature = "simd"))]
            i32_unrolled_band(acodes, pk, bias, scale, z, batch, o0, o1);
        }
    }
}

/// Partition `fan_out` into ≤ `threads` contiguous row bands and run
/// `run_band(o0, o1, band_buf)` for each, scattering band buffers back
/// into `z` in band order.  `threads ≤ 1` runs the whole output as one
/// band directly in `z` — no allocation, no pool.  Each `z[b,o]` is
/// written by exactly one band executing the unchanged tile arithmetic,
/// so the result is bit-identical at any thread count.
fn banded(
    fo: usize,
    batch: usize,
    z: &mut [f32],
    threads: usize,
    run_band: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    let t = threads.max(1).min(fo.max(1));
    if t <= 1 {
        run_band(0, fo, z);
        return;
    }
    let (base, extra) = (fo / t, fo % t);
    let mut bands = Vec::with_capacity(t);
    let mut start = 0usize;
    for k in 0..t {
        let len = base + usize::from(k < extra);
        bands.push((start, start + len));
        start += len;
    }
    let results = crate::coordinator::job_pool(
        bands,
        t,
        || Ok(()),
        |_, (o0, o1)| {
            let mut buf = vec![0f32; batch * (o1 - o0)];
            run_band(o0, o1, &mut buf);
            Ok((o0, o1, buf))
        },
    )
    .expect("packed: row-band pool is infallible");
    for (o0, o1, buf) in results {
        let bw = o1 - o0;
        for bi in 0..batch {
            z[bi * fo + o0..bi * fo + o1].copy_from_slice(&buf[bi * bw..(bi + 1) * bw]);
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

/// Forward tile over packed rows with LUT decode:
/// `z[b,o] = bias[o] + Σ_i a[b,i] · lut[code(o,i)]`.
///
/// Accumulation contract: bias first, `i` ascending, exact skip of zero
/// activations — the identical add sequence as
/// [`super::gemm::gemm_bias_wt`] over identical operand bits, so the
/// result is **bit-identical** to the reference fake-quant forward.
/// Runs the default [`PackedVariant`] single-threaded; see
/// [`gemm_bias_packed_v`] for variant/thread control.
pub fn gemm_bias_packed(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
) {
    gemm_bias_packed_v(a, pk, bias, z, batch, PackedVariant::default(), 1);
}

/// [`gemm_bias_packed`] with explicit variant and row-parallel width.
/// Every variant preserves the ε = 0 contract (decode-only acceleration,
/// add order untouched), and row bands are bit-identical at any
/// `threads` by construction.
pub fn gemm_bias_packed_v(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    variant: PackedVariant,
    threads: usize,
) {
    let lt = super::ltrace::enter();
    banded(pk.fan_out, batch, z, threads, |o0, o1, band| {
        lut_band(a, pk, bias, band, batch, o0, o1, variant)
    });
    if let Some(t0) = lt {
        super::ltrace::exit(t0, pk.bits, variant.name());
    }
}

/// Forward tile with the per-layer LSQ scale applied **once in the
/// epilogue**: `acc = Σ_i a[b,i] · code(o,i)` in f32 (codes are exact
/// small integers), then `z[b,o] = bias[o] + sw · acc`.
///
/// Not bit-identical to the reference — the scale reassociation costs a
/// bounded rounding difference ([`PACKED_LOGIT_EPS`]).  Safe only where
/// no activation quantizer consumes `z` (the logits layer).  Runs the
/// default [`PackedVariant`] single-threaded; see
/// [`gemm_bias_packed_epilogue_v`].
pub fn gemm_bias_packed_epilogue(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
) {
    gemm_bias_packed_epilogue_v(a, pk, bias, z, batch, PackedVariant::default(), 1);
}

/// [`gemm_bias_packed_epilogue`] with explicit variant and row-parallel
/// width.  Wide variants reassociate f32 lanes inside the
/// [`PACKED_LOGIT_EPS`] contract; row bands are bit-identical at any
/// `threads`.
pub fn gemm_bias_packed_epilogue_v(
    a: &[f32],
    pk: &PackedLayer,
    bias: &[f32],
    z: &mut [f32],
    batch: usize,
    variant: PackedVariant,
    threads: usize,
) {
    let lt = super::ltrace::enter();
    banded(pk.fan_out, batch, z, threads, |o0, o1, band| {
        epi_band(a, pk, bias, band, batch, o0, o1, variant)
    });
    if let Some(t0) = lt {
        super::ltrace::exit(t0, pk.bits, variant.name());
    }
}

/// The fully integer MAC tile: `u8` activation codes × packed weight
/// codes, **exact `i32` accumulation**, one scale multiply in the
/// epilogue:
///
/// `z[b,o] = bias[o] + scale · (Σ_i acode[b,i] · code(o,i))`
///
/// where `scale` is the product of the incoming activation step size and
/// this layer's weight step size (`sa_in · sw`).  The integer dot is
/// exact (no rounding at any accumulation step: |acc| ≤ fan_in·255·128
/// fits i32 for any fan_in ≤ 2¹⁶); the entire f32 error is the epilogue
/// multiply-add ([`PACKED_LOGIT_EPS`]).  Runs the default
/// [`PackedVariant`] single-threaded; see [`gemm_bias_packed_i32_v`].
pub fn gemm_bias_packed_i32(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
) {
    gemm_bias_packed_i32_v(acodes, pk, bias, scale, z, batch, PackedVariant::default(), 1);
}

/// [`gemm_bias_packed_i32`] with explicit variant and row-parallel
/// width.  i32 addition is associative, so every variant is
/// **bit-identical** to the scalar tile, and row bands are bit-identical
/// at any `threads`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_packed_i32_v(
    acodes: &[u8],
    pk: &PackedLayer,
    bias: &[f32],
    scale: f32,
    z: &mut [f32],
    batch: usize,
    variant: PackedVariant,
    threads: usize,
) {
    let lt = super::ltrace::enter();
    banded(pk.fan_out, batch, z, threads, |o0, o1, band| {
        i32_band(acodes, pk, bias, scale, band, batch, o0, o1, variant)
    });
    if let Some(t0) = lt {
        super::ltrace::exit(t0, pk.bits, variant.name());
    }
}

/// ReLU → unsigned LSQ activation **codes** — the same rounding rule as
/// [`super::gemm::relu_quant_act`] (`clamp(round(max(z,0)/sa), 0, aqp)`),
/// kept as integers for [`gemm_bias_packed_i32`].  `aqp` must be ≤ 255
/// (8-bit unsigned activations), which [`crate::quant::qrange_unsigned`]
/// guarantees for bits ≤ 8.  Pass a [`super::LayerWs`]'s `acodes`
/// scratch on hot paths so the buffer's capacity is reused across
/// requests instead of reallocated.
pub fn quantize_acts_u8(z: &[f32], sa: f32, aqp: f32, codes: &mut Vec<u8>) {
    debug_assert!(aqp <= 255.0);
    codes.clear();
    codes.reserve(z.len());
    codes.extend(
        z.iter()
            .map(|&zv| (zv.max(0.0) / sa).round().clamp(0.0, aqp) as u8),
    );
}

/// One model's packed layers at one (checkpoint, bits) configuration —
/// the immutable state the serving engine materializes once and shares
/// across its worker pool (`Backend::prepare_shared` / `adopt_shared`).
#[derive(Debug, Clone)]
pub struct PackedNet {
    /// Effective per-layer precision the codes were packed at (fixed
    /// layers pinned), used to fail closed on a config mismatch.
    pub bits_eff: Vec<u32>,
    pub layers: Vec<Arc<PackedLayer>>,
}

impl PackedNet {
    /// Total packed bytes across the model.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm;
    use crate::rng::Pcg32;

    fn random_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0x7061_636b);
        (0..n).map(|_| rng.normal() * 0.3).collect()
    }

    #[test]
    fn pack_round_trips_codes_at_any_fan_in() {
        for &bits in &[1u32, 2, 3, 4, 5, 8] {
            for &fan_in in &[1usize, 3, 4, 5, 7, 8, 13, 16] {
                let fan_out = 3;
                let w = random_weights(fan_in * fan_out, bits as u64 * 100 + fan_in as u64);
                let pk = pack(&w, 0.1, bits, fan_in, fan_out).unwrap();
                assert_eq!(pk.field, quant::storage_field_bits(bits));
                assert_eq!(
                    pk.row_bytes,
                    (fan_in + pk.codes_per_byte - 1) / pk.codes_per_byte
                );
                for o in 0..fan_out {
                    for i in 0..fan_in {
                        assert_eq!(
                            pk.code(o, i),
                            quant::weight_code(w[i * fan_out + o], 0.1, bits),
                            "bits={bits} fan_in={fan_in} (o={o}, i={i})"
                        );
                    }
                    // Tail padding rule: fields past fan_in are zero.
                    let row = &pk.data[o * pk.row_bytes..(o + 1) * pk.row_bytes];
                    let mask = ((1u16 << pk.field) - 1) as u8;
                    for i in fan_in..pk.row_bytes * pk.codes_per_byte {
                        assert_eq!(
                            pattern_at(row, i, pk.field, pk.cpb_shift, pk.codes_per_byte - 1, mask),
                            0,
                            "padding must be the zero pattern"
                        );
                    }
                }
            }
        }
        assert!(pack(&[0.0], 0.1, 9, 1, 1).is_err(), "bits > 8 must fail closed");
        assert!(pack(&[0.0; 3], 0.1, 4, 2, 2).is_err(), "shape mismatch must fail");
    }

    #[test]
    fn packed_bytes_shrink_with_precision() {
        let (fi, fo) = (16usize, 8usize);
        let w = random_weights(fi * fo, 9);
        let p2 = pack(&w, 0.1, 2, fi, fo).unwrap();
        let p4 = pack(&w, 0.1, 4, fi, fo).unwrap();
        let p8 = pack(&w, 0.1, 8, fi, fo).unwrap();
        assert_eq!(p2.packed_bytes(), fi * fo / 4);
        assert_eq!(p4.packed_bytes(), fi * fo / 2);
        assert_eq!(p8.packed_bytes(), fi * fo);
        // vs 4 bytes/weight fake-quant: 16x / 8x / 4x smaller.
        assert_eq!(4 * fi * fo / p2.packed_bytes(), 16);
    }

    #[test]
    fn decode_tables_match_sign_extension() {
        for b in 0..256usize {
            for s in 0..4 {
                let p = ((b >> (2 * s)) & 0b11) as u8;
                assert_eq!(DECODE2[b][s] as i32, sign_extend(p, 2), "byte={b} slot={s}");
            }
            for s in 0..2 {
                let p = ((b >> (4 * s)) & 0xF) as u8;
                assert_eq!(DECODE4[b][s] as i32, sign_extend(p, 4), "byte={b} slot={s}");
            }
        }
    }

    #[test]
    fn packed_variant_parse_round_trip() {
        assert_eq!(PackedVariant::default(), PackedVariant::Unrolled);
        for v in [PackedVariant::Scalar, PackedVariant::Unrolled] {
            assert_eq!(PackedVariant::parse(v.name()).unwrap(), v);
        }
        #[cfg(feature = "simd")]
        assert_eq!(PackedVariant::parse("simd").unwrap(), PackedVariant::Simd);
        #[cfg(not(feature = "simd"))]
        {
            let err = PackedVariant::parse("simd").unwrap_err().to_string();
            assert!(err.contains("--features simd"), "fail-closed message: {err}");
        }
        let err = PackedVariant::parse("wide").unwrap_err().to_string();
        assert!(err.contains("unknown packed variant"), "{err}");
    }

    /// LUT decode reproduces the reference fake-quant GEMM bit for bit,
    /// including at fan-ins that are not multiples of the packing factor.
    #[test]
    fn lut_gemm_is_bit_identical_to_reference() {
        let mut rng = Pcg32::new(5, 6);
        for &bits in &[2u32, 4, 8] {
            for &fi in &[5usize, 7, 8, 13] {
                let (fo, batch) = (6usize, 3usize);
                let w = random_weights(fi * fo, bits as u64 + fi as u64);
                let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
                let a: Vec<f32> = (0..batch * fi)
                    .map(|i| if i % 4 == 0 { 0.0 } else { rng.normal() })
                    .collect();
                let sw = 0.13f32;
                let (qn, qp) = quant::qrange_signed(bits);
                let mut wt = vec![0f32; fi * fo];
                let mut w_in = vec![false; fi * fo];
                gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
                let mut z_ref = vec![0f32; batch * fo];
                gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
                let pk = pack(&w, sw, bits, fi, fo).unwrap();
                let mut z_pk = vec![0f32; batch * fo];
                gemm_bias_packed(&a, &pk, &bias, &mut z_pk, batch);
                assert_eq!(z_pk, z_ref, "bits={bits} fan_in={fi}");
            }
        }
    }

    /// With power-of-two step sizes and small magnitudes every f32
    /// operation in both paths is exact, so the i32 kernel must agree
    /// with the reference *bitwise* — isolating packing/decode bugs from
    /// rounding noise.
    #[test]
    fn i32_gemm_is_exact_with_pow2_scales() {
        let (fi, fo, batch) = (13usize, 4usize, 2usize);
        let (sw, sa) = (0.25f32, 0.5f32);
        let bits = 4u32;
        let (_, aqp) = quant::qrange_unsigned(bits);
        let mut rng = Pcg32::new(11, 12);
        let w: Vec<f32> = (0..fi * fo).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..fo).map(|_| (rng.below(8) as f32) * 0.25).collect();
        let acodes: Vec<u8> = (0..batch * fi)
            .map(|_| rng.below(aqp as u32 + 1) as u8)
            .collect();
        let a: Vec<f32> = acodes.iter().map(|&c| c as f32 * sa).collect();
        let (qn, qp) = quant::qrange_signed(bits);
        let mut wt = vec![0f32; fi * fo];
        let mut w_in = vec![false; fi * fo];
        gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
        let mut z_ref = vec![0f32; batch * fo];
        gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
        let pk = pack(&w, sw, bits, fi, fo).unwrap();
        let mut z_pk = vec![0f32; batch * fo];
        gemm_bias_packed_i32(&acodes, &pk, &bias, sa * sw, &mut z_pk, batch);
        for (p, r) in z_pk.iter().zip(&z_ref) {
            assert_eq!(p.to_bits(), r.to_bits(), "pow2 scales must be exact");
        }
    }

    /// General scales: the integer dot is exact, so the only divergence
    /// from the reference is bounded rounding — well inside the
    /// documented epsilon.
    #[test]
    fn i32_and_epilogue_gemm_match_reference_within_epsilon() {
        let mut rng = Pcg32::new(21, 22);
        for &bits in &[2u32, 4, 8] {
            let (fi, fo, batch) = (15usize, 5usize, 3usize);
            let (sw, sa) = (0.13f32, 0.1f32);
            let (_, aqp) = quant::qrange_unsigned(bits.min(4));
            let w = random_weights(fi * fo, 31 + bits as u64);
            let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
            let acodes: Vec<u8> = (0..batch * fi)
                .map(|_| rng.below(aqp as u32 + 1) as u8)
                .collect();
            let a: Vec<f32> = acodes.iter().map(|&c| c as f32 * sa).collect();
            let (qn, qp) = quant::qrange_signed(bits);
            let mut wt = vec![0f32; fi * fo];
            let mut w_in = vec![false; fi * fo];
            gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
            let mut z_ref = vec![0f32; batch * fo];
            gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);
            let pk = pack(&w, sw, bits, fi, fo).unwrap();
            let mut z_i32 = vec![0f32; batch * fo];
            gemm_bias_packed_i32(&acodes, &pk, &bias, sa * sw, &mut z_i32, batch);
            let mut z_epi = vec![0f32; batch * fo];
            gemm_bias_packed_epilogue(&a, &pk, &bias, &mut z_epi, batch);
            for idx in 0..batch * fo {
                assert!(
                    (z_i32[idx] - z_ref[idx]).abs() <= PACKED_LOGIT_EPS,
                    "bits={bits} i32 idx={idx}: {} vs {}",
                    z_i32[idx],
                    z_ref[idx]
                );
                assert!(
                    (z_epi[idx] - z_ref[idx]).abs() <= PACKED_LOGIT_EPS,
                    "bits={bits} epilogue idx={idx}: {} vs {}",
                    z_epi[idx],
                    z_ref[idx]
                );
            }
        }
    }

    /// The tentpole property: across every fan-in 1..=67 (crossing every
    /// byte/block boundary of the 8- and 16-wide tiles) × storage widths,
    /// the unrolled (and simd, when built) variants are bit-identical to
    /// scalar on the i32 tile, bit-identical to the *reference* on the
    /// ε = 0 LUT tile, and inside [`PACKED_LOGIT_EPS`] on the epilogue
    /// tile.  `Simd` is exercised even without the feature (it must fall
    /// back to `Unrolled`, which carries the same contracts).
    #[test]
    fn variant_kernels_are_bit_identical_across_fan_in() {
        let variants = [
            PackedVariant::Scalar,
            PackedVariant::Unrolled,
            PackedVariant::Simd,
        ];
        for &bits in &[2u32, 4, 8] {
            for fi in 1usize..=67 {
                let (fo, batch) = (4usize, 2usize);
                let (sw, sa) = (0.13f32, 0.1f32);
                let mut rng = Pcg32::new(fi as u64 * 1000 + bits as u64, 77);
                let w = random_weights(fi * fo, fi as u64 * 31 + bits as u64);
                let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
                let acodes: Vec<u8> = (0..batch * fi).map(|_| rng.below(16) as u8).collect();
                let a: Vec<f32> = (0..batch * fi)
                    .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() })
                    .collect();
                let pk = pack(&w, sw, bits, fi, fo).unwrap();

                // ε = 0 reference for the LUT tile.
                let (qn, qp) = quant::qrange_signed(bits);
                let mut wt = vec![0f32; fi * fo];
                let mut w_in = vec![false; fi * fo];
                gemm::quantize_weights_wt(&w, sw, qn, qp, &mut wt, &mut w_in, fi, fo);
                let mut z_ref = vec![0f32; batch * fo];
                gemm::gemm_bias_wt(&a, &wt, &bias, &mut z_ref, batch, fi, fo);

                let mut z_i32_scalar = vec![0f32; batch * fo];
                gemm_bias_packed_i32_v(
                    &acodes, &pk, &bias, sa * sw, &mut z_i32_scalar, batch,
                    PackedVariant::Scalar, 1,
                );
                for &v in &variants {
                    let mut z_l = vec![0f32; batch * fo];
                    gemm_bias_packed_v(&a, &pk, &bias, &mut z_l, batch, v, 1);
                    for (got, want) in z_l.iter().zip(&z_ref) {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "bits={bits} fi={fi} lut {} vs reference",
                            v.name()
                        );
                    }
                    let mut z_i = vec![0f32; batch * fo];
                    gemm_bias_packed_i32_v(&acodes, &pk, &bias, sa * sw, &mut z_i, batch, v, 1);
                    for (got, want) in z_i.iter().zip(&z_i32_scalar) {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "bits={bits} fi={fi} i32 {} vs scalar",
                            v.name()
                        );
                    }
                    let mut z_e = vec![0f32; batch * fo];
                    gemm_bias_packed_epilogue_v(&a, &pk, &bias, &mut z_e, batch, v, 1);
                    for (got, want) in z_e.iter().zip(&z_ref) {
                        assert!(
                            (got - want).abs() <= PACKED_LOGIT_EPS,
                            "bits={bits} fi={fi} epilogue {}: {} vs {}",
                            v.name(),
                            got,
                            want
                        );
                    }
                }
            }
        }
    }

    /// Row-band parallelism must be invisible: for every tile × variant ×
    /// thread count (including counts that don't divide fan_out), the
    /// output is bit-identical to the single-threaded run.
    #[test]
    fn row_parallel_is_bit_identical_at_any_thread_count() {
        let variants = [
            PackedVariant::Scalar,
            PackedVariant::Unrolled,
            PackedVariant::Simd,
        ];
        for &bits in &[2u32, 4, 8] {
            let (fi, fo, batch) = (23usize, 10usize, 3usize);
            let (sw, sa) = (0.13f32, 0.1f32);
            let mut rng = Pcg32::new(bits as u64 * 7 + 1, 99);
            let w = random_weights(fi * fo, bits as u64 * 13 + 5);
            let bias: Vec<f32> = (0..fo).map(|_| rng.normal() * 0.1).collect();
            let acodes: Vec<u8> = (0..batch * fi).map(|_| rng.below(16) as u8).collect();
            let a: Vec<f32> = (0..batch * fi)
                .map(|i| if i % 4 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let pk = pack(&w, sw, bits, fi, fo).unwrap();
            for &v in &variants {
                let mut lut_1 = vec![0f32; batch * fo];
                gemm_bias_packed_v(&a, &pk, &bias, &mut lut_1, batch, v, 1);
                let mut epi_1 = vec![0f32; batch * fo];
                gemm_bias_packed_epilogue_v(&a, &pk, &bias, &mut epi_1, batch, v, 1);
                let mut i32_1 = vec![0f32; batch * fo];
                gemm_bias_packed_i32_v(&acodes, &pk, &bias, sa * sw, &mut i32_1, batch, v, 1);
                for &t in &[2usize, 4] {
                    let mut lut_t = vec![0f32; batch * fo];
                    gemm_bias_packed_v(&a, &pk, &bias, &mut lut_t, batch, v, t);
                    let mut epi_t = vec![0f32; batch * fo];
                    gemm_bias_packed_epilogue_v(&a, &pk, &bias, &mut epi_t, batch, v, t);
                    let mut i32_t = vec![0f32; batch * fo];
                    gemm_bias_packed_i32_v(&acodes, &pk, &bias, sa * sw, &mut i32_t, batch, v, t);
                    for idx in 0..batch * fo {
                        assert_eq!(
                            lut_t[idx].to_bits(),
                            lut_1[idx].to_bits(),
                            "bits={bits} {} lut t={t} idx={idx}",
                            v.name()
                        );
                        assert_eq!(
                            epi_t[idx].to_bits(),
                            epi_1[idx].to_bits(),
                            "bits={bits} {} epilogue t={t} idx={idx}",
                            v.name()
                        );
                        assert_eq!(
                            i32_t[idx].to_bits(),
                            i32_1[idx].to_bits(),
                            "bits={bits} {} i32 t={t} idx={idx}",
                            v.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_threads_env_parses_and_falls_back() {
        // Not set in the test environment: the fallback must pass through.
        std::env::remove_var("MPQ_GEMM_THREADS");
        assert_eq!(gemm_threads_from_env(3), 3);
        std::env::set_var("MPQ_GEMM_THREADS", "4");
        assert_eq!(gemm_threads_from_env(1), 4);
        std::env::set_var("MPQ_GEMM_THREADS", "0");
        assert_eq!(gemm_threads_from_env(2), 2, "zero is not a valid width");
        std::env::set_var("MPQ_GEMM_THREADS", "not-a-number");
        assert_eq!(gemm_threads_from_env(2), 2);
        std::env::remove_var("MPQ_GEMM_THREADS");
    }

    #[test]
    fn quantize_acts_matches_relu_quant_rule() {
        let z = vec![-0.3f32, 0.0, 0.04, 0.06, 1.49, 100.0];
        let (sa, aqp) = (0.1f32, 15.0f32);
        let mut codes = Vec::new();
        quantize_acts_u8(&z, sa, aqp, &mut codes);
        // Reference rule via relu_quant_act: out = code·sa.
        let mut out = vec![0f32; z.len()];
        let mut act_in = vec![false; z.len()];
        gemm::relu_quant_act(&z, sa, aqp, None, 0.0, &mut out, &mut act_in);
        for (c, o) in codes.iter().zip(&out) {
            assert_eq!((*c as f32) * sa, *o);
        }
        assert_eq!(codes, vec![0, 0, 0, 1, 15, 15]);
    }
}
