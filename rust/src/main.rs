//! `mpq` — command-line launcher for the mixed-precision quantization
//! framework.
//!
//! ```text
//! mpq exp        --manifest m.json [--workers N]   # the primary entry point
//! mpq info       --model sim_skew
//! mpq train-base --model sim_skew [--steps 400]
//! mpq gains      --model sim_skew --method eagl|alps|hawq_v3
//! mpq select     --model sim_skew --method eagl --budget 0.7
//! mpq run        --model sim_skew --method eagl --budget 0.7 --seed 0
//! mpq sweep      --model sim_skew --methods eagl,alps,hawq_v3,first_to_last
//!                --budgets 0.95,0.9,...  --seeds 3
//! mpq report     --model sim_skew | --models a,b | --manifest m.json
//! mpq serve      --model sim_skew --budget 0.7 [--workers N --max-batch B]
//!                [--listen ADDR | --target http://HOST:PORT]
//! mpq infer      --model sim_skew [--samples N --index I]
//! mpq eagl       --model sim_skew [--ckpt path]   # offline metric (Fig. 2)
//! ```
//!
//! `exp` executes a declarative experiment manifest (models × methods ×
//! budgets × seeds) through the resumable multi-model scheduler; `run`
//! and `sweep` are thin wrappers that synthesize a one-model manifest
//! from their flags.  Every subcommand rejects flags it does not
//! understand (a misspelled `--budgets` on `run` is an error, not a
//! silent fallback to the default budget).
//!
//! Backend selection: `--backend sim|pjrt|auto` (default auto).  Auto uses
//! the pjrt artifact runtime when `artifacts/` holds the model's manifest
//! *and* the binary was built with `--features pjrt`; otherwise the
//! hermetic pure-Rust sim backend (models `sim_tiny`, `sim_skew`).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mpq::backend::{self, Backend, BackendKind, KernelChoice, Task, TrainState};
use mpq::cli::Args;
use mpq::coordinator::{self, Coordinator, ResultStore};
use mpq::data::Split;
use mpq::experiment::{self, ExecOptions, ExperimentSpec, Overrides};
use mpq::methods::MethodKind;
use mpq::quant::BitsConfig;
use mpq::report;
use mpq::serve;
use mpq::train::{finetune, TrainConfig};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn metric_name(task: Task) -> &'static str {
    match task {
        Task::Cls => "top-1 accuracy",
        Task::Seg => "mIoU",
        Task::Span => "F1",
    }
}

/// Metric name for a model without keeping a backend open (falls back to
/// a generic label when the backend cannot open, e.g. pjrt-less builds).
fn metric_name_for(kind: BackendKind, model: &str) -> String {
    match backend::open(kind, model) {
        Ok(be) => metric_name(be.manifest().task).to_string(),
        Err(_) => "metric".to_string(),
    }
}

/// Resolve (backend kind, model): an explicit --model wins; otherwise the
/// default model follows the backend (artifacts → qresnet20, sim →
/// sim_skew).
fn resolve_target(args: &Args) -> mpq::Result<(BackendKind, String)> {
    let requested = args.opt_str("backend");
    match args.opt_str("model") {
        Some(model) => Ok((backend::resolve(requested, model)?, model.to_string())),
        None => {
            let kind = backend::resolve(requested, "qresnet20")?;
            let model = match kind {
                BackendKind::Pjrt => "qresnet20",
                BackendKind::Sim => "sim_skew",
            };
            Ok((kind, model.to_string()))
        }
    }
}

/// Resolve `--kernel` for a subcommand: the flag wins, else
/// `default_kernel` — but only on the sim backend (packed kernels are
/// sim-only, so pjrt always defaults to reference).
fn kernel_for(args: &Args, kind: BackendKind, default_kernel: &str) -> mpq::Result<KernelChoice> {
    let d = match kind {
        BackendKind::Sim => default_kernel,
        BackendKind::Pjrt => "reference",
    };
    KernelChoice::parse(&args.str("kernel", d))
}

fn coordinator(args: &Args) -> mpq::Result<Coordinator<Box<dyn Backend>>> {
    Ok(coordinator_kernel(args, "reference")?.0)
}

/// [`coordinator`] with a subcommand-specific `--kernel` default
/// (`serve`/`infer` default to the packed inference kernels).  Returns
/// the resolved backend kind and kernel alongside the coordinator so
/// callers that open more backends (the serve spawner) reuse exactly the
/// resolution the coordinator was built with instead of re-deriving it.
fn coordinator_kernel(
    args: &Args,
    default_kernel: &str,
) -> mpq::Result<(Coordinator<Box<dyn Backend>>, BackendKind, KernelChoice)> {
    let (kind, model) = resolve_target(args)?;
    let kernel = kernel_for(args, kind, default_kernel)?;
    let mut co = Coordinator::open_kernel(kind, &model, args.u64("data-seed", 7)?, kernel)?;
    co.base_steps = args.usize("base-steps", co.base_steps)?;
    co.ft_steps = args.usize("ft-steps", co.ft_steps)?;
    co.eval_batches = args.usize("eval-batches", co.eval_batches)?;
    co.mcfg.alps_steps = args.usize("alps-steps", co.mcfg.alps_steps)?;
    co.mcfg.hawq_samples = args.usize("hawq-samples", co.mcfg.hawq_samples)?;
    co.mcfg.hawq_batches = args.usize("hawq-batches", co.mcfg.hawq_batches)?;
    // Sweep parallelism: --workers wins, else MPQ_WORKERS, else available
    // parallelism (resolved in default_workers, already set on co).
    co.workers = args.usize("workers", co.workers)?.max(1);
    Ok((co, kind, kernel))
}

/// Tuning flags shared by the single-cell subcommands (for `exp` these
/// live in the manifest instead).
const COMMON_FLAGS: &[&str] = &[
    "backend",
    "model",
    "data-seed",
    "base-steps",
    "ft-steps",
    "eval-batches",
    "alps-steps",
    "hawq-samples",
    "hawq-batches",
    "workers",
    "kernel",
];

/// Per-subcommand flag validation: every subcommand rejects unknown or
/// misspelled flags with a suggestion instead of silently ignoring them.
fn validate_flags(args: &Args) -> mpq::Result<()> {
    let Some(sub) = args.subcommand.as_deref() else {
        return Ok(());
    };
    let extra: &[&str] = match sub {
        "info" | "train-base" => &[],
        "gains" => &["method"],
        "select" => &["method", "budget"],
        "run" => &["method", "budget", "seed"],
        "sweep" => &["methods", "budgets", "seeds"],
        "report" => &["models", "manifest"],
        "eagl" => &["ckpt"],
        "serve" => &[
            "method",
            "budget",
            "bits-from",
            "seed",
            "max-batch",
            "batch-timeout-ms",
            "requests",
            "max-request",
            "mode",
            "concurrency",
            "rate",
            "loadgen-seed",
            "per-request",
            "listen",
            "target",
            "queue-cap",
            "max-inflight",
            "keepalive-max",
        ],
        "infer" => &["method", "budget", "bits-from", "seed", "samples", "index"],
        // Manifest-driven: tuning knobs belong in the manifest, so only
        // the orchestration flags are accepted.
        "exp" => return args.ensure_known_flags(sub, &["manifest", "workers", "backend"]),
        _ => return Ok(()), // unknown subcommand → usage text below
    };
    let mut allowed: Vec<&str> = COMMON_FLAGS.to_vec();
    allowed.extend_from_slice(extra);
    args.ensure_known_flags(sub, &allowed)
}

fn run() -> mpq::Result<()> {
    let args = Args::from_env()?;
    validate_flags(&args)?;
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train-base") => cmd_train_base(&args),
        Some("gains") => cmd_gains(&args),
        Some("select") => cmd_select(&args),
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("infer") => cmd_infer(&args),
        Some("report") => cmd_report(&args),
        Some("eagl") => cmd_eagl(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand '{cmd}'\n");
            }
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
mpq — mixed-precision quantization framework (EAGL + ALPS, Bablani et al. 2023)

subcommands:
  exp         --manifest M.json [--workers N]   execute a declarative experiment
              manifest (models x methods x budgets x seeds) with resume: cells
              already in the per-model registry are skipped, and records are
              bit-identical at any --workers value
  info        --model M                     manifest/graph/cost summary
  train-base  --model M [--base-steps N]    train + cache 4-bit base & 8-bit ref
  gains       --model M --method K          per-layer gain estimates + timing
  select      --model M --method K --budget F   knapsack selection at budget
  run         --model M --method K --budget F --seed S   one full experiment
  sweep       --model M --methods a,b,.. --budgets f,..  --seeds N   full sweep
  report      --model M | --models a,b | --manifest M.json
              frontier tables/plots/significance, aggregated across models
  serve       --model M [--budget F [--method K] | --bits-from sweep.jsonl --budget F]
              [--workers N] [--max-batch B] [--batch-timeout-ms T] [--ft-steps S]
              [--requests R] [--max-request S] [--mode closed|open]
              [--concurrency C] [--rate HZ] [--loadgen-seed X] [--per-request]
              batched inference engine + deterministic loadgen; batching is
              invariant (responses bit-identical at any --workers/--max-batch/
              composition); vs direct single-request eval: bit-identical with
              --kernel reference or --per-request, epsilon-equal with the
              packed default (identical accuracy)
              --listen ADDR   put the HTTP/1.1 front door on ADDR (port 0
                              picks a free port) and self-drive it over real
                              loopback sockets; [--queue-cap N] admission
                              bound (queue-full is fail-fast 503),
                              [--max-inflight N] per-connection pipelining
                              bound, [--keepalive-max N] requests served per
                              connection; endpoints: POST /infer,
                              GET /metrics, GET /healthz
              --target http://HOST:PORT   pure socket client: drive a remote
                              front door with the same deterministic request
                              stream (default --mode open)
  infer       --model M [--budget F | --bits-from ...] [--samples N] [--index I]
              one-shot inference (a direct eval_step; bit-identical across
              kernels)
  eagl        --model M [--ckpt P]          offline EAGL metric (Fig. 2)

backends: --backend sim|pjrt|auto (default auto).  sim = hermetic pure-Rust
          reference executor (models sim_tiny, sim_skew; no artifacts).
          pjrt = AOT artifact runtime (needs `make artifacts` + a build
          with --features pjrt).  auto prefers pjrt when available.
common flags: --data-seed, --base-steps, --ft-steps, --eval-batches,
              --alps-steps, --hawq-samples, --hawq-batches,
              --workers N (parallel runs + gain estimation; default:
              available parallelism; results bit-identical at any N),
              --kernel packed|reference (sim forward kernels; default
              reference, except serve/infer which default to the
              bit-packed integer path — eval is bit-identical either
              way, packed inference logits carry a documented epsilon;
              see rust/README.md §Packed kernels)
unknown or misspelled flags are rejected per subcommand.
env: MPQ_ARTIFACTS (artifacts dir), MPQ_RESULTS (results root),
     MPQ_LOG (debug|info|warn|error), MPQ_WORKERS (default for --workers)
";

fn cmd_info(args: &Args) -> mpq::Result<()> {
    let co = coordinator(args)?;
    let g = &co.graph;
    println!("model: {}", co.model);
    println!("backend: {}", co.rt.kind());
    println!(
        "task: {:?} ({})",
        co.rt.manifest().task,
        metric_name(co.rt.manifest().task)
    );
    println!("layers: {} ({} selectable groups)", g.layers.len(), g.groups.len());
    println!("params: {}", co.rt.manifest().params.len());
    println!(
        "selectable BMACs: 4-bit {:.3} G / 2-bit {:.3} G",
        g.selectable_bmacs(4) as f64 / 1e9,
        g.selectable_bmacs(2) as f64 / 1e9
    );
    let b4 = BitsConfig::uniform(g, 4);
    println!(
        "uniform 4-bit: compression {:.2}x, {:.4} GBOPs",
        mpq::quant::compression_ratio(g, &b4),
        mpq::quant::gbops(g, &b4)
    );
    println!(
        "\n{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
        "layer", "kind", "macs", "params", "fixed", "group"
    );
    for l in &g.layers {
        println!(
            "{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
            l.name,
            l.kind,
            l.macs,
            l.weight_params,
            l.fixed_bits.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            l.link_group
        );
    }
    Ok(())
}

fn cmd_train_base(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let task = co.rt.manifest().task;
    let ck4 = co.base_checkpoint()?;
    let e4 = co.eval_uniform(&ck4, 4)?;
    println!("4-bit base: loss {:.4} {} {:.4}", e4.loss, metric_name(task), e4.metric);
    let ck8 = co.reference_checkpoint()?;
    let e8 = co.eval_uniform(&ck8, 8)?;
    println!("8-bit ref : loss {:.4} {} {:.4}", e8.loss, metric_name(task), e8.metric);
    Ok(())
}

fn cmd_gains(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let est = co.gains(kind)?;
    println!("method: {} ({:.3}s to estimate)", kind.name(), est.wall_seconds);
    println!("{:<16} {:>10}", "layer", "gain");
    for l in &co.graph.layers {
        println!(
            "{:<16} {:>10.5}{}",
            l.name,
            est.per_layer[l.qindex],
            if l.fixed_bits.is_some() { "  (fixed)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_select(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let bits = co.select(kind, frac)?;
    println!(
        "{}",
        report::layer_selection_map(&co.graph, &[(kind.name().to_string(), bits.clone())])
    );
    println!(
        "compression {:.2}x  GBOPs {:.4}  groups at 2-bit: {}",
        mpq::quant::compression_ratio(&co.graph, &bits),
        mpq::quant::gbops(&co.graph, &bits),
        bits.count_at(&co.graph, 2)
    );
    Ok(())
}

/// `--base-steps` etc. as manifest-style overrides for the synthesized
/// specs behind `run` and `sweep`.
fn overrides_from_args(args: &Args) -> mpq::Result<Overrides> {
    let opt = |key: &str| -> mpq::Result<Option<usize>> {
        match args.opt_str(key) {
            None => Ok(None),
            Some(_) => args.usize(key, 0).map(Some),
        }
    };
    Ok(Overrides {
        base_steps: opt("base-steps")?,
        ft_steps: opt("ft-steps")?,
        eval_batches: opt("eval-batches")?,
        alps_steps: opt("alps-steps")?,
        hawq_samples: opt("hawq-samples")?,
        hawq_batches: opt("hawq-batches")?,
        workers: None, // --workers is the scheduler width, not a manifest knob
    })
}

/// One full experiment — a thin wrapper over a synthesized one-cell
/// manifest, executed without touching the result registry.
fn cmd_run(args: &Args) -> mpq::Result<()> {
    let (kind, model) = resolve_target(args)?;
    let method = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let seed = args.u64("seed", 0)?;
    let spec = ExperimentSpec::synthesized(
        "run",
        args.opt_str("backend").map(String::from),
        args.u64("data-seed", 7)?,
        &model,
        vec![method],
        vec![frac],
        vec![seed],
        overrides_from_args(args)?,
    );
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        persist: false,
        results_root: None,
        progress: false,
    };
    let outcome = experiment::execute(&spec, &opts)?;
    let rec = &outcome.records[0];
    println!(
        "{} {} budget {:.0}% seed {}: {} = {:.4} (loss {:.4}) [{:.1}s]",
        rec.model,
        rec.method,
        frac * 100.0,
        seed,
        metric_name_for(kind, &model),
        rec.metric,
        rec.loss,
        rec.wall_s
    );
    Ok(())
}

/// Budget × seed sweep — a thin wrapper over a synthesized one-model
/// manifest, executed with registry persistence and resume.
fn cmd_sweep(args: &Args) -> mpq::Result<()> {
    let (kind, model) = resolve_target(args)?;
    let methods: Vec<MethodKind> = args
        .list("methods", &["eagl", "alps", "hawq_v3", "uniform", "first_to_last"])
        .iter()
        .map(|s| MethodKind::parse(s))
        .collect::<mpq::Result<_>>()?;
    let budgets = args.f64_list(
        "budgets",
        &[0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60],
    )?;
    let seeds: Vec<u64> = (0..args.u64("seeds", 3)?).collect();
    mpq::ensure!(!seeds.is_empty(), "--seeds must be at least 1");
    let spec = ExperimentSpec::synthesized(
        "sweep",
        args.opt_str("backend").map(String::from),
        args.u64("data-seed", 7)?,
        &model,
        methods,
        budgets,
        seeds,
        overrides_from_args(args)?,
    );
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        ..ExecOptions::default()
    };
    let outcome = experiment::execute(&spec, &opts)?;
    let cells = report::frontier(&outcome.records);
    println!("{}", report::frontier_table(&cells, &metric_name_for(kind, &model)));
    Ok(())
}

/// Execute a declarative experiment manifest (the primary entry point).
fn cmd_exp(args: &Args) -> mpq::Result<()> {
    let path = args
        .opt_str("manifest")
        .ok_or_else(|| mpq::err!("exp requires --manifest <file.json> (see rust/examples/manifests/)"))?;
    let mut spec = ExperimentSpec::from_file(Path::new(path))?;
    if let Some(b) = args.opt_str("backend") {
        spec.backend = Some(b.to_string());
    }
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        ..ExecOptions::default()
    };
    let outcome = experiment::execute(&spec, &opts)?;
    println!(
        "\nexp \"{}\" done: {} run(s) executed, {} resumed, {:.1}s",
        spec.name, outcome.executed, outcome.skipped, outcome.wall_s
    );

    // Per-model frontiers + the cross-model overview.
    let mut per_model: Vec<(String, Vec<report::FrontierCell>)> = Vec::new();
    for m in &spec.models {
        let recs: Vec<_> = outcome
            .records
            .iter()
            .filter(|r| r.model == m.name)
            .cloned()
            .collect();
        let kind = backend::resolve(spec.backend.as_deref(), &m.name)?;
        let cells = report::frontier(&recs);
        println!(
            "\n== {} ==\n{}",
            m.name,
            report::frontier_table(&cells, &metric_name_for(kind, &m.name))
        );
        per_model.push((m.name.clone(), cells));
    }
    if per_model.len() > 1 {
        println!("{}", report::cross_model_table(&per_model));
    }
    Ok(())
}

/// Resolve the precision assignment to serve: `--bits-from` looks up the
/// winning sweep record at `--budget`, a bare `--budget` runs the
/// selection directly (`--method`, default eagl), and neither serves the
/// uniform `b_hi` baseline.
fn serve_bits(
    args: &Args,
    co: &mut Coordinator<Box<dyn Backend>>,
) -> mpq::Result<BitsConfig> {
    if let Some(path) = args.opt_str("bits-from") {
        mpq::ensure!(
            args.opt_str("budget").is_some(),
            "--bits-from needs --budget <frac> to pick the winning row"
        );
        let budget = args.f64("budget", 0.7)?;
        let store = ResultStore::open(Path::new(path))?;
        let (rec, bits) = co.bits_from_store(&store, budget)?;
        println!(
            "bits from {path}: {} @ budget {:.0}% (seed {}, metric {:.4})",
            rec.method,
            rec.budget_frac * 100.0,
            rec.seed,
            rec.metric
        );
        Ok(bits)
    } else if args.opt_str("budget").is_some() {
        let kind = MethodKind::parse(&args.str("method", "eagl"))?;
        co.select(kind, args.f64("budget", 0.7)?)
    } else {
        Ok(BitsConfig::uniform(&co.graph, co.mcfg.b_hi))
    }
}

/// Checkpoint to serve: the cached base checkpoint transformed for the
/// precision assignment, optionally fine-tuned (`--ft-steps`, default 0
/// for serving — pass a run's step count to serve the paper's protocol).
fn serve_checkpoint(
    args: &Args,
    co: &mut Coordinator<Box<dyn Backend>>,
    bits: &BitsConfig,
) -> mpq::Result<mpq::ckpt::Checkpoint> {
    let ck4 = co.base_checkpoint()?;
    let ck = mpq::methods::prepare_mp_checkpoint(&ck4, &co.graph, bits, co.mcfg.b_hi)?;
    let ft = args.usize("ft-steps", 0)?;
    if ft == 0 {
        return Ok(ck);
    }
    let mut state = TrainState::new(ck);
    let tcfg = TrainConfig {
        steps: ft,
        lr0: 0.005,
        seed: args.u64("seed", 0)?,
        ..TrainConfig::default()
    };
    finetune(&mut co.rt, &mut state, &co.data, &bits.to_f32(), &tcfg)?;
    Ok(state.params)
}

/// `mpq serve`: start the batched inference engine for the resolved
/// (checkpoint, bits) pair and drive it with the deterministic loadgen.
fn cmd_serve(args: &Args) -> mpq::Result<()> {
    // Pure socket-client mode: no engine, no model — just the
    // deterministic loadgen aimed at a remote `mpq serve --listen`.
    if let Some(target) = args.opt_str("target") {
        return cmd_serve_target(args, target);
    }
    // Serving defaults to the packed inference kernels on sim: bit-packed
    // weight codes, materialized once and shared across the worker pool.
    // The worker spawner reuses the exact (kind, kernel) the coordinator
    // resolved, so engine workers can never diverge from the coordinator
    // that produced the checkpoint and bits.
    let (mut co, kind, kernel) = coordinator_kernel(args, "packed")?;
    let model = co.model.clone();
    let bits = serve_bits(args, &mut co)?;
    let ck = serve_checkpoint(args, &mut co, &bits)?;
    let timeout_ms = args.f64("batch-timeout-ms", 1.0)?;
    mpq::ensure!(
        timeout_ms.is_finite() && timeout_ms >= 0.0,
        "--batch-timeout-ms expects a non-negative number, got {timeout_ms}"
    );
    let cfg = serve::ServeConfig {
        workers: co.workers,
        max_batch: args.usize("max-batch", 32)?,
        batch_timeout: Duration::from_secs_f64(timeout_ms / 1e3),
        force_per_request: args.bool("per-request"),
        warmup: true,
    };
    let model_s = model.clone();
    let spawner: serve::Spawner = Arc::new(move || backend::open_with(kind, &model_s, kernel));
    println!(
        "serving {model} [{}, {} kernels]: {} group(s) at 2-bit, compression {:.2}x, {:.4} GBOPs",
        kind.name(),
        kernel.name(),
        bits.count_at(&co.graph, 2),
        mpq::quant::compression_ratio(&co.graph, &bits),
        mpq::quant::gbops(&co.graph, &bits)
    );
    let engine = serve::Engine::start(spawner, ck, bits.to_f32(), cfg.clone())?;
    println!(
        "engine: {} worker(s), max-batch {}, timeout {:.1}ms, {} batching",
        cfg.workers,
        cfg.max_batch,
        cfg.batch_timeout.as_secs_f64() * 1e3,
        if engine.fused() { "fused" } else { "per-request" }
    );
    let mode = match args.str("mode", "closed").as_str() {
        "closed" => serve::LoadMode::Closed {
            concurrency: args.usize("concurrency", 8)?,
        },
        "open" => serve::LoadMode::Open {
            rate_hz: args.f64("rate", 200.0)?,
        },
        other => mpq::bail!("--mode expects closed|open, got '{other}'"),
    };
    let spec = serve::LoadSpec {
        requests: args.usize("requests", 256)?,
        max_request_samples: args.usize("max-request", 4)?,
        seed: args.u64("loadgen-seed", 42)?,
        mode,
    };
    // Socket front-door mode: put the HTTP/1.1 server in front of the
    // engine and self-drive it with the same loadgen over real loopback
    // sockets (this is what `make http-smoke` runs).
    if let Some(listen) = args.opt_str("listen") {
        return cmd_serve_listen(args, engine, co.data.clone(), &spec, listen);
    }
    // run() verifies the serving invariants: every request answered
    // exactly once, response ids monotone and contiguous.
    let load = serve::loadgen::run(&engine, &co.data, &spec)?;
    let snap = engine.drain()?;
    print!("{}", report::serve_table(&snap, &load));
    // The drained engine must account for exactly the loadgen's traffic,
    // with no failures — this (plus run()'s own checks and drain()'s
    // unresolved-request check) is what `make serve-smoke` gates on.
    mpq::ensure!(
        snap.completed == spec.requests as u64 && snap.failed == 0,
        "serve: engine completed {}/{} request(s) with {} failure(s)",
        snap.completed,
        spec.requests,
        snap.failed
    );
    println!(
        "serve OK: {} response(s), ids monotone, clean drain",
        load.responses.len()
    );
    Ok(())
}

/// `mpq serve --listen`: HTTP/1.1 front door over the engine, self-driven
/// by the same deterministic loadgen over real loopback sockets, with one
/// verified `/metrics` scrape.  `make http-smoke` gates on the final
/// "http-serve OK" line.
fn cmd_serve_listen(
    args: &Args,
    engine: serve::Engine,
    data: mpq::data::Dataset,
    spec: &serve::LoadSpec,
    listen: &str,
) -> mpq::Result<()> {
    let hcfg = serve::HttpConfig {
        addr: listen.trim_start_matches("http://").to_string(),
        queue_capacity: args.usize("queue-cap", 1024)?,
        max_inflight_per_conn: args.usize("max-inflight", 8)?,
        max_requests_per_conn: args.usize("keepalive-max", 4096)?,
        ..serve::HttpConfig::default()
    };
    let server = serve::HttpServer::start(engine, data, hcfg)?;
    let addr = server.local_addr().to_string();
    println!("listening on http://{addr} (POST /infer, GET /metrics, GET /healthz)");
    let load = serve::loadgen::run_http(&addr, spec)?;
    // One real scrape: /metrics must parse and account for the traffic.
    let scrape = serve::http::client::HttpClient::connect(&addr)?.get("/metrics")?;
    mpq::ensure!(scrape.status == 200, "GET /metrics: HTTP {}", scrape.status);
    let text = scrape.body_str();
    let line = format!("mpq_engine_requests_completed_total {}", spec.requests);
    mpq::ensure!(
        text.lines().any(|l| l == line),
        "metrics scrape did not account for all {} request(s)",
        spec.requests
    );
    println!("metrics scrape OK: {} line(s)", text.lines().count());
    let (snap, hstats) = server.shutdown()?;
    print!("{}", report::serve_table(&snap, &load));
    println!(
        "http: {} conn(s), admitted {}, answered {}, rejected {}, bad {}, scrapes {}",
        hstats.connections,
        hstats.admitted,
        hstats.answered,
        hstats.rejected,
        hstats.bad_requests,
        hstats.metrics_scrapes
    );
    mpq::ensure!(
        snap.completed == spec.requests as u64 && snap.failed == 0,
        "serve: engine completed {}/{} request(s) with {} failure(s)",
        snap.completed,
        spec.requests,
        snap.failed
    );
    mpq::ensure!(
        hstats.admitted == hstats.answered && hstats.failed == 0 && hstats.aborted == 0,
        "http: admitted {} != answered {} (failed {}, aborted {})",
        hstats.admitted,
        hstats.answered,
        hstats.failed,
        hstats.aborted
    );
    println!(
        "http-serve OK: {} response(s) over http://{addr}, ids monotone, clean drain",
        load.responses.len()
    );
    Ok(())
}

/// `mpq serve --target http://HOST:PORT`: pure socket client — drive a
/// remote front door with the deterministic request stream and report the
/// client-side view (per-request latencies are the server-reported
/// values, so the histogram matches the server's own `/metrics`).
fn cmd_serve_target(args: &Args, target: &str) -> mpq::Result<()> {
    let addr = target.trim_start_matches("http://").trim_end_matches('/');
    // Open-loop is the default against a remote target: fixed-rate
    // arrivals are the saturation benchmark the socket path exists for.
    let mode = match args.str("mode", "open").as_str() {
        "closed" => serve::LoadMode::Closed {
            concurrency: args.usize("concurrency", 8)?,
        },
        "open" => serve::LoadMode::Open {
            rate_hz: args.f64("rate", 200.0)?,
        },
        other => mpq::bail!("--mode expects closed|open, got '{other}'"),
    };
    let spec = serve::LoadSpec {
        requests: args.usize("requests", 256)?,
        max_request_samples: args.usize("max-request", 4)?,
        seed: args.u64("loadgen-seed", 42)?,
        mode,
    };
    println!("loadgen -> http://{addr}: {} request(s)", spec.requests);
    let load = serve::loadgen::run_http(addr, &spec)?;
    let m = serve::Metrics::new();
    for r in &load.responses {
        m.record_submitted();
        m.record_request(r.samples as u64, Duration::from_secs_f64(r.latency_s));
    }
    print!("{}", report::serve_table(&m.snapshot(), &load));
    println!(
        "http loadgen OK: {} response(s), ids monotone",
        load.responses.len()
    );
    Ok(())
}

/// `mpq infer`: one-shot inference — a direct single-request `eval_step`,
/// the reference computation serve responses are compared against:
/// bit-identical for `--kernel reference` (or `--per-request`) serving,
/// epsilon-equal for the packed fused path (whose logits layer applies
/// the LSQ scale in the epilogue; eval itself is bit-identical across
/// kernels, so this command prints the same numbers with either flag).
fn cmd_infer(args: &Args) -> mpq::Result<()> {
    let (mut co, _, _) = coordinator_kernel(args, "packed")?;
    let bits = serve_bits(args, &mut co)?;
    let ck = serve_checkpoint(args, &mut co, &bits)?;
    let samples = args.usize("samples", 1)?;
    mpq::ensure!(samples > 0, "--samples must be at least 1");
    let (x, y) = co.data.batch(Split::Eval, args.u64("index", 0)?, samples);
    let task = co.rt.manifest().task;
    let t0 = Instant::now();
    let (loss, evalout) = co.rt.eval_step(&ck, &x, &y, &bits.to_f32())?;
    let dt = t0.elapsed().as_secs_f64();
    print!(
        "infer {}: {} sample(s), loss {:.4}",
        co.model, samples, loss
    );
    if evalout.len() == 1 {
        print!(
            ", {} {:.4}",
            metric_name(task),
            evalout.item() as f64 / samples as f64
        );
    }
    println!(", {:.2} ms", dt * 1e3);
    Ok(())
}

/// Report over one or many models' registries: `--model M`, `--models
/// a,b`, or `--manifest M.json` (which also supplies the backend).
fn cmd_report(args: &Args) -> mpq::Result<()> {
    let mut backend_req = args.opt_str("backend").map(String::from);
    let models: Vec<String> = if let Some(path) = args.opt_str("manifest") {
        let spec = ExperimentSpec::from_file(Path::new(path))?;
        if backend_req.is_none() {
            backend_req = spec.backend.clone();
        }
        spec.models.iter().map(|m| m.name.clone()).collect()
    } else if args.opt_str("models").is_some() {
        args.list("models", &[])
    } else {
        vec![resolve_target(args)?.1]
    };

    let mut per_model: Vec<(String, Vec<report::FrontierCell>)> = Vec::new();
    for model in &models {
        let kind = backend::resolve(backend_req.as_deref(), model)?;
        let dir = coordinator::results_dir_for(kind, model);
        let store = ResultStore::open(&dir.join("sweep.jsonl"))?;
        if store.records().is_empty() {
            println!("== {model} == (no results yet — run `mpq sweep` or `mpq exp`)");
            continue;
        }
        let cells = report::frontier(store.records());
        let name = metric_name_for(kind, model);
        println!("== {model} ({name}) ==");
        println!("{}", report::frontier_table(&cells, &name));
        println!("{}", report::frontier_plot(&cells, 64, 18));
        // Significance over every method pair actually present in the
        // store (the hardcoded eagl/alps/hawq trio missed everything else).
        for (a, b) in report::method_pairs(&cells) {
            let sig = report::significance(&cells, &a, &b);
            if !sig.is_empty() {
                println!("Wilcoxon rank-sum {a} vs {b}:");
                for (bud, p) in sig {
                    println!("  budget {:>4.0}%  p = {:.4}", bud * 100.0, p);
                }
            }
        }
        report::write_csv(&cells, &dir.join("frontier.csv"))?;
        println!("csv written to {}", dir.join("frontier.csv").display());
        per_model.push((model.clone(), cells));
    }
    mpq::ensure!(
        !per_model.is_empty(),
        "no sweep results for {:?} — run `mpq sweep` or `mpq exp` first",
        models
    );
    if per_model.len() > 1 {
        println!("{}", report::cross_model_table(&per_model));
        let out = coordinator::results_dir_for(
            backend::resolve(backend_req.as_deref(), &models[0])?,
            &models[0],
        )
        .parent()
        .map(|p| p.join("frontier_all.csv"))
        .unwrap_or_else(|| std::path::PathBuf::from("frontier_all.csv"));
        report::write_csv_multi(&per_model, &out)?;
        println!("cross-model csv written to {}", out.display());
    }
    Ok(())
}

fn cmd_eagl(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let ck = match args.opt_str("ckpt") {
        Some(p) => mpq::ckpt::Checkpoint::load(std::path::Path::new(p))?,
        None => co.base_checkpoint()?,
    };
    let t0 = std::time::Instant::now();
    let ents = mpq::eagl::checkpoint_entropies(&co.graph, &ck, co.mcfg.b_hi)?;
    let dt = t0.elapsed();
    println!(
        "EAGL on {} layers in {:.3} ms (paper Table 3: CPU seconds)",
        co.graph.layers.len(),
        dt.as_secs_f64() * 1e3
    );
    println!("{:<16} {:>10} {:>8}", "layer", "H(bits)", "alloc");
    for l in &co.graph.layers {
        let b = l.fixed_bits.unwrap_or(co.mcfg.b_hi);
        println!("{:<16} {:>10.4} {:>8}", l.name, ents[l.qindex], b);
    }
    Ok(())
}
