//! `mpq` — command-line launcher for the mixed-precision quantization
//! framework.
//!
//! ```text
//! mpq exp        --manifest m.json [--workers N]   # the primary entry point
//! mpq info       --model sim_skew
//! mpq train-base --model sim_skew [--steps 400]
//! mpq gains      --model sim_skew --method eagl|alps|hawq_v3
//! mpq select     --model sim_skew --method eagl --budget 0.7
//! mpq run        --model sim_skew --method eagl --budget 0.7 --seed 0
//! mpq sweep      --model sim_skew --methods eagl,alps,hawq_v3,first_to_last
//!                --budgets 0.95,0.9,...  --seeds 3
//! mpq report     --model sim_skew | --models a,b | --manifest m.json
//! mpq serve      --model sim_skew --budget 0.7 [--workers N --max-batch B]
//!                [--listen ADDR | --target http://HOST:PORT]
//! mpq infer      --model sim_skew [--samples N --index I]
//! mpq trace      --file trace.json                # validate a --trace-out file
//! mpq eagl       --model sim_skew [--ckpt path]   # offline metric (Fig. 2)
//! ```
//!
//! `exp` executes a declarative experiment manifest (models × methods ×
//! budgets × seeds) through the resumable multi-model scheduler; `run`
//! and `sweep` are thin wrappers that synthesize a one-model manifest
//! from their flags.  Every subcommand rejects flags it does not
//! understand (a misspelled `--budgets` on `run` is an error, not a
//! silent fallback to the default budget).
//!
//! Backend selection: `--backend sim|pjrt|auto` (default auto).  Auto uses
//! the pjrt artifact runtime when `artifacts/` holds the model's manifest
//! *and* the binary was built with `--features pjrt`; otherwise the
//! hermetic pure-Rust sim backend (models `sim_tiny`, `sim_skew`).

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mpq::backend::{
    self, Backend, BackendKind, KernelChoice, KernelTuning, PackedVariant, Task, TrainState,
};
use mpq::cli::Args;
use mpq::coordinator::{self, Coordinator, ResultStore};
use mpq::data::Split;
use mpq::experiment::{self, ExecOptions, ExperimentSpec, Overrides};
use mpq::methods::MethodKind;
use mpq::quant::BitsConfig;
use mpq::report;
use mpq::serve;
use mpq::train::{finetune, TrainConfig};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn metric_name(task: Task) -> &'static str {
    match task {
        Task::Cls => "top-1 accuracy",
        Task::Seg => "mIoU",
        Task::Span => "F1",
    }
}

/// Metric name for a model without keeping a backend open (falls back to
/// a generic label when the backend cannot open, e.g. pjrt-less builds).
fn metric_name_for(kind: BackendKind, model: &str) -> String {
    match backend::open(kind, model) {
        Ok(be) => metric_name(be.manifest().task).to_string(),
        Err(_) => "metric".to_string(),
    }
}

/// Resolve (backend kind, model): an explicit --model wins; otherwise the
/// default model follows the backend (artifacts → qresnet20, sim →
/// sim_skew).
fn resolve_target(args: &Args) -> mpq::Result<(BackendKind, String)> {
    let requested = args.opt_str("backend");
    match args.opt_str("model") {
        Some(model) => Ok((backend::resolve(requested, model)?, model.to_string())),
        None => {
            let kind = backend::resolve(requested, "qresnet20")?;
            let model = match kind {
                BackendKind::Pjrt => "qresnet20",
                BackendKind::Sim => "sim_skew",
            };
            Ok((kind, model.to_string()))
        }
    }
}

/// Resolve `--kernel` for a subcommand: the flag wins, else
/// `default_kernel` — but only on the sim backend (packed kernels are
/// sim-only, so pjrt always defaults to reference).
fn kernel_for(args: &Args, kind: BackendKind, default_kernel: &str) -> mpq::Result<KernelChoice> {
    let d = match kind {
        BackendKind::Sim => default_kernel,
        BackendKind::Pjrt => "reference",
    };
    KernelChoice::parse(&args.str("kernel", d))
}

/// Resolve the packed-path tuning flags: `--packed-variant`
/// (scalar|unrolled|simd, fail-closed when the build lacks the simd
/// tiles) and `--gemm-threads` (flag wins, else `MPQ_GEMM_THREADS`, else
/// `default_threads`).  Serve passes `default_threads = 1` — its engine
/// already runs one worker per core, and intra-layer banding on top
/// would oversubscribe — while `mpq infer`/eval default to the
/// worker-pool width.
fn kernel_tuning(args: &Args, default_threads: usize) -> mpq::Result<KernelTuning> {
    let variant = PackedVariant::parse(&args.str("packed-variant", "unrolled"))?;
    let gemm_threads = args
        .usize(
            "gemm-threads",
            mpq::kernels::packed::gemm_threads_from_env(default_threads),
        )?
        .max(1);
    Ok(KernelTuning { variant, gemm_threads })
}

fn coordinator(args: &Args) -> mpq::Result<Coordinator<Box<dyn Backend>>> {
    Ok(coordinator_kernel(args, "reference", 1)?.0)
}

/// [`coordinator`] with a subcommand-specific `--kernel` default
/// (`serve`/`infer` default to the packed inference kernels) and
/// `--gemm-threads` default.  Returns the resolved backend kind, kernel
/// and tuning alongside the coordinator so callers that open more
/// backends (the serve spawner) reuse exactly the resolution the
/// coordinator was built with instead of re-deriving it.
fn coordinator_kernel(
    args: &Args,
    default_kernel: &str,
    default_gemm_threads: usize,
) -> mpq::Result<(Coordinator<Box<dyn Backend>>, BackendKind, KernelChoice, KernelTuning)> {
    let (kind, model) = resolve_target(args)?;
    let kernel = kernel_for(args, kind, default_kernel)?;
    let tuning = kernel_tuning(args, default_gemm_threads)?;
    let mut co =
        Coordinator::open_tuned(kind, &model, args.u64("data-seed", 7)?, kernel, tuning)?;
    co.base_steps = args.usize("base-steps", co.base_steps)?;
    co.ft_steps = args.usize("ft-steps", co.ft_steps)?;
    co.eval_batches = args.usize("eval-batches", co.eval_batches)?;
    co.mcfg.alps_steps = args.usize("alps-steps", co.mcfg.alps_steps)?;
    co.mcfg.hawq_samples = args.usize("hawq-samples", co.mcfg.hawq_samples)?;
    co.mcfg.hawq_batches = args.usize("hawq-batches", co.mcfg.hawq_batches)?;
    // Sweep parallelism: --workers wins, else MPQ_WORKERS, else available
    // parallelism (resolved in default_workers, already set on co).
    co.workers = args.usize("workers", co.workers)?.max(1);
    Ok((co, kind, kernel, tuning))
}

/// Tuning flags shared by the single-cell subcommands (for `exp` these
/// live in the manifest instead).
const COMMON_FLAGS: &[&str] = &[
    "backend",
    "model",
    "data-seed",
    "base-steps",
    "ft-steps",
    "eval-batches",
    "alps-steps",
    "hawq-samples",
    "hawq-batches",
    "workers",
    "kernel",
    "packed-variant",
    "gemm-threads",
];

/// Per-subcommand flag validation: every subcommand rejects unknown or
/// misspelled flags with a suggestion instead of silently ignoring them.
fn validate_flags(args: &Args) -> mpq::Result<()> {
    let Some(sub) = args.subcommand.as_deref() else {
        return Ok(());
    };
    let extra: &[&str] = match sub {
        "info" | "train-base" => &[],
        "gains" => &["method"],
        "select" => &["method", "budget"],
        "run" => &["method", "budget", "seed"],
        "sweep" => &["methods", "budgets", "seeds"],
        "report" => &["models", "manifest"],
        "eagl" => &["ckpt"],
        "serve" => &[
            "method",
            "budget",
            "bits-from",
            "seed",
            "max-batch",
            "batch-timeout-ms",
            "requests",
            "max-request",
            "mode",
            "concurrency",
            "rate",
            "loadgen-seed",
            "per-request",
            "listen",
            "target",
            "queue-cap",
            "max-inflight",
            "keepalive-max",
            "frontier-from",
            "degrade",
            "slo-p99-ms",
            "slo-recover",
            "queue-high",
            "queue-low",
            "cooldown-ticks",
            "floor-budget",
            "ctl-tick-ms",
            "capacity",
            "window-ticks",
            "fault-seed",
            "fault-stall-every",
            "fault-stall-ms",
            "fault-stall-work",
            "fault-spike-every",
            "fault-spike-work",
            "trace-out",
            "trace-sample",
            "latency-out",
            "decision-log",
        ],
        "infer" => &["method", "budget", "bits-from", "seed", "samples", "index"],
        // Offline trace validation: no model, no backend — just the file.
        "trace" => return args.ensure_known_flags(sub, &["file"]),
        // Static analysis: no model, no backend — a source tree + waivers.
        "lint" => return args.ensure_known_flags(sub, &["root", "json", "waivers"]),
        // Manifest-driven: tuning knobs belong in the manifest, so only
        // the orchestration flags are accepted.
        "exp" => return args.ensure_known_flags(sub, &["manifest", "workers", "backend"]),
        _ => return Ok(()), // unknown subcommand → usage text below
    };
    let mut allowed: Vec<&str> = COMMON_FLAGS.to_vec();
    allowed.extend_from_slice(extra);
    args.ensure_known_flags(sub, &allowed)
}

fn run() -> mpq::Result<()> {
    let args = Args::from_env()?;
    validate_flags(&args)?;
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train-base") => cmd_train_base(&args),
        Some("gains") => cmd_gains(&args),
        Some("select") => cmd_select(&args),
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("infer") => cmd_infer(&args),
        Some("trace") => cmd_trace(&args),
        Some("lint") => cmd_lint(&args),
        Some("report") => cmd_report(&args),
        Some("eagl") => cmd_eagl(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand '{cmd}'\n");
            }
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
mpq — mixed-precision quantization framework (EAGL + ALPS, Bablani et al. 2023)

subcommands:
  exp         --manifest M.json [--workers N]   execute a declarative experiment
              manifest (models x methods x budgets x seeds) with resume: cells
              already in the per-model registry are skipped, and records are
              bit-identical at any --workers value
  info        --model M                     manifest/graph/cost summary
  train-base  --model M [--base-steps N]    train + cache 4-bit base & 8-bit ref
  gains       --model M --method K          per-layer gain estimates + timing
  select      --model M --method K --budget F   knapsack selection at budget
  run         --model M --method K --budget F --seed S   one full experiment
  sweep       --model M --methods a,b,.. --budgets f,..  --seeds N   full sweep
  report      --model M | --models a,b | --manifest M.json
              frontier tables/plots/significance, aggregated across models
  serve       --model M [--budget F [--method K] | --bits-from sweep.jsonl --budget F]
              [--workers N] [--max-batch B] [--batch-timeout-ms T] [--ft-steps S]
              [--requests R] [--max-request S] [--mode closed|open]
              [--concurrency C] [--rate HZ] [--loadgen-seed X] [--per-request]
              batched inference engine + deterministic loadgen; batching is
              invariant (responses bit-identical at any --workers/--max-batch/
              composition); vs direct single-request eval: bit-identical with
              --kernel reference or --per-request, epsilon-equal with the
              packed default (identical accuracy)
              --listen ADDR   put the HTTP/1.1 front door on ADDR (port 0
                              picks a free port) and self-drive it over real
                              loopback sockets; [--queue-cap N] admission
                              bound (queue-full is fail-fast 503),
                              [--max-inflight N] per-connection pipelining
                              bound, [--keepalive-max N] requests served per
                              connection; endpoints: POST /infer,
                              GET /metrics, GET /healthz
              --target http://HOST:PORT   pure socket client: drive a remote
                              front door with the same deterministic request
                              stream (default --mode open)
              --frontier-from sweep.jsonl   load the sweep's whole accuracy/
                              cost frontier as pre-materialized hot-swap
                              targets (level 0 = highest budget; serving
                              starts there); with --listen this adds
                              POST /swap and an SLO controller thread that
                              walks the frontier from windowed p99 + queue
                              depth; thresholds: [--slo-p99-ms F]
                              [--slo-recover F] [--queue-high N]
                              [--queue-low N] [--cooldown-ticks N]
                              [--floor-budget F] [--ctl-tick-ms F]
              --degrade quiet|ramp|spike|TICKSxRATE,..   deterministic
                              sim-time degradation drill over the loaded
                              frontier (needs --frontier-from with >= 2
                              levels): seeded phase profile + optional
                              fault plan drive overload -> downgrade ->
                              recover; the real engine serves and hot-swaps
                              while the decision log derives only from the
                              sim queue model, so it is byte-identical
                              across reruns, --workers, and --kernel;
                              [--capacity F] [--window-ticks N] plus fault
                              flags [--fault-stall-every N] [--fault-stall-ms F]
                              [--fault-stall-work F] [--fault-spike-every N]
                              [--fault-spike-work F] [--fault-seed X]
              --trace-sample N   per-request span tracing: record every Nth
                              admitted request (deterministic id % N == 0;
                              default 1 = every request) through the full
                              lifecycle — HTTP parse, admission, queue wait,
                              batch assembly, per-layer packed GEMM,
                              reassembly, epilogue, serialize, socket write —
                              plus pinned mpq_stage_* histogram lines on
                              /metrics and GET /trace (with --listen)
              --trace-out F   write the Chrome trace-event JSON (load it in
                              Perfetto / chrome://tracing) after drain;
                              implies tracing at --trace-sample's rate
              --latency-out F   per-request {index, samples, epoch,
                              latency_ns} JSONL from the loadgen
              --decision-log F  controller decision JSONL; the sim-time
                              (--degrade) log is byte-identical across
                              reruns, --workers, and --kernel
  infer       --model M [--budget F | --bits-from ...] [--samples N] [--index I]
              one-shot inference (a direct eval_step; bit-identical across
              kernels)
  trace       --file trace.json   validate a --trace-out / GET /trace file:
              complete span sets per request, monotone timestamps
  lint        [--root rust/src] [--json] [--waivers F]   repo-aware static
              analysis: wall-clock, relaxed-audit, hot-path-panic,
              float-reassoc, stdout-discipline, fail-closed-flags (see
              rust/README.md §Static analysis); waivers default to
              rust/lint-waivers.json, parsed fail-closed (unknown keys and
              stale waivers are errors); exit 0 clean / 1 findings / 2
              config error
  eagl        --model M [--ckpt P]          offline EAGL metric (Fig. 2)

backends: --backend sim|pjrt|auto (default auto).  sim = hermetic pure-Rust
          reference executor (models sim_tiny, sim_skew; no artifacts).
          pjrt = AOT artifact runtime (needs `make artifacts` + a build
          with --features pjrt).  auto prefers pjrt when available.
common flags: --data-seed, --base-steps, --ft-steps, --eval-batches,
              --alps-steps, --hawq-samples, --hawq-batches,
              --workers N (parallel runs + gain estimation; default:
              available parallelism; results bit-identical at any N),
              --kernel packed|reference (sim forward kernels; default
              reference, except serve/infer which default to the
              bit-packed integer path — eval is bit-identical either
              way, packed inference logits carry a documented epsilon;
              see rust/README.md §Packed kernels),
              --packed-variant scalar|unrolled|simd (packed tile
              implementation; default unrolled, simd needs a build with
              --features simd; results bit-identical across variants),
              --gemm-threads N (intra-layer row-parallel packed GEMM;
              default 1 for serve — the engine owns the cores — and the
              worker-pool width for infer; bit-identical at any N)
unknown or misspelled flags are rejected per subcommand.
env: MPQ_ARTIFACTS (artifacts dir), MPQ_RESULTS (results root),
     MPQ_LOG (debug|info|warn|error, or a per-module spec like
     "warn,serve=debug,serve::http=error"), MPQ_WORKERS (default for --workers),
     MPQ_GEMM_THREADS (default for --gemm-threads)
";

fn cmd_info(args: &Args) -> mpq::Result<()> {
    let co = coordinator(args)?;
    let g = &co.graph;
    println!("model: {}", co.model);
    println!("backend: {}", co.rt.kind());
    println!(
        "task: {:?} ({})",
        co.rt.manifest().task,
        metric_name(co.rt.manifest().task)
    );
    println!("layers: {} ({} selectable groups)", g.layers.len(), g.groups.len());
    println!("params: {}", co.rt.manifest().params.len());
    println!(
        "selectable BMACs: 4-bit {:.3} G / 2-bit {:.3} G",
        g.selectable_bmacs(4) as f64 / 1e9,
        g.selectable_bmacs(2) as f64 / 1e9
    );
    let b4 = BitsConfig::uniform(g, 4);
    println!(
        "uniform 4-bit: compression {:.2}x, {:.4} GBOPs",
        mpq::quant::compression_ratio(g, &b4),
        mpq::quant::gbops(g, &b4)
    );
    println!(
        "\n{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
        "layer", "kind", "macs", "params", "fixed", "group"
    );
    for l in &g.layers {
        println!(
            "{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
            l.name,
            l.kind,
            l.macs,
            l.weight_params,
            l.fixed_bits.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            l.link_group
        );
    }
    Ok(())
}

fn cmd_train_base(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let task = co.rt.manifest().task;
    let ck4 = co.base_checkpoint()?;
    let e4 = co.eval_uniform(&ck4, 4)?;
    println!("4-bit base: loss {:.4} {} {:.4}", e4.loss, metric_name(task), e4.metric);
    let ck8 = co.reference_checkpoint()?;
    let e8 = co.eval_uniform(&ck8, 8)?;
    println!("8-bit ref : loss {:.4} {} {:.4}", e8.loss, metric_name(task), e8.metric);
    Ok(())
}

fn cmd_gains(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let est = co.gains(kind)?;
    println!("method: {} ({:.3}s to estimate)", kind.name(), est.wall_seconds);
    println!("{:<16} {:>10}", "layer", "gain");
    for l in &co.graph.layers {
        println!(
            "{:<16} {:>10.5}{}",
            l.name,
            est.per_layer[l.qindex],
            if l.fixed_bits.is_some() { "  (fixed)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_select(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let bits = co.select(kind, frac)?;
    println!(
        "{}",
        report::layer_selection_map(&co.graph, &[(kind.name().to_string(), bits.clone())])
    );
    println!(
        "compression {:.2}x  GBOPs {:.4}  groups at 2-bit: {}",
        mpq::quant::compression_ratio(&co.graph, &bits),
        mpq::quant::gbops(&co.graph, &bits),
        bits.count_at(&co.graph, 2)
    );
    Ok(())
}

/// `--base-steps` etc. as manifest-style overrides for the synthesized
/// specs behind `run` and `sweep`.
fn overrides_from_args(args: &Args) -> mpq::Result<Overrides> {
    let opt = |key: &str| -> mpq::Result<Option<usize>> {
        match args.opt_str(key) {
            None => Ok(None),
            Some(_) => args.usize(key, 0).map(Some),
        }
    };
    Ok(Overrides {
        base_steps: opt("base-steps")?,
        ft_steps: opt("ft-steps")?,
        eval_batches: opt("eval-batches")?,
        alps_steps: opt("alps-steps")?,
        hawq_samples: opt("hawq-samples")?,
        hawq_batches: opt("hawq-batches")?,
        workers: None, // --workers is the scheduler width, not a manifest knob
    })
}

/// One full experiment — a thin wrapper over a synthesized one-cell
/// manifest, executed without touching the result registry.
fn cmd_run(args: &Args) -> mpq::Result<()> {
    let (kind, model) = resolve_target(args)?;
    let method = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let seed = args.u64("seed", 0)?;
    let spec = ExperimentSpec::synthesized(
        "run",
        args.opt_str("backend").map(String::from),
        args.u64("data-seed", 7)?,
        &model,
        vec![method],
        vec![frac],
        vec![seed],
        overrides_from_args(args)?,
    );
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        persist: false,
        results_root: None,
        progress: false,
    };
    let outcome = experiment::execute(&spec, &opts)?;
    let rec = &outcome.records[0];
    println!(
        "{} {} budget {:.0}% seed {}: {} = {:.4} (loss {:.4}) [{:.1}s]",
        rec.model,
        rec.method,
        frac * 100.0,
        seed,
        metric_name_for(kind, &model),
        rec.metric,
        rec.loss,
        rec.wall_s
    );
    Ok(())
}

/// Budget × seed sweep — a thin wrapper over a synthesized one-model
/// manifest, executed with registry persistence and resume.
fn cmd_sweep(args: &Args) -> mpq::Result<()> {
    let (kind, model) = resolve_target(args)?;
    let methods: Vec<MethodKind> = args
        .list("methods", &["eagl", "alps", "hawq_v3", "uniform", "first_to_last"])
        .iter()
        .map(|s| MethodKind::parse(s))
        .collect::<mpq::Result<_>>()?;
    let budgets = args.f64_list(
        "budgets",
        &[0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60],
    )?;
    let seeds: Vec<u64> = (0..args.u64("seeds", 3)?).collect();
    mpq::ensure!(!seeds.is_empty(), "--seeds must be at least 1");
    let spec = ExperimentSpec::synthesized(
        "sweep",
        args.opt_str("backend").map(String::from),
        args.u64("data-seed", 7)?,
        &model,
        methods,
        budgets,
        seeds,
        overrides_from_args(args)?,
    );
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        ..ExecOptions::default()
    };
    let outcome = experiment::execute(&spec, &opts)?;
    let cells = report::frontier(&outcome.records);
    println!("{}", report::frontier_table(&cells, &metric_name_for(kind, &model)));
    Ok(())
}

/// Execute a declarative experiment manifest (the primary entry point).
fn cmd_exp(args: &Args) -> mpq::Result<()> {
    let path = args
        .opt_str("manifest")
        .ok_or_else(|| mpq::err!("exp requires --manifest <file.json> (see rust/examples/manifests/)"))?;
    let mut spec = ExperimentSpec::from_file(Path::new(path))?;
    if let Some(b) = args.opt_str("backend") {
        spec.backend = Some(b.to_string());
    }
    let opts = ExecOptions {
        workers: args.usize("workers", coordinator::default_workers())?.max(1),
        ..ExecOptions::default()
    };
    let outcome = experiment::execute(&spec, &opts)?;
    println!(
        "\nexp \"{}\" done: {} run(s) executed, {} resumed, {:.1}s",
        spec.name, outcome.executed, outcome.skipped, outcome.wall_s
    );

    // Per-model frontiers + the cross-model overview.
    let mut per_model: Vec<(String, Vec<report::FrontierCell>)> = Vec::new();
    for m in &spec.models {
        let recs: Vec<_> = outcome
            .records
            .iter()
            .filter(|r| r.model == m.name)
            .cloned()
            .collect();
        let kind = backend::resolve(spec.backend.as_deref(), &m.name)?;
        let cells = report::frontier(&recs);
        println!(
            "\n== {} ==\n{}",
            m.name,
            report::frontier_table(&cells, &metric_name_for(kind, &m.name))
        );
        per_model.push((m.name.clone(), cells));
    }
    if per_model.len() > 1 {
        println!("{}", report::cross_model_table(&per_model));
    }
    Ok(())
}

/// Resolve the precision assignment to serve: `--bits-from` looks up the
/// winning sweep record at `--budget`, a bare `--budget` runs the
/// selection directly (`--method`, default eagl), and neither serves the
/// uniform `b_hi` baseline.
fn serve_bits(
    args: &Args,
    co: &mut Coordinator<Box<dyn Backend>>,
) -> mpq::Result<BitsConfig> {
    if let Some(path) = args.opt_str("bits-from") {
        mpq::ensure!(
            args.opt_str("budget").is_some(),
            "--bits-from needs --budget <frac> to pick the winning row"
        );
        let budget = args.f64("budget", 0.7)?;
        let store = ResultStore::open(Path::new(path))?;
        let (rec, bits) = co.bits_from_store(&store, budget)?;
        println!(
            "bits from {path}: {} @ budget {:.0}% (seed {}, metric {:.4})",
            rec.method,
            rec.budget_frac * 100.0,
            rec.seed,
            rec.metric
        );
        Ok(bits)
    } else if args.opt_str("budget").is_some() {
        let kind = MethodKind::parse(&args.str("method", "eagl"))?;
        co.select(kind, args.f64("budget", 0.7)?)
    } else {
        Ok(BitsConfig::uniform(&co.graph, co.mcfg.b_hi))
    }
}

/// Checkpoint to serve: the cached base checkpoint transformed for the
/// precision assignment, optionally fine-tuned (`--ft-steps`, default 0
/// for serving — pass a run's step count to serve the paper's protocol).
fn serve_checkpoint(
    args: &Args,
    co: &mut Coordinator<Box<dyn Backend>>,
    bits: &BitsConfig,
) -> mpq::Result<mpq::ckpt::Checkpoint> {
    let ck4 = co.base_checkpoint()?;
    let ck = mpq::methods::prepare_mp_checkpoint(&ck4, &co.graph, bits, co.mcfg.b_hi)?;
    let ft = args.usize("ft-steps", 0)?;
    if ft == 0 {
        return Ok(ck);
    }
    let mut state = TrainState::new(ck);
    let tcfg = TrainConfig {
        steps: ft,
        lr0: 0.005,
        seed: args.u64("seed", 0)?,
        ..TrainConfig::default()
    };
    finetune(&mut co.rt, &mut state, &co.data, &bits.to_f32(), &tcfg)?;
    Ok(state.params)
}

/// `--frontier-from`: resolve every stored budget for this model into a
/// fully materialized hot-swap target (level 0 = highest budget).
fn build_frontier(
    args: &Args,
    co: &mut Coordinator<Box<dyn Backend>>,
    path: &str,
) -> mpq::Result<Vec<serve::FrontierStep>> {
    let store = ResultStore::open(Path::new(path))?;
    let floor = args.f64("floor-budget", 0.0)?;
    let resolved = co.frontier_from_store(&store, floor)?;
    let mut steps = Vec::with_capacity(resolved.len());
    for (rec, bits) in resolved {
        let ckpt = serve_checkpoint(args, co, &bits)?;
        steps.push(serve::FrontierStep {
            budget_frac: rec.budget_frac,
            method: rec.method.clone(),
            metric: rec.metric,
            gbops: mpq::quant::gbops(&co.graph, &bits),
            ckpt,
            bits: bits.to_f32(),
        });
    }
    Ok(steps)
}

/// Fault-injection plan from the `--fault-*` flags; `None` (no plan)
/// unless at least one `--fault-*-every` period is set.
fn fault_from_args(args: &Args) -> mpq::Result<Option<serve::FaultPlan>> {
    let stall_every = args.u64("fault-stall-every", 0)?;
    let spike_every = args.u64("fault-spike-every", 0)?;
    if stall_every == 0 && spike_every == 0 {
        return Ok(None);
    }
    let stall_ms = args.f64("fault-stall-ms", 2.0)?;
    mpq::ensure!(
        stall_ms.is_finite() && stall_ms >= 0.0,
        "--fault-stall-ms expects a non-negative number, got {stall_ms}"
    );
    Ok(Some(serve::FaultPlan {
        seed: args.u64("fault-seed", 1)?,
        stall_every,
        stall_wall: Duration::from_secs_f64(stall_ms / 1e3),
        stall_work: args.f64("fault-stall-work", 16.0)?,
        spike_every,
        spike_work: args.f64("fault-spike-work", 12.0)?,
    }))
}

/// Controller thresholds from the `--slo-*`/`--queue-*` flags.  In sim
/// mode (`--degrade`) latency is measured in ticks, 1 tick ≙ 1 ms of the
/// flag; live mode converts to seconds.
fn thresholds_from_args(args: &Args, sim_ticks: bool) -> mpq::Result<serve::SloThresholds> {
    let slo_ms = args.f64("slo-p99-ms", 6.0)?;
    mpq::ensure!(
        slo_ms.is_finite() && slo_ms > 0.0,
        "--slo-p99-ms expects a positive number, got {slo_ms}"
    );
    Ok(serve::SloThresholds {
        slo_p99: if sim_ticks { slo_ms } else { slo_ms / 1e3 },
        recover_frac: args.f64("slo-recover", 0.5)?,
        queue_high: args.usize("queue-high", 64)?,
        queue_low: args.usize("queue-low", 8)?,
        cooldown_ticks: args.u64("cooldown-ticks", 3)? as u32,
        floor_budget: args.f64("floor-budget", 0.0)?,
    })
}

/// Span-tracing sink from the `--trace-*` flags: enabled when either
/// `--trace-out` or `--trace-sample` is given (sample defaults to 1 =
/// every request).  Disabled tracing costs the hot path one `Option`
/// check at admission.
fn trace_sink_from_args(args: &Args) -> mpq::Result<Option<Arc<serve::TraceSink>>> {
    if args.opt_str("trace-out").is_none() && args.opt_str("trace-sample").is_none() {
        return Ok(None);
    }
    let sample = args.u64("trace-sample", 1)?;
    mpq::ensure!(sample >= 1, "--trace-sample expects a positive integer, got {sample}");
    let cfg = serve::TraceConfig { sample, ..serve::TraceConfig::default() };
    mpq::info!("tracing on: sample 1-in-{sample}, ring capacity {} request(s)", cfg.capacity);
    Ok(Some(serve::TraceSink::new(cfg)))
}

/// `--trace-out`: write the Chrome trace-event file after the engine has
/// drained (so every sampled request's spans are published).
fn write_trace_out(args: &Args, sink: &Option<Arc<serve::TraceSink>>) -> mpq::Result<()> {
    let Some(path) = args.opt_str("trace-out") else {
        return Ok(());
    };
    let sink = sink
        .as_ref()
        .ok_or_else(|| mpq::err!("--trace-out without an active trace sink"))?;
    sink.write_chrome(Path::new(path))?;
    println!(
        "trace written to {path}: {} request(s) published, {} evicted",
        sink.published(),
        sink.dropped()
    );
    Ok(())
}

/// `--latency-out`: per-request latency JSONL from a finished load run.
fn write_latency_out(args: &Args, load: &serve::LoadReport) -> mpq::Result<()> {
    let Some(path) = args.opt_str("latency-out") else {
        return Ok(());
    };
    std::fs::write(path, serve::latency_jsonl(load))
        .map_err(|e| mpq::err!("--latency-out {path}: {e}"))?;
    println!("latencies written to {path}: {} line(s)", load.responses.len());
    Ok(())
}

/// `--decision-log`: controller decision JSONL.  The sim-time
/// (`--degrade`) log is byte-identical across reruns; the live log's
/// shape is wall-clock-driven.
fn write_decision_log(args: &Args, log: &[serve::controller::DecisionRecord]) -> mpq::Result<()> {
    let Some(path) = args.opt_str("decision-log") else {
        return Ok(());
    };
    std::fs::write(path, serve::decisions_jsonl(log))
        .map_err(|e| mpq::err!("--decision-log {path}: {e}"))?;
    println!("decision log written to {path}: {} tick(s)", log.len());
    Ok(())
}

/// `mpq trace --file trace.json`: offline validation of a trace file
/// written by `--trace-out` (or saved from `GET /trace`) — every traced
/// request must carry a complete span set with sane timestamps.
fn cmd_trace(args: &Args) -> mpq::Result<()> {
    let path = args
        .opt_str("file")
        .ok_or_else(|| mpq::err!("trace requires --file <trace.json>"))?;
    let text = std::fs::read_to_string(path).map_err(|e| mpq::err!("trace: read {path}: {e}"))?;
    let chk = serve::check_trace_text(&text)?;
    println!(
        "trace OK: {} event(s), {} request(s), {} stage(s) covered, {} controller tick(s)",
        chk.events,
        chk.requests,
        chk.stages.len(),
        chk.ctl_events
    );
    Ok(())
}

/// `mpq lint`: the repo-aware static analysis pass (see
/// `mpq::analysis`).  Exit codes are pinned — 0 clean, 1 findings, 2
/// configuration error (bad waiver file, stale waiver, wrong --root) —
/// so `make lint` and CI can distinguish "invariant violated" from
/// "the linter itself is misconfigured".
fn cmd_lint(args: &Args) -> mpq::Result<()> {
    let root = args.str("root", "rust/src");
    let root = Path::new(&root);
    let result = match args.opt_str("waivers") {
        Some(w) => mpq::analysis::run_with(root, Some(Path::new(w))),
        None => mpq::analysis::run(root),
    };
    let report = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: config error: {e:#}");
            std::process::exit(2);
        }
    };
    if args.bool("json") {
        println!("{}", report.to_json().to_string_compact());
    } else {
        print!("{}", report.render_text());
    }
    if !report.findings.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

/// `mpq serve`: start the batched inference engine for the resolved
/// (checkpoint, bits) pair and drive it with the deterministic loadgen.
fn cmd_serve(args: &Args) -> mpq::Result<()> {
    // Pure socket-client mode: no engine, no model — just the
    // deterministic loadgen aimed at a remote `mpq serve --listen`.
    if let Some(target) = args.opt_str("target") {
        return cmd_serve_target(args, target);
    }
    // Serving defaults to the packed inference kernels on sim: bit-packed
    // weight codes, materialized once and shared across the worker pool.
    // The worker spawner reuses the exact (kind, kernel, tuning) the
    // coordinator resolved, so engine workers can never diverge from the
    // coordinator that produced the checkpoint and bits.  gemm-threads
    // defaults to 1 here: the engine already runs one worker per core.
    let (mut co, kind, kernel, tuning) = coordinator_kernel(args, "packed", 1)?;
    let model = co.model.clone();
    // The adaptive path: load the sweep's whole frontier as swap targets
    // and start serving its most accurate level.
    let frontier: Option<Vec<serve::FrontierStep>> = match args.opt_str("frontier-from") {
        Some(path) => {
            mpq::ensure!(
                args.opt_str("bits-from").is_none() && args.opt_str("budget").is_none(),
                "--frontier-from replaces --bits-from/--budget: serving starts at frontier level 0"
            );
            let steps = build_frontier(args, &mut co, path)?;
            mpq::info!("frontier from {path}: {} level(s) [{}, {} kernels]", steps.len(), kind.name(), kernel.name());
            for (i, s) in steps.iter().enumerate() {
                mpq::info!(
                    "  level {i}: {:<14} metric {:.4}  {:.4} GBOPs",
                    s.label(),
                    s.metric,
                    s.gbops
                );
            }
            Some(steps)
        }
        None => None,
    };
    let (ck, bits_f32, init_budget, init_label) = match frontier.as_ref() {
        Some(steps) => {
            let s0 = &steps[0];
            (s0.ckpt.clone(), s0.bits.clone(), s0.budget_frac, s0.label())
        }
        None => {
            let bits = serve_bits(args, &mut co)?;
            let ck = serve_checkpoint(args, &mut co, &bits)?;
            mpq::info!(
                "serving {model} [{}, {} kernels]: {} group(s) at 2-bit, compression {:.2}x, {:.4} GBOPs",
                kind.name(),
                kernel.name(),
                bits.count_at(&co.graph, 2),
                mpq::quant::compression_ratio(&co.graph, &bits),
                mpq::quant::gbops(&co.graph, &bits)
            );
            (ck, bits.to_f32(), f64::NAN, "startup".to_string())
        }
    };
    let timeout_ms = args.f64("batch-timeout-ms", 1.0)?;
    mpq::ensure!(
        timeout_ms.is_finite() && timeout_ms >= 0.0,
        "--batch-timeout-ms expects a non-negative number, got {timeout_ms}"
    );
    let trace_sink = trace_sink_from_args(args)?;
    let cfg = serve::ServeConfig {
        workers: co.workers,
        max_batch: args.usize("max-batch", 32)?,
        batch_timeout: Duration::from_secs_f64(timeout_ms / 1e3),
        force_per_request: args.bool("per-request"),
        warmup: true,
        fault: fault_from_args(args)?,
        initial_budget: init_budget,
        initial_label: init_label,
        trace: trace_sink.clone(),
    };
    let model_s = model.clone();
    let spawner: serve::Spawner =
        Arc::new(move || backend::open_tuned(kind, &model_s, kernel, tuning));
    let engine = serve::Engine::start(spawner, ck, bits_f32, cfg.clone())?;
    mpq::info!(
        "engine: {} worker(s), max-batch {}, timeout {:.1}ms, {} batching, {} tiles, gemm-threads {}",
        cfg.workers,
        cfg.max_batch,
        cfg.batch_timeout.as_secs_f64() * 1e3,
        if engine.fused() { "fused" } else { "per-request" },
        tuning.variant.name(),
        tuning.gemm_threads
    );
    // Deterministic degradation drill: sim-time controller + real engine.
    if let Some(profile) = args.opt_str("degrade") {
        let steps = frontier
            .ok_or_else(|| mpq::err!("--degrade needs --frontier-from sweep.jsonl"))?;
        cmd_degrade(args, engine, co.data.clone(), steps, profile)?;
        return write_trace_out(args, &trace_sink);
    }
    let mode = match args.str("mode", "closed").as_str() {
        "closed" => serve::LoadMode::Closed {
            concurrency: args.usize("concurrency", 8)?,
        },
        "open" => serve::LoadMode::Open {
            rate_hz: args.f64("rate", 200.0)?,
        },
        other => mpq::bail!("--mode expects closed|open, got '{other}'"),
    };
    let spec = serve::LoadSpec {
        requests: args.usize("requests", 256)?,
        max_request_samples: args.usize("max-request", 4)?,
        seed: args.u64("loadgen-seed", 42)?,
        mode,
    };
    // Socket front-door mode: put the HTTP/1.1 server in front of the
    // engine and self-drive it with the same loadgen over real loopback
    // sockets (this is what `make http-smoke` runs).
    if let Some(listen) = args.opt_str("listen") {
        cmd_serve_listen(args, engine, co.data.clone(), &spec, listen, frontier)?;
        return write_trace_out(args, &trace_sink);
    }
    mpq::ensure!(
        frontier.is_none(),
        "--frontier-from without --listen/--degrade has no controller to drive it; \
         add --listen ADDR or --degrade PROFILE"
    );
    // run() verifies the serving invariants: every request answered
    // exactly once, response ids monotone and contiguous.
    let load = serve::loadgen::run(&engine, &co.data, &spec)?;
    let snap = engine.drain()?;
    print!("{}", report::serve_table(&snap, &load));
    // The drained engine must account for exactly the loadgen's traffic,
    // with no failures — this (plus run()'s own checks and drain()'s
    // unresolved-request check) is what `make serve-smoke` gates on.
    mpq::ensure!(
        snap.completed == spec.requests as u64 && snap.failed == 0,
        "serve: engine completed {}/{} request(s) with {} failure(s)",
        snap.completed,
        spec.requests,
        snap.failed
    );
    println!(
        "serve OK: {} response(s), ids monotone, clean drain",
        load.responses.len()
    );
    write_latency_out(args, &load)?;
    write_trace_out(args, &trace_sink)?;
    Ok(())
}

/// `mpq serve --listen`: HTTP/1.1 front door over the engine, self-driven
/// by the same deterministic loadgen over real loopback sockets, with one
/// verified `/metrics` scrape.  `make http-smoke` gates on the final
/// "http-serve OK" line.
fn cmd_serve_listen(
    args: &Args,
    engine: serve::Engine,
    data: mpq::data::Dataset,
    spec: &serve::LoadSpec,
    listen: &str,
    frontier: Option<Vec<serve::FrontierStep>>,
) -> mpq::Result<()> {
    let hcfg = serve::HttpConfig {
        addr: listen.trim_start_matches("http://").to_string(),
        queue_capacity: args.usize("queue-cap", 1024)?,
        max_inflight_per_conn: args.usize("max-inflight", 8)?,
        max_requests_per_conn: args.usize("keepalive-max", 4096)?,
        ..serve::HttpConfig::default()
    };
    let swaps = frontier.map(|steps| Arc::new(serve::SwapRegistry { steps }));
    let server = serve::HttpServer::start_with(engine, data, hcfg, swaps.clone())?;
    let addr = server.local_addr().to_string();
    mpq::info!(
        "listening on http://{addr} (POST /infer, POST /swap, GET /metrics, GET /trace, GET /healthz)"
    );
    // SLO controller: tick against the live engine while the loadgen
    // runs, hot-swapping along the frontier when the windowed p99 or
    // queue depth trips the thresholds.  Stopped (and its engine handle
    // dropped) before shutdown, which asserts sole engine ownership.
    let ctl = match swaps.as_ref() {
        Some(reg) => {
            let th = thresholds_from_args(args, false)?;
            let tick_ms = args.f64("ctl-tick-ms", 20.0)?;
            mpq::ensure!(
                tick_ms.is_finite() && tick_ms > 0.0,
                "--ctl-tick-ms expects a positive number, got {tick_ms}"
            );
            let steps = Arc::new(reg.steps.clone());
            let eng = server.engine_handle();
            let stop = Arc::new(AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let handle = std::thread::Builder::new()
                .name("mpq-ctl".to_string())
                .spawn(move || -> mpq::Result<serve::Controller> {
                    let mut c = serve::Controller::new(th, steps)?;
                    while !stop2.load(Ordering::SeqCst) {
                        c.tick(&eng)?;
                        std::thread::sleep(Duration::from_secs_f64(tick_ms / 1e3));
                    }
                    Ok(c)
                })
                .map_err(|e| mpq::err!("serve: spawn controller: {e}"))?;
            mpq::info!(
                "controller: tick {:.0}ms, slo p99 {:.1}ms, queue high/low {}/{}, cooldown {}",
                tick_ms,
                th.slo_p99 * 1e3,
                th.queue_high,
                th.queue_low,
                th.cooldown_ticks
            );
            Some((stop, handle))
        }
        None => None,
    };
    drop(swaps);
    let load = serve::loadgen::run_http(&addr, spec)?;
    if let Some((stop, handle)) = ctl {
        stop.store(true, Ordering::SeqCst);
        let c = handle
            .join()
            .map_err(|_| mpq::err!("serve: controller thread panicked"))??;
        println!(
            "controller: {} tick(s), {} down, {} up, final level {} ({})",
            c.log.len(),
            c.swaps_down,
            c.swaps_up,
            c.state.level,
            c.frontier[c.state.level].label()
        );
        // Live decision log: shaped by the wall clock (unlike the
        // byte-stable --degrade variant), but the same JSONL schema.
        write_decision_log(args, &c.log)?;
    }
    // One real scrape: /metrics must parse and account for the traffic.
    let scrape = serve::http::client::HttpClient::connect(&addr)?.get("/metrics")?;
    mpq::ensure!(scrape.status == 200, "GET /metrics: HTTP {}", scrape.status);
    let text = scrape.body_str();
    let line = format!("mpq_engine_requests_completed_total {}", spec.requests);
    mpq::ensure!(
        text.lines().any(|l| l == line),
        "metrics scrape did not account for all {} request(s)",
        spec.requests
    );
    println!("metrics scrape OK: {} line(s)", text.lines().count());
    // With tracing on, the scrape must also carry the pinned per-stage
    // histogram section (appended after the engine/http/ctl families).
    if args.opt_str("trace-out").is_some() || args.opt_str("trace-sample").is_some() {
        for stage in ["layer_gemm", "queue_wait", "socket_write"] {
            let needle = format!("mpq_stage_latency_seconds_count{{stage=\"{stage}\"}}");
            mpq::ensure!(
                text.lines().any(|l| l.starts_with(&needle)),
                "metrics scrape missing {needle} while tracing is on"
            );
        }
        println!("stage metrics OK");
    }
    let (snap, hstats) = server.shutdown()?;
    print!("{}", report::serve_table(&snap, &load));
    println!(
        "http: {} conn(s), admitted {}, answered {}, rejected {}, bad {}, scrapes {}",
        hstats.connections,
        hstats.admitted,
        hstats.answered,
        hstats.rejected,
        hstats.bad_requests,
        hstats.metrics_scrapes
    );
    mpq::ensure!(
        snap.completed == spec.requests as u64 && snap.failed == 0,
        "serve: engine completed {}/{} request(s) with {} failure(s)",
        snap.completed,
        spec.requests,
        snap.failed
    );
    mpq::ensure!(
        hstats.admitted == hstats.answered && hstats.failed == 0 && hstats.aborted == 0,
        "http: admitted {} != answered {} (failed {}, aborted {})",
        hstats.admitted,
        hstats.answered,
        hstats.failed,
        hstats.aborted
    );
    println!(
        "http-serve OK: {} response(s) over http://{addr}, ids monotone, clean drain",
        load.responses.len()
    );
    write_latency_out(args, &load)?;
    Ok(())
}

/// One `/metrics` scrape reduced to the controller gauges:
/// `(epoch, swap_total, active_budget)`.
fn scrape_ctl(addr: &str) -> mpq::Result<(u64, u64, f64)> {
    let resp = serve::http::client::HttpClient::connect(addr)?.get("/metrics")?;
    mpq::ensure!(resp.status == 200, "GET /metrics: HTTP {}", resp.status);
    let text = resp.body_str();
    let field = |name: &str| -> mpq::Result<f64> {
        text.lines()
            .find_map(|l| l.strip_prefix(name).and_then(|v| v.trim().parse::<f64>().ok()))
            .ok_or_else(|| mpq::err!("/metrics missing '{name}'"))
    };
    Ok((
        field("mpq_ctl_epoch ")? as u64,
        field("mpq_ctl_swap_total ")? as u64,
        field("mpq_ctl_active_budget ")?,
    ))
}

/// Shared tail of both `--degrade` paths: print the swap decisions and
/// gate on the drill actually exercising both directions.
fn print_degrade(out: &serve::DegradeOutcome) -> mpq::Result<()> {
    for line in out.log_text.lines() {
        if line.contains(" down:") || line.contains(" up:") {
            println!("  {line}");
        }
    }
    mpq::ensure!(
        out.swaps_down >= 1,
        "degrade drill produced no downgrade — raise the load profile or lower --capacity"
    );
    mpq::ensure!(
        out.swaps_up >= 1,
        "degrade drill never recovered — extend the profile's quiet tail"
    );
    println!(
        "degrade OK: {} request(s), {} swap(s) down, {} up, {} epoch(s), zero dropped",
        out.requests,
        out.swaps_down,
        out.swaps_up,
        out.epoch_levels.len()
    );
    Ok(())
}

/// `mpq serve --degrade PROFILE`: deterministic "overload → degrade →
/// recover" drill.  The sim-time queue model paces the controller (so the
/// decision log is byte-identical across reruns, `--workers`, and
/// `--kernel`) while the real engine serves the identical request stream
/// and hot-swaps on every decision.  With `--listen` a front door runs
/// alongside purely so `/metrics` can be scraped for the controller
/// gauges; `make degrade-smoke` gates on the "degrade OK" and
/// "ctl metrics OK" lines.
fn cmd_degrade(
    args: &Args,
    engine: serve::Engine,
    data: mpq::data::Dataset,
    steps: Vec<serve::FrontierStep>,
    profile: &str,
) -> mpq::Result<()> {
    mpq::ensure!(
        steps.len() >= 2,
        "--degrade needs a frontier with at least 2 levels to walk, got {}",
        steps.len()
    );
    let mut dcfg = serve::DegradeConfig::new(serve::SimProfile::named(profile)?);
    dcfg.thresholds = thresholds_from_args(args, true)?;
    dcfg.fault = fault_from_args(args)?.unwrap_or_else(serve::FaultPlan::none);
    dcfg.seed = args.u64("loadgen-seed", 42)?;
    dcfg.max_request_samples = args.usize("max-request", 4)?;
    dcfg.capacity_per_tick = args.f64("capacity", 8.0)?;
    dcfg.window_ticks = args.u64("window-ticks", 8)?;
    mpq::ensure!(
        dcfg.capacity_per_tick > 0.0,
        "--capacity expects a positive number, got {}",
        dcfg.capacity_per_tick
    );
    mpq::info!(
        "degrade drill: profile '{}' ({} tick(s)), {} frontier level(s), capacity {}/tick",
        dcfg.profile.name,
        dcfg.profile.arrivals_per_tick().len(),
        steps.len(),
        dcfg.capacity_per_tick
    );
    let Some(listen) = args.opt_str("listen") else {
        let out = serve::run_degrade(&engine, &data, &steps, &dcfg)?;
        engine.drain()?;
        write_decision_log(args, &out.log)?;
        return print_degrade(&out);
    };
    // Front door alongside the drill: the controller gauges must be
    // visible over the socket and the swap counter monotone.
    let hcfg = serve::HttpConfig {
        addr: listen.trim_start_matches("http://").to_string(),
        ..serve::HttpConfig::default()
    };
    let swaps = Arc::new(serve::SwapRegistry { steps });
    let server =
        serve::HttpServer::start_with(engine, data.clone(), hcfg, Some(Arc::clone(&swaps)))?;
    let addr = server.local_addr().to_string();
    let before = scrape_ctl(&addr)?;
    mpq::ensure!(
        before == (0, 0, swaps.steps[0].budget_frac),
        "ctl metrics: expected fresh gauges (epoch 0, swaps 0, budget {}), got {:?}",
        swaps.steps[0].budget_frac,
        before
    );
    let eng = server.engine_handle();
    let out = serve::run_degrade(&eng, &data, &swaps.steps, &dcfg)?;
    drop(eng);
    let after = scrape_ctl(&addr)?;
    let swaps_total = (out.swaps_down + out.swaps_up) as u64;
    mpq::ensure!(
        after.1 >= before.1 && after.1 == swaps_total,
        "ctl metrics: swap_total moved {} -> {}, expected {swaps_total}",
        before.1,
        after.1
    );
    let final_level = *out.epoch_levels.last().unwrap_or(&0);
    mpq::ensure!(
        after.0 == out.epoch_levels.len() as u64 - 1
            && after.2.to_bits() == swaps.steps[final_level].budget_frac.to_bits(),
        "ctl metrics: epoch {} budget {} disagree with the drill's final epoch {} level {}",
        after.0,
        after.2,
        out.epoch_levels.len() - 1,
        final_level
    );
    println!(
        "ctl metrics OK: swap_total {} -> {} (monotone), active budget {:.2}",
        before.1, after.1, after.2
    );
    server.shutdown()?;
    write_decision_log(args, &out.log)?;
    print_degrade(&out)
}

/// `mpq serve --target http://HOST:PORT`: pure socket client — drive a
/// remote front door with the deterministic request stream and report the
/// client-side view (per-request latencies are the server-reported
/// values, so the histogram matches the server's own `/metrics`).
fn cmd_serve_target(args: &Args, target: &str) -> mpq::Result<()> {
    let addr = target.trim_start_matches("http://").trim_end_matches('/');
    // Open-loop is the default against a remote target: fixed-rate
    // arrivals are the saturation benchmark the socket path exists for.
    let mode = match args.str("mode", "open").as_str() {
        "closed" => serve::LoadMode::Closed {
            concurrency: args.usize("concurrency", 8)?,
        },
        "open" => serve::LoadMode::Open {
            rate_hz: args.f64("rate", 200.0)?,
        },
        other => mpq::bail!("--mode expects closed|open, got '{other}'"),
    };
    let spec = serve::LoadSpec {
        requests: args.usize("requests", 256)?,
        max_request_samples: args.usize("max-request", 4)?,
        seed: args.u64("loadgen-seed", 42)?,
        mode,
    };
    mpq::info!("loadgen -> http://{addr}: {} request(s)", spec.requests);
    let load = serve::loadgen::run_http(addr, &spec)?;
    let m = serve::Metrics::new();
    for r in &load.responses {
        m.record_submitted();
        m.record_request(r.samples as u64, Duration::from_secs_f64(r.latency_s));
    }
    print!("{}", report::serve_table(&m.snapshot(), &load));
    println!(
        "http loadgen OK: {} response(s), ids monotone",
        load.responses.len()
    );
    write_latency_out(args, &load)?;
    Ok(())
}

/// `mpq infer`: one-shot inference — a direct single-request `eval_step`,
/// the reference computation serve responses are compared against:
/// bit-identical for `--kernel reference` (or `--per-request`) serving,
/// epsilon-equal for the packed fused path (whose logits layer applies
/// the LSQ scale in the epilogue; eval itself is bit-identical across
/// kernels, so this command prints the same numbers with either flag).
fn cmd_infer(args: &Args) -> mpq::Result<()> {
    // Unlike serve (whose engine owns the cores), a one-shot infer has
    // the whole machine: default the intra-layer GEMM row-parallelism to
    // the worker-pool width.
    let (mut co, _, _, _) = coordinator_kernel(args, "packed", coordinator::default_workers())?;
    let bits = serve_bits(args, &mut co)?;
    let ck = serve_checkpoint(args, &mut co, &bits)?;
    let samples = args.usize("samples", 1)?;
    mpq::ensure!(samples > 0, "--samples must be at least 1");
    let (x, y) = co.data.batch(Split::Eval, args.u64("index", 0)?, samples);
    let task = co.rt.manifest().task;
    let t0 = Instant::now();
    let (loss, evalout) = co.rt.eval_step(&ck, &x, &y, &bits.to_f32())?;
    let dt = t0.elapsed().as_secs_f64();
    print!(
        "infer {}: {} sample(s), loss {:.4}",
        co.model, samples, loss
    );
    if evalout.len() == 1 {
        print!(
            ", {} {:.4}",
            metric_name(task),
            evalout.item() as f64 / samples as f64
        );
    }
    println!(", {:.2} ms", dt * 1e3);
    Ok(())
}

/// Report over one or many models' registries: `--model M`, `--models
/// a,b`, or `--manifest M.json` (which also supplies the backend).
fn cmd_report(args: &Args) -> mpq::Result<()> {
    let mut backend_req = args.opt_str("backend").map(String::from);
    let models: Vec<String> = if let Some(path) = args.opt_str("manifest") {
        let spec = ExperimentSpec::from_file(Path::new(path))?;
        if backend_req.is_none() {
            backend_req = spec.backend.clone();
        }
        spec.models.iter().map(|m| m.name.clone()).collect()
    } else if args.opt_str("models").is_some() {
        args.list("models", &[])
    } else {
        vec![resolve_target(args)?.1]
    };

    let mut per_model: Vec<(String, Vec<report::FrontierCell>)> = Vec::new();
    for model in &models {
        let kind = backend::resolve(backend_req.as_deref(), model)?;
        let dir = coordinator::results_dir_for(kind, model);
        let store = ResultStore::open(&dir.join("sweep.jsonl"))?;
        if store.records().is_empty() {
            println!("== {model} == (no results yet — run `mpq sweep` or `mpq exp`)");
            continue;
        }
        let cells = report::frontier(store.records());
        let name = metric_name_for(kind, model);
        println!("== {model} ({name}) ==");
        println!("{}", report::frontier_table(&cells, &name));
        println!("{}", report::frontier_plot(&cells, 64, 18));
        // Significance over every method pair actually present in the
        // store (the hardcoded eagl/alps/hawq trio missed everything else).
        for (a, b) in report::method_pairs(&cells) {
            let sig = report::significance(&cells, &a, &b);
            if !sig.is_empty() {
                println!("Wilcoxon rank-sum {a} vs {b}:");
                for (bud, p) in sig {
                    println!("  budget {:>4.0}%  p = {:.4}", bud * 100.0, p);
                }
            }
        }
        report::write_csv(&cells, &dir.join("frontier.csv"))?;
        println!("csv written to {}", dir.join("frontier.csv").display());
        per_model.push((model.clone(), cells));
    }
    mpq::ensure!(
        !per_model.is_empty(),
        "no sweep results for {:?} — run `mpq sweep` or `mpq exp` first",
        models
    );
    if per_model.len() > 1 {
        println!("{}", report::cross_model_table(&per_model));
        let out = coordinator::results_dir_for(
            backend::resolve(backend_req.as_deref(), &models[0])?,
            &models[0],
        )
        .parent()
        .map(|p| p.join("frontier_all.csv"))
        .unwrap_or_else(|| std::path::PathBuf::from("frontier_all.csv"));
        report::write_csv_multi(&per_model, &out)?;
        println!("cross-model csv written to {}", out.display());
    }
    Ok(())
}

fn cmd_eagl(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let ck = match args.opt_str("ckpt") {
        Some(p) => mpq::ckpt::Checkpoint::load(std::path::Path::new(p))?,
        None => co.base_checkpoint()?,
    };
    let t0 = std::time::Instant::now();
    let ents = mpq::eagl::checkpoint_entropies(&co.graph, &ck, co.mcfg.b_hi)?;
    let dt = t0.elapsed();
    println!(
        "EAGL on {} layers in {:.3} ms (paper Table 3: CPU seconds)",
        co.graph.layers.len(),
        dt.as_secs_f64() * 1e3
    );
    println!("{:<16} {:>10} {:>8}", "layer", "H(bits)", "alloc");
    for l in &co.graph.layers {
        let b = l.fixed_bits.unwrap_or(co.mcfg.b_hi);
        println!("{:<16} {:>10.4} {:>8}", l.name, ents[l.qindex], b);
    }
    Ok(())
}
