//! `mpq` — command-line launcher for the mixed-precision quantization
//! framework.
//!
//! ```text
//! mpq info       --model sim_skew
//! mpq train-base --model sim_skew [--steps 400]
//! mpq gains      --model sim_skew --method eagl|alps|hawq_v3
//! mpq select     --model sim_skew --method eagl --budget 0.7
//! mpq run        --model sim_skew --method eagl --budget 0.7 --seed 0
//! mpq sweep      --model sim_skew --methods eagl,alps,hawq_v3,first_to_last
//!                --budgets 0.95,0.9,...  --seeds 3
//! mpq report     --model sim_skew
//! mpq eagl       --model sim_skew [--ckpt path]   # offline metric (Fig. 2)
//! ```
//!
//! Backend selection: `--backend sim|pjrt|auto` (default auto).  Auto uses
//! the pjrt artifact runtime when `artifacts/` holds the model's manifest
//! *and* the binary was built with `--features pjrt`; otherwise the
//! hermetic pure-Rust sim backend (models `sim_tiny`, `sim_skew`).

use mpq::backend::{self, Backend, BackendKind, Task};
use mpq::cli::Args;
use mpq::coordinator::{Coordinator, ResultStore};
use mpq::methods::MethodKind;
use mpq::quant::BitsConfig;
use mpq::report;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn metric_name(task: Task) -> &'static str {
    match task {
        Task::Cls => "top-1 accuracy",
        Task::Seg => "mIoU",
        Task::Span => "F1",
    }
}

/// Resolve (backend kind, model): an explicit --model wins; otherwise the
/// default model follows the backend (artifacts → qresnet20, sim →
/// sim_skew).
fn resolve_target(args: &Args) -> mpq::Result<(BackendKind, String)> {
    let requested = args.opt_str("backend");
    match args.opt_str("model") {
        Some(model) => Ok((backend::resolve(requested, model)?, model.to_string())),
        None => {
            let kind = backend::resolve(requested, "qresnet20")?;
            let model = match kind {
                BackendKind::Pjrt => "qresnet20",
                BackendKind::Sim => "sim_skew",
            };
            Ok((kind, model.to_string()))
        }
    }
}

fn coordinator(args: &Args) -> mpq::Result<Coordinator<Box<dyn Backend>>> {
    let (kind, model) = resolve_target(args)?;
    let mut co = Coordinator::open(kind, &model, args.u64("data-seed", 7)?)?;
    co.base_steps = args.usize("base-steps", co.base_steps)?;
    co.ft_steps = args.usize("ft-steps", co.ft_steps)?;
    co.eval_batches = args.usize("eval-batches", co.eval_batches)?;
    co.mcfg.alps_steps = args.usize("alps-steps", co.mcfg.alps_steps)?;
    co.mcfg.hawq_samples = args.usize("hawq-samples", co.mcfg.hawq_samples)?;
    co.mcfg.hawq_batches = args.usize("hawq-batches", co.mcfg.hawq_batches)?;
    // Sweep parallelism: --workers wins, else MPQ_WORKERS, else available
    // parallelism (resolved in default_workers, already set on co).
    co.workers = args.usize("workers", co.workers)?.max(1);
    Ok(co)
}

fn run() -> mpq::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train-base") => cmd_train_base(&args),
        Some("gains") => cmd_gains(&args),
        Some("select") => cmd_select(&args),
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("report") => cmd_report(&args),
        Some("eagl") => cmd_eagl(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand '{cmd}'\n");
            }
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
mpq — mixed-precision quantization framework (EAGL + ALPS, Bablani et al. 2023)

subcommands:
  info        --model M                     manifest/graph/cost summary
  train-base  --model M [--base-steps N]    train + cache 4-bit base & 8-bit ref
  gains       --model M --method K          per-layer gain estimates + timing
  select      --model M --method K --budget F   knapsack selection at budget
  run         --model M --method K --budget F --seed S   one full experiment
  sweep       --model M --methods a,b,.. --budgets f,..  --seeds N   full sweep
  report      --model M                     frontier table/plot/significance
  eagl        --model M [--ckpt P]          offline EAGL metric (Fig. 2)

backends: --backend sim|pjrt|auto (default auto).  sim = hermetic pure-Rust
          reference executor (models sim_tiny, sim_skew; no artifacts).
          pjrt = AOT artifact runtime (needs `make artifacts` + a build
          with --features pjrt).  auto prefers pjrt when available.
common flags: --data-seed, --base-steps, --ft-steps, --eval-batches,
              --alps-steps, --hawq-samples, --hawq-batches,
              --workers N (parallel ALPS/HAWQ gain estimation; default:
              available parallelism; results bit-identical at any N)
env: MPQ_ARTIFACTS (artifacts dir), MPQ_RESULTS (results root),
     MPQ_LOG (debug|info|warn|error), MPQ_WORKERS (default for --workers)
";

fn cmd_info(args: &Args) -> mpq::Result<()> {
    let co = coordinator(args)?;
    let g = &co.graph;
    println!("model: {}", co.model);
    println!("backend: {}", co.rt.kind());
    println!(
        "task: {:?} ({})",
        co.rt.manifest().task,
        metric_name(co.rt.manifest().task)
    );
    println!("layers: {} ({} selectable groups)", g.layers.len(), g.groups.len());
    println!("params: {}", co.rt.manifest().params.len());
    println!(
        "selectable BMACs: 4-bit {:.3} G / 2-bit {:.3} G",
        g.selectable_bmacs(4) as f64 / 1e9,
        g.selectable_bmacs(2) as f64 / 1e9
    );
    let b4 = BitsConfig::uniform(g, 4);
    println!(
        "uniform 4-bit: compression {:.2}x, {:.4} GBOPs",
        mpq::quant::compression_ratio(g, &b4),
        mpq::quant::gbops(g, &b4)
    );
    println!(
        "\n{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
        "layer", "kind", "macs", "params", "fixed", "group"
    );
    for l in &g.layers {
        println!(
            "{:<16} {:>6} {:>12} {:>10} {:>8} {:>12}",
            l.name,
            l.kind,
            l.macs,
            l.weight_params,
            l.fixed_bits.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            l.link_group
        );
    }
    Ok(())
}

fn cmd_train_base(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let task = co.rt.manifest().task;
    let ck4 = co.base_checkpoint()?;
    let e4 = co.eval_uniform(&ck4, 4)?;
    println!("4-bit base: loss {:.4} {} {:.4}", e4.loss, metric_name(task), e4.metric);
    let ck8 = co.reference_checkpoint()?;
    let e8 = co.eval_uniform(&ck8, 8)?;
    println!("8-bit ref : loss {:.4} {} {:.4}", e8.loss, metric_name(task), e8.metric);
    Ok(())
}

fn cmd_gains(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let est = co.gains(kind)?;
    println!("method: {} ({:.3}s to estimate)", kind.name(), est.wall_seconds);
    println!("{:<16} {:>10}", "layer", "gain");
    for l in &co.graph.layers {
        println!(
            "{:<16} {:>10.5}{}",
            l.name,
            est.per_layer[l.qindex],
            if l.fixed_bits.is_some() { "  (fixed)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_select(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let bits = co.select(kind, frac)?;
    println!(
        "{}",
        report::layer_selection_map(&co.graph, &[(kind.name().to_string(), bits.clone())])
    );
    println!(
        "compression {:.2}x  GBOPs {:.4}  groups at 2-bit: {}",
        mpq::quant::compression_ratio(&co.graph, &bits),
        mpq::quant::gbops(&co.graph, &bits),
        bits.count_at(&co.graph, 2)
    );
    Ok(())
}

fn cmd_run(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let task = co.rt.manifest().task;
    let kind = MethodKind::parse(&args.str("method", "eagl"))?;
    let frac = args.f64("budget", 0.7)?;
    let seed = args.u64("seed", 0)?;
    let rec = co.run_one(kind, frac, seed)?;
    println!(
        "{} {} budget {:.0}% seed {}: {} = {:.4} (loss {:.4}) [{:.1}s]",
        rec.model,
        rec.method,
        frac * 100.0,
        seed,
        metric_name(task),
        rec.metric,
        rec.loss,
        rec.wall_s
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let task = co.rt.manifest().task;
    let kinds: Vec<MethodKind> = args
        .list("methods", &["eagl", "alps", "hawq_v3", "uniform", "first_to_last"])
        .iter()
        .map(|s| MethodKind::parse(s))
        .collect::<mpq::Result<_>>()?;
    let budgets = args.f64_list(
        "budgets",
        &[0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60],
    )?;
    let n_seeds = args.u64("seeds", 3)?;
    let seeds: Vec<u64> = (0..n_seeds).collect();
    let store_path = co.results_dir.join("sweep.jsonl");
    let mut store = ResultStore::open(&store_path)?;
    let records = co.sweep(&kinds, &budgets, &seeds, &mut store)?;
    let cells = report::frontier(&records);
    println!("{}", report::frontier_table(&cells, metric_name(task)));
    Ok(())
}

fn cmd_report(args: &Args) -> mpq::Result<()> {
    let co = coordinator(args)?;
    let store = ResultStore::open(&co.results_dir.join("sweep.jsonl"))?;
    mpq::ensure!(!store.records().is_empty(), "no sweep results yet — run `mpq sweep`");
    let cells = report::frontier(store.records());
    let name = metric_name(co.rt.manifest().task);
    println!("{}", report::frontier_table(&cells, name));
    println!("{}", report::frontier_plot(&cells, 64, 18));
    for pair in [("eagl", "hawq_v3"), ("alps", "hawq_v3"), ("eagl", "first_to_last")] {
        let sig = report::significance(&cells, pair.0, pair.1);
        if !sig.is_empty() {
            println!("Wilcoxon rank-sum {} vs {}:", pair.0, pair.1);
            for (b, p) in sig {
                println!("  budget {:>4.0}%  p = {:.4}", b * 100.0, p);
            }
        }
    }
    report::write_csv(&cells, &co.results_dir.join("frontier.csv"))?;
    println!("csv written to {}", co.results_dir.join("frontier.csv").display());
    Ok(())
}

fn cmd_eagl(args: &Args) -> mpq::Result<()> {
    let mut co = coordinator(args)?;
    let ck = match args.opt_str("ckpt") {
        Some(p) => mpq::ckpt::Checkpoint::load(std::path::Path::new(p))?,
        None => co.base_checkpoint()?,
    };
    let t0 = std::time::Instant::now();
    let ents = mpq::eagl::checkpoint_entropies(&co.graph, &ck, co.mcfg.b_hi)?;
    let dt = t0.elapsed();
    println!(
        "EAGL on {} layers in {:.3} ms (paper Table 3: CPU seconds)",
        co.graph.layers.len(),
        dt.as_secs_f64() * 1e3
    );
    println!("{:<16} {:>10} {:>8}", "layer", "H(bits)", "alloc");
    for l in &co.graph.layers {
        let b = l.fixed_bits.unwrap_or(co.mcfg.b_hi);
        println!("{:<16} {:>10.4} {:>8}", l.name, ents[l.qindex], b);
    }
    Ok(())
}
