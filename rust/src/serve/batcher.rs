//! Dynamic micro-batching: the submission queue, size/deadline batch
//! closing policy, request splitting, and plan-order response reassembly.
//!
//! ## Bit-identity contract
//!
//! Every response must be **bit-identical to an unbatched single-request
//! execution of the same backend entry** on that request's samples, at
//! any batch composition, `max_batch`, and worker count — batching must
//! be invisible.  With the reference kernels (or per-request mode) that
//! unbatched execution *is* `eval_step`, so responses match it bit for
//! bit; with the packed inference kernels the fused entry's logits layer
//! applies its scale in the epilogue, so responses are epsilon-equal to
//! `eval_step` instead (see [`crate::kernels::packed`]) while remaining
//! bit-identical across every batching configuration.  The batcher
//! guarantees the invariance by construction rather than by tolerance:
//!
//! * the unit of fused execution is a **chunk** — a contiguous run of one
//!   request's samples, `≤ max_batch` of them.  Chunk boundaries are a
//!   pure function of (request size, `max_batch`), never of queue state,
//!   batch composition, or worker count;
//! * the fused forward (`infer_step`) produces **per-sample logits**, and
//!   every kernel under it is row-independent (documented accumulation
//!   order in [`crate::kernels::gemm`]), so a sample's logits do not
//!   depend on which batch it rode in;
//! * reassembly writes each chunk's logit rows back into the request's
//!   buffer at the chunk's offset (plan order), and only when the **whole
//!   request** is present runs one [`softmax_ce`] over all of its samples
//!   — the exact computation `eval_step` performs on that request alone.
//!
//! In the per-request fallback mode (backends without an `infer_step`
//! entry) a chunk is always a whole request and the worker's `eval_step`
//! call *is* the reference computation, so identity is trivial.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::kernels::gemm::softmax_ce;
use crate::tensor::Tensor;

use super::engine::EpochState;
use super::metrics::Metrics;
use super::trace::{ReqTrace, Stage};

/// One served response.  `loss`/`evalout` carry exactly what a direct
/// [`crate::backend::Backend::eval_step`] on the request's samples
/// returns (for classification: mean loss and the correct-count scalar).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub samples: usize,
    pub loss: f32,
    pub evalout: Tensor,
    /// Submit→completion latency as observed by the engine.
    pub latency_s: f64,
    /// Serving epoch whose (checkpoint, bits) produced these outputs —
    /// the epoch active when the request was admitted (see
    /// [`super::engine::EpochState`]).
    pub epoch: u64,
}

impl Response {
    /// Classification accuracy (correct / samples) when `evalout` is the
    /// scalar correct count; NaN for other tasks.
    pub fn accuracy(&self) -> f64 {
        if self.evalout.len() == 1 {
            self.evalout.item() as f64 / self.samples as f64
        } else {
            f64::NAN
        }
    }
}

/// One-shot completion slot a client blocks on.
pub(crate) struct Promise {
    slot: Mutex<Option<crate::Result<Response>>>,
    cv: Condvar,
}

impl Promise {
    fn new() -> Promise {
        Promise {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn fulfill(&self, r: crate::Result<Response>) {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(r);
        }
        self.cv.notify_all();
    }
}

/// Handle returned by [`crate::serve::Engine::submit`]; wait for the
/// response with [`Ticket::wait`].
pub struct Ticket {
    pub(crate) id: u64,
    pub(crate) promise: Arc<Promise>,
    /// Span buffer when this request is trace-sampled: the client side
    /// (HTTP conn thread) records parse/serialize/write spans through it,
    /// and the last clone's drop publishes the whole request.
    pub(crate) trace: Option<ReqTrace>,
}

impl Ticket {
    /// The engine-assigned request id (strictly increasing in submission
    /// order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The request's span buffer when tracing sampled it.
    pub fn trace(&self) -> Option<&ReqTrace> {
        self.trace.as_ref()
    }

    /// Block until the engine fulfills this request.
    pub fn wait(self) -> crate::Result<Response> {
        let mut slot = self.promise.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.promise.cv.wait(slot).unwrap();
        }
    }
}

/// Mutable reassembly state of one in-flight request.
struct PendingState {
    /// Concatenated per-sample logits, `[samples * classes]`, filled
    /// chunk by chunk (fused mode only).
    logits: Vec<f32>,
    classes: usize,
    done_chunks: usize,
    finished: bool,
}

/// One in-flight request: immutable inputs plus the reassembly state.
/// The request pins the [`EpochState`] that admitted it, so a hot-swap
/// cannot retire a config while batches built on it are still in flight.
pub(crate) struct Pending {
    pub id: u64,
    pub x: Tensor,
    pub y: Tensor,
    pub samples: usize,
    pub submitted: Instant,
    /// The serving config active at admission; every chunk of this
    /// request executes against it (never the post-swap one).
    pub epoch_state: Arc<EpochState>,
    total_chunks: usize,
    state: Mutex<PendingState>,
    promise: Arc<Promise>,
    metrics: Arc<Metrics>,
    /// Span buffer when this request is trace-sampled (`None` = not
    /// sampled or tracing disabled; every hook below is gated on it).
    pub trace: Option<ReqTrace>,
}

impl Pending {
    pub fn new(
        id: u64,
        x: Tensor,
        y: Tensor,
        samples: usize,
        total_chunks: usize,
        epoch_state: Arc<EpochState>,
        metrics: Arc<Metrics>,
        trace: Option<ReqTrace>,
    ) -> Pending {
        Pending {
            id,
            x,
            y,
            samples,
            submitted: Instant::now(),
            epoch_state,
            total_chunks,
            state: Mutex::new(PendingState {
                logits: Vec::new(),
                classes: 0,
                done_chunks: 0,
                finished: false,
            }),
            promise: Arc::new(Promise::new()),
            metrics,
            trace,
        }
    }

    /// The serving epoch this request was admitted under.
    pub fn epoch(&self) -> u64 {
        self.epoch_state.epoch
    }

    pub fn ticket(&self) -> Ticket {
        Ticket {
            id: self.id,
            promise: Arc::clone(&self.promise),
            trace: self.trace.clone(),
        }
    }

    fn finish(&self, state: &mut PendingState, r: crate::Result<Response>) {
        state.finished = true;
        match &r {
            Ok(resp) => self
                .metrics
                .record_request(self.samples as u64, Duration::from_secs_f64(resp.latency_s)),
            Err(_) => self.metrics.record_failed(),
        }
        self.promise.fulfill(r);
    }

    /// Fused-mode chunk completion: write `len` logit rows at sample
    /// offset `offset`; when the last chunk lands, run one softmax-CE
    /// over the whole request — the identical computation a direct
    /// single-request `eval_step` performs — and fulfill the ticket.
    pub fn complete_chunk(&self, offset: usize, len: usize, classes: usize, rows: &[f32]) {
        let mut st = self.state.lock().unwrap();
        if st.finished {
            return;
        }
        if st.classes == 0 {
            st.classes = classes;
            st.logits.resize(self.samples * classes, 0.0);
        }
        // A backend returning wrong-shaped logits (class-count drift
        // across chunks, a short row block, an offset past the request)
        // would panic the slice below inside a worker thread and strand
        // the ticket — fail the request cleanly instead.
        if st.classes != classes
            || rows.len() != len * classes
            || (offset + len) * classes > st.logits.len()
        {
            let err = crate::err!(
                "serve request {}: chunk shape mismatch (offset {offset}, len {len}, \
                 classes {classes}, {} logit row value(s)) against {} classes x {} sample(s)",
                self.id,
                rows.len(),
                st.classes,
                self.samples
            );
            self.finish(&mut st, Err(err));
            return;
        }
        let t_asm = self.trace.as_ref().map(|rt| rt.now_ns());
        st.logits[offset * classes..(offset + len) * classes].copy_from_slice(rows);
        if let (Some(rt), Some(t0)) = (&self.trace, t_asm) {
            rt.span(Stage::Reassembly, self.epoch(), t0, rt.now_ns());
        }
        st.done_chunks += 1;
        if st.done_chunks < self.total_chunks {
            return;
        }
        // Out-of-range labels would index past the logit row inside
        // softmax_ce — a worker-thread panic that strands the ticket, so
        // convert them into a clean request failure instead.
        let y = self.y.i32s();
        if let Some(&bad) = y.iter().find(|&&c| c < 0 || c as usize >= classes) {
            let err = crate::err!(
                "serve request {}: label {bad} out of range for {classes} class(es)",
                self.id
            );
            self.finish(&mut st, Err(err));
            return;
        }
        let t_epi = self.trace.as_ref().map(|rt| rt.now_ns());
        let (loss, correct) = softmax_ce(&st.logits, y, self.samples, classes, None);
        if let (Some(rt), Some(t0)) = (&self.trace, t_epi) {
            rt.span(Stage::Epilogue, self.epoch(), t0, rt.now_ns());
        }
        let resp = Response {
            id: self.id,
            samples: self.samples,
            loss,
            // Same shape/content as the sim backend's eval_step evalout.
            evalout: Tensor::from_f32(&[], vec![correct as f32]),
            latency_s: self.submitted.elapsed().as_secs_f64(),
            epoch: self.epoch(),
        };
        self.finish(&mut st, Ok(resp));
    }

    /// Per-request-mode completion: the worker's own `eval_step` outputs.
    pub fn complete_whole(&self, loss: f32, evalout: Tensor) {
        let mut st = self.state.lock().unwrap();
        if st.finished {
            return;
        }
        let resp = Response {
            id: self.id,
            samples: self.samples,
            loss,
            evalout,
            latency_s: self.submitted.elapsed().as_secs_f64(),
            epoch: self.epoch(),
        };
        self.finish(&mut st, Ok(resp));
    }

    /// Fail the whole request (first failure wins; later chunk
    /// completions become no-ops).
    pub fn fail(&self, msg: &str) {
        let mut st = self.state.lock().unwrap();
        if st.finished {
            return;
        }
        let err = crate::err!("serve request {}: {msg}", self.id);
        self.finish(&mut st, Err(err));
    }
}

/// A schedulable unit: `len` samples of one request starting at sample
/// `offset`.  Chunk geometry depends only on (request size, max_batch).
pub(crate) struct ChunkJob {
    pub pending: Arc<Pending>,
    pub offset: usize,
    pub len: usize,
}

/// What a worker should do next (see [`BatchQueue::next_batch`]).
pub(crate) enum NextBatch {
    /// Execute these chunks as one micro-batch.
    Ready(Vec<ChunkJob>),
    /// Queue is non-empty but the batch is still filling: wait until the
    /// oldest request's deadline.
    Wait(Instant),
    /// Queue is empty.
    Idle,
}

/// The shared submission queue with the size/deadline closing policy.
/// Guarded by one engine-level mutex; everything here is O(chunk count).
///
/// The queue also owns the **active serving epoch**: admission captures
/// `active` under the same lock that orders request ids, and a hot-swap
/// replaces it under that lock too, so "which config admitted request
/// id=k" is a total order with no torn reads and no second lock.
pub(crate) struct BatchQueue {
    queue: VecDeque<ChunkJob>,
    queued_samples: usize,
    pub max_batch: usize,
    pub timeout: Duration,
    pub draining: bool,
    pub fatal: Option<String>,
    /// Config new submissions are admitted under (see
    /// [`super::engine::Engine::swap`]).
    pub active: Arc<EpochState>,
    next_id: u64,
}

impl BatchQueue {
    pub fn new(max_batch: usize, timeout: Duration, active: Arc<EpochState>) -> BatchQueue {
        BatchQueue {
            queue: VecDeque::new(),
            queued_samples: 0,
            max_batch: max_batch.max(1),
            timeout,
            draining: false,
            fatal: None,
            active,
            next_id: 0,
        }
    }

    /// Samples currently queued and not yet claimed by a worker (the
    /// `/metrics` queue-depth gauge).
    pub(crate) fn queued_samples(&self) -> usize {
        self.queued_samples
    }

    /// Next request id (strictly increasing; allocated under the queue
    /// lock so submission order defines the id order).
    pub fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Number of chunks a request of `samples` splits into.
    pub fn chunks_for(&self, samples: usize, split: bool) -> usize {
        if split {
            (samples + self.max_batch - 1) / self.max_batch
        } else {
            1
        }
    }

    /// Enqueue a request: in fused mode (`split`) as `max_batch`-sized
    /// chunks, otherwise as one whole-request chunk.
    pub fn enqueue(&mut self, pending: &Arc<Pending>, split: bool) {
        if split {
            let mut offset = 0;
            while offset < pending.samples {
                let len = self.max_batch.min(pending.samples - offset);
                self.queue.push_back(ChunkJob {
                    pending: Arc::clone(pending),
                    offset,
                    len,
                });
                offset += len;
            }
        } else {
            self.queue.push_back(ChunkJob {
                pending: Arc::clone(pending),
                offset: 0,
                len: pending.samples,
            });
        }
        self.queued_samples += pending.samples;
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The batch closing policy.  A batch closes when enough samples are
    /// queued to fill `max_batch`, when the oldest queued request has
    /// waited `timeout`, or when the engine is draining; otherwise the
    /// caller sleeps until the deadline.  Chunks are popped FIFO while
    /// they fit (a whole-request chunk larger than `max_batch` — the
    /// per-request fallback mode — rides alone).
    ///
    /// A batch never spans a serving-epoch boundary: a fused forward runs
    /// one (checkpoint, bits) pair, so mixing admissions from before and
    /// after a hot-swap would answer some requests with the wrong config.
    /// Coalescing stops at the first chunk whose epoch differs from the
    /// batch head's (FIFO order keeps epochs contiguous in the queue).
    pub fn next_batch(&mut self, now: Instant) -> NextBatch {
        let Some(front) = self.queue.front() else {
            return NextBatch::Idle;
        };
        let deadline = front.pending.submitted + self.timeout;
        let ready = self.draining || self.queued_samples >= self.max_batch || now >= deadline;
        if !ready {
            return NextBatch::Wait(deadline);
        }
        let Some(first) = self.queue.pop_front() else {
            // Unreachable given the front() check above, but a panic
            // here would take a worker thread down with the queue lock.
            return NextBatch::Idle;
        };
        let epoch = first.pending.epoch();
        let mut total = first.len;
        let mut batch = vec![first];
        while let Some(next) = self.queue.front() {
            if total + next.len > self.max_batch || next.pending.epoch() != epoch {
                break;
            }
            total += next.len;
            match self.queue.pop_front() {
                Some(next) => batch.push(next),
                None => break,
            }
        }
        self.queued_samples = self.queued_samples.saturating_sub(total);
        NextBatch::Ready(batch)
    }

    /// Pop everything (the fatal-error path fails each job's request).
    pub fn drain_all(&mut self) -> Vec<ChunkJob> {
        self.queued_samples = 0;
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::ckpt::Checkpoint;

    fn epoch_state(epoch: u64) -> Arc<EpochState> {
        Arc::new(EpochState {
            epoch,
            ckpt: Checkpoint::new(vec![], vec![]),
            bits: vec![],
            shared_exec: None,
            budget_frac: f64::NAN,
            label: format!("test-{epoch}"),
        })
    }

    fn queue(max_batch: usize, timeout: Duration) -> BatchQueue {
        BatchQueue::new(max_batch, timeout, epoch_state(0))
    }

    fn pending_at(id: u64, samples: usize, total_chunks: usize, epoch: u64) -> Arc<Pending> {
        let x = Tensor::zeros(&[samples, 2]);
        let y = Tensor::zeros_i32(&[samples]);
        Arc::new(Pending::new(
            id,
            x,
            y,
            samples,
            total_chunks,
            epoch_state(epoch),
            Arc::new(Metrics::new()),
            None,
        ))
    }

    fn pending(id: u64, samples: usize, total_chunks: usize) -> Arc<Pending> {
        pending_at(id, samples, total_chunks, 0)
    }

    #[test]
    fn splits_into_max_batch_chunks_with_contiguous_offsets() {
        let mut q = queue(4, Duration::from_millis(10));
        assert_eq!(q.chunks_for(9, true), 3);
        assert_eq!(q.chunks_for(9, false), 1);
        let p = pending(0, 9, 3);
        q.enqueue(&p, true);
        let NextBatch::Ready(b) = q.next_batch(Instant::now() + Duration::from_secs(1)) else {
            panic!("expected ready batch after deadline");
        };
        // One full chunk fits per 4-sample batch.
        assert_eq!(b.len(), 1);
        assert_eq!((b[0].offset, b[0].len), (0, 4));
        let NextBatch::Ready(b) = q.next_batch(Instant::now() + Duration::from_secs(1)) else {
            panic!()
        };
        assert_eq!((b[0].offset, b[0].len), (4, 4));
        let NextBatch::Ready(b) = q.next_batch(Instant::now() + Duration::from_secs(1)) else {
            panic!()
        };
        assert_eq!((b[0].offset, b[0].len), (8, 1));
        assert!(matches!(q.next_batch(Instant::now()), NextBatch::Idle));
    }

    #[test]
    fn size_trigger_fills_up_to_max_batch() {
        let mut q = queue(8, Duration::from_secs(10));
        for id in 0..4 {
            q.enqueue(&pending(id, 3, 1), true);
        }
        // 12 samples queued >= 8 → ready immediately, takes 3+3 and stops
        // before overflowing.
        let NextBatch::Ready(b) = q.next_batch(Instant::now()) else {
            panic!("size trigger must close the batch")
        };
        assert_eq!(b.iter().map(|c| c.len).sum::<usize>(), 6);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn deadline_trigger_and_wait() {
        let mut q = queue(64, Duration::from_millis(50));
        let p = pending(0, 2, 1);
        let t0 = p.submitted;
        q.enqueue(&p, true);
        match q.next_batch(t0) {
            NextBatch::Wait(deadline) => assert_eq!(deadline, t0 + Duration::from_millis(50)),
            _ => panic!("under-full batch before the deadline must wait"),
        }
        let NextBatch::Ready(b) = q.next_batch(t0 + Duration::from_millis(51)) else {
            panic!("deadline must close the partial batch")
        };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].len, 2);
    }

    #[test]
    fn draining_flushes_immediately_and_oversized_fallback_chunk_rides_alone() {
        let mut q = queue(4, Duration::from_secs(10));
        q.enqueue(&pending(0, 9, 1), false); // per-request mode: no split
        q.enqueue(&pending(1, 2, 1), false);
        q.draining = true;
        let NextBatch::Ready(b) = q.next_batch(Instant::now()) else {
            panic!("draining must flush")
        };
        assert_eq!(b.len(), 1, "oversized whole-request chunk rides alone");
        assert_eq!(b[0].len, 9);
        let NextBatch::Ready(b) = q.next_batch(Instant::now()) else { panic!() };
        assert_eq!(b[0].len, 2);
        assert!(q.is_empty());
    }

    #[test]
    fn batches_never_mix_epochs() {
        // Requests admitted under epoch 0 and epoch 1 are interleaved in
        // the queue; coalescing must stop at the epoch boundary even
        // though both chunks would fit in one batch.
        let mut q = queue(8, Duration::from_secs(10));
        q.enqueue(&pending_at(0, 2, 1, 0), true);
        q.enqueue(&pending_at(1, 2, 1, 1), true);
        q.enqueue(&pending_at(2, 2, 1, 1), true);
        q.draining = true; // flush immediately regardless of deadline
        let NextBatch::Ready(b) = q.next_batch(Instant::now()) else {
            panic!("draining must flush")
        };
        assert_eq!(b.len(), 1, "epoch-0 chunk must ride alone");
        assert_eq!(b[0].pending.epoch(), 0);
        let NextBatch::Ready(b) = q.next_batch(Instant::now()) else { panic!() };
        assert_eq!(b.len(), 2, "both epoch-1 chunks coalesce");
        assert!(b.iter().all(|c| c.pending.epoch() == 1));
        assert!(q.is_empty());
    }

    #[test]
    fn ids_are_strictly_increasing() {
        let mut q = queue(4, Duration::from_millis(1));
        assert_eq!((q.alloc_id(), q.alloc_id(), q.alloc_id()), (0, 1, 2));
    }

    #[test]
    fn chunk_reassembly_runs_one_softmax_over_the_whole_request() {
        // 3 samples, 2 classes, reassembled from two chunks out of order.
        let metrics = Arc::new(Metrics::new());
        let y = Tensor::from_i32(&[3], vec![0, 1, 0]);
        let p = Pending::new(7, Tensor::zeros(&[3, 1]), y.clone(), 3, 2, epoch_state(0), metrics, None);
        let t = p.ticket();
        let logits = vec![2.0f32, -1.0, 0.5, 1.5, 3.0, 0.0];
        // Chunk 2 (sample 2) lands before chunk 1 (samples 0..2).
        p.complete_chunk(2, 1, 2, &logits[4..6]);
        p.complete_chunk(0, 2, 2, &logits[0..4]);
        let r = t.wait().unwrap();
        let (ref_loss, ref_correct) = softmax_ce(&logits, y.i32s(), 3, 2, None);
        assert_eq!(r.loss.to_bits(), ref_loss.to_bits());
        assert_eq!(r.evalout.item() as usize, ref_correct);
        assert_eq!(r.id, 7);
        assert_eq!(r.samples, 3);
    }

    #[test]
    fn out_of_range_label_fails_cleanly_instead_of_panicking() {
        let y = Tensor::from_i32(&[2], vec![0, 9]); // 9 >= 2 classes
        let p = Pending::new(5, Tensor::zeros(&[2, 1]), y, 2, 1, epoch_state(0), Arc::new(Metrics::new()), None);
        let t = p.ticket();
        p.complete_chunk(0, 2, 2, &[0.1, 0.2, 0.3, 0.4]);
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("label 9 out of range"), "{err}");
    }

    #[test]
    fn chunk_shape_mismatch_fails_cleanly_instead_of_panicking() {
        // Class-count drift between chunks of one request: chunk 1
        // reports 2 classes, chunk 2 reports 3.  Pre-fix this was a
        // debug_assert + slice panic in a worker thread; now the ticket
        // resolves with an error.
        let p = pending(11, 4, 2);
        let t = p.ticket();
        p.complete_chunk(0, 2, 2, &[0.0; 4]);
        p.complete_chunk(2, 2, 3, &[0.0; 6]);
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("chunk shape mismatch"), "{err}");

        // A short logit block from the backend must fail the same way.
        let p = pending(12, 2, 1);
        let t = p.ticket();
        p.complete_chunk(0, 2, 2, &[0.0; 3]); // needs 4 values
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("chunk shape mismatch"), "{err}");

        // An offset past the request's sample count must fail too.
        let p = pending(13, 2, 1);
        let t = p.ticket();
        p.complete_chunk(2, 2, 2, &[0.0; 4]); // rows 2..4 of a 2-sample request
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("chunk shape mismatch"), "{err}");
    }

    #[test]
    fn fail_wins_once_and_later_chunks_are_ignored() {
        let p = pending(3, 4, 2);
        let t = p.ticket();
        p.fail("backend exploded");
        p.complete_chunk(0, 2, 2, &[0.0; 4]); // ignored
        let err = t.wait().unwrap_err().to_string();
        assert!(err.contains("request 3"), "{err}");
        assert!(err.contains("backend exploded"), "{err}");
    }
}
