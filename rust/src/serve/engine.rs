//! The serving engine: N worker threads over one shared micro-batching
//! queue, each worker holding its own [`Backend`] instance.
//!
//! Per-worker backends matter twice over: PJRT clients are not `Sync`,
//! and the sim backend's [`crate::kernels`] weight-code/featurizer caches
//! are per-instance — a worker quantizes each layer's weights **once**
//! (on warmup or the first batch) and every subsequent request reuses the
//! codes, instead of re-materializing them per request.
//!
//! Execution modes (chosen at [`Engine::start`] from the manifest):
//!
//! * **fused** — the backend exposes an `infer_step` entry returning
//!   per-sample logits and the task is classification: chunks from many
//!   requests are concatenated into one forward pass of `≤ max_batch`
//!   samples, and responses are reassembled per request (see
//!   [`super::batcher`] for the bit-identity argument);
//! * **per-request** — fallback for backends without `infer_step` (or
//!   when [`ServeConfig::force_per_request`] is set): a micro-batch is a
//!   group of whole requests one worker dequeues together and runs
//!   through `eval_step` back to back on its warm caches.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{Backend, SharedExecState, Task};
use crate::ckpt::Checkpoint;
use crate::tensor::{DType, Tensor};

use super::batcher::{BatchQueue, ChunkJob, NextBatch, Pending, Ticket};
use super::metrics::{Metrics, MetricsSnapshot};

/// Source of per-worker backend instances (`Arc` so every worker thread
/// can hold it; cf. the coordinator's boxed [`crate::coordinator::Spawner`]).
pub type Spawner = Arc<dyn Fn() -> crate::Result<Box<dyn Backend>> + Send + Sync>;

/// Engine knobs.
#[derive(Clone)]
pub struct ServeConfig {
    /// Worker threads, each with its own backend.
    pub workers: usize,
    /// Micro-batch sample budget; also the chunk size oversized requests
    /// split into (fused mode).
    pub max_batch: usize,
    /// How long an under-full batch may wait for more traffic before a
    /// partial batch is dispatched.
    pub batch_timeout: Duration,
    /// Disable the fused `infer_step` path even when available (testing
    /// and apples-to-apples comparisons).
    pub force_per_request: bool,
    /// Run one throwaway single-sample inference per worker at startup so
    /// weight codes are materialized before the first real request.
    pub warmup: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: crate::coordinator::default_workers(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
        }
    }
}

/// State shared between the submit path and the worker threads.
struct Shared {
    q: Mutex<BatchQueue>,
    cv: Condvar,
    metrics: Arc<Metrics>,
    ckpt: Checkpoint,
    bits: Vec<f32>,
    fused: bool,
    /// Per-sample x dims (manifest eval shape minus the batch dim).
    sample_dims: Vec<usize>,
    x_dtype: DType,
    y_dtype: DType,
    /// Immutable execution state materialized once by the startup probe
    /// and adopted by every worker — e.g. the sim backend's bit-packed
    /// weight codes, so N workers share one per-layer packed
    /// materialization instead of packing N times.
    shared_exec: Option<SharedExecState>,
}

/// A running serving engine.  `submit` is thread-safe; [`Engine::drain`]
/// stops intake, finishes all queued work, and joins the workers.
pub struct Engine {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Validate the model contract, pick the execution mode, and spawn
    /// the worker pool.  `ckpt` is the checkpoint to serve and `bits`
    /// the per-layer precision vector (`BitsConfig::to_f32`).
    pub fn start(
        spawner: Spawner,
        ckpt: Checkpoint,
        bits: Vec<f32>,
        cfg: ServeConfig,
    ) -> crate::Result<Engine> {
        crate::ensure!(cfg.workers >= 1, "serve: --workers must be at least 1");
        crate::ensure!(cfg.max_batch >= 1, "serve: --max-batch must be at least 1");
        // Probe one backend for the model contract, then let every worker
        // open its own.  The probe cannot be handed to a worker thread:
        // `Box<dyn Backend>` carries no `Send` bound (PJRT clients must
        // stay on the thread that opened them), so backends are only ever
        // constructed inside their worker.
        let (fused, sample_dims, x_dtype, y_dtype, shared_exec) = {
            let mut probe = spawner()?;
            let m = probe.manifest();
            crate::ensure!(
                bits.len() == m.n_bits,
                "serve: bits vector has {} entries, model '{}' expects {}",
                bits.len(),
                m.model,
                m.n_bits
            );
            crate::ensure!(
                ckpt.names.len() == m.n_params(),
                "serve: checkpoint has {} tensors, model '{}' expects {}",
                ckpt.names.len(),
                m.model,
                m.n_params()
            );
            // Fused batching needs per-sample logits (infer_step), the
            // classification reassembly semantics, and f32 inputs (the
            // chunk concatenation copies f32 rows); anything else takes
            // the per-request eval_step path.
            let fused = !cfg.force_per_request
                && m.task == Task::Cls
                && m.x_dtype == DType::F32
                && m.entries.contains_key("infer_step");
            let dims = m.x_eval_shape.get(1..).unwrap_or(&[]).to_vec();
            crate::ensure!(
                !dims.is_empty(),
                "serve: model '{}' manifest has no eval input shape",
                m.model
            );
            let (x_dtype, y_dtype) = (m.x_dtype, m.y_dtype);
            // Materialize any shareable execution state (e.g. packed
            // weight codes) once, on the probe, before the workers spawn.
            let shared_exec = probe.prepare_shared(&ckpt, &bits)?;
            (fused, dims, x_dtype, y_dtype, shared_exec)
        };
        let shared = Arc::new(Shared {
            q: Mutex::new(BatchQueue::new(cfg.max_batch, cfg.batch_timeout)),
            cv: Condvar::new(),
            metrics: Arc::new(Metrics::new()),
            ckpt,
            bits,
            fused,
            sample_dims,
            x_dtype,
            y_dtype,
            shared_exec,
        });
        let mut handles = Vec::with_capacity(cfg.workers);
        for wi in 0..cfg.workers {
            let sh = Arc::clone(&shared);
            let sp = Arc::clone(&spawner);
            let warmup = cfg.warmup;
            let handle = std::thread::Builder::new()
                .name(format!("mpq-serve-{wi}"))
                .spawn(move || worker_loop(sh, sp, warmup))?;
            handles.push(handle);
        }
        Ok(Engine { shared, handles })
    }

    /// Whether the fused `infer_step` batching path is active.
    pub fn fused(&self) -> bool {
        self.shared.fused
    }

    /// Submit one request (`x`: `[samples, <per-sample dims>]`, `y`:
    /// matching labels).  Returns a [`Ticket`] whose id is strictly
    /// increasing in submission order.
    pub fn submit(&self, x: Tensor, y: Tensor) -> crate::Result<Ticket> {
        let samples = x.shape.first().copied().unwrap_or(0);
        crate::ensure!(samples > 0, "serve: request must contain at least one sample");
        crate::ensure!(
            x.shape.len() == self.shared.sample_dims.len() + 1
                && x.shape[1..] == self.shared.sample_dims[..],
            "serve: request x shape {:?} does not match per-sample dims {:?}",
            x.shape,
            self.shared.sample_dims
        );
        crate::ensure!(
            x.dtype() == self.shared.x_dtype,
            "serve: request x dtype {:?} does not match the model's {:?}",
            x.dtype(),
            self.shared.x_dtype
        );
        crate::ensure!(
            y.shape.first().copied().unwrap_or(0) == samples,
            "serve: y covers {} sample(s) but x has {}",
            y.shape.first().copied().unwrap_or(0),
            samples
        );
        // Reject label buffers a backend (or the fused softmax) would
        // choke on — a panic inside a worker thread would strand the
        // ticket forever, so labels must be validated at the door.
        crate::ensure!(
            y.dtype() == self.shared.y_dtype,
            "serve: request y dtype {:?} does not match the model's {:?}",
            y.dtype(),
            self.shared.y_dtype
        );
        if self.shared.fused {
            crate::ensure!(
                y.shape.len() == 1,
                "serve: classification labels must be rank-1 [samples], got shape {:?}",
                y.shape
            );
        }
        let ticket = {
            let mut q = self.shared.q.lock().unwrap();
            crate::ensure!(!q.draining, "serve: engine is draining — submission rejected");
            if let Some(f) = &q.fatal {
                crate::bail!("serve: engine failed: {f}");
            }
            let id = q.alloc_id();
            let total_chunks = q.chunks_for(samples, self.shared.fused);
            let pending = Arc::new(Pending::new(
                id,
                x,
                y,
                samples,
                total_chunks,
                Arc::clone(&self.shared.metrics),
            ));
            let ticket = pending.ticket();
            q.enqueue(&pending, self.shared.fused);
            self.shared.metrics.record_submitted();
            ticket
        };
        // Wake every idle worker: a multi-chunk request can fan out
        // across several of them at once.
        self.shared.cv.notify_all();
        Ok(ticket)
    }

    /// Point-in-time metrics (exact after [`drain`](Engine::drain)).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Samples queued and not yet claimed by a worker — the queue-depth
    /// gauge exposed on `/metrics`.
    pub fn queued_samples(&self) -> usize {
        self.shared.q.lock().unwrap().queued_samples()
    }

    /// Graceful shutdown: reject new submissions, flush every queued
    /// batch (ignoring the batch timeout), join the workers, and verify
    /// nothing was left unresolved.
    pub fn drain(mut self) -> crate::Result<MetricsSnapshot> {
        {
            self.shared.q.lock().unwrap().draining = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        {
            let q = self.shared.q.lock().unwrap();
            if let Some(f) = &q.fatal {
                crate::bail!("serve: engine failed before drain completed: {f}");
            }
            crate::ensure!(q.is_empty(), "serve: drain left work queued");
        }
        let snap = self.shared.metrics.snapshot();
        crate::ensure!(
            snap.submitted == snap.completed + snap.failed,
            "serve: drain left {} request(s) unresolved",
            snap.submitted - snap.completed - snap.failed
        );
        Ok(snap)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if self.handles.is_empty() {
            return; // already drained
        }
        {
            self.shared.q.lock().unwrap().draining = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Record an unrecoverable engine error: fail everything queued and
/// reject all future submissions.
fn fatal(sh: &Shared, msg: &str) {
    crate::warn!("serve: fatal: {msg}");
    let jobs = {
        let mut q = sh.q.lock().unwrap();
        q.fatal = Some(msg.to_string());
        q.drain_all()
    };
    for j in &jobs {
        j.pending.fail(msg);
    }
    sh.cv.notify_all();
}

fn worker_loop(sh: Arc<Shared>, spawner: Spawner, warmup: bool) {
    let mut be = match spawner() {
        Ok(b) => b,
        Err(e) => {
            fatal(&sh, &format!("worker backend open failed: {e}"));
            return;
        }
    };
    // Adopt the probe's shared execution state (e.g. packed weight
    // codes) before any request: the expensive per-layer materialization
    // happened exactly once, at engine startup.
    if let Some(h) = &sh.shared_exec {
        if let Err(e) = be.adopt_shared(h) {
            fatal(&sh, &format!("worker failed to adopt shared state: {e}"));
            return;
        }
    }
    if warmup {
        warmup_backend(&sh, &mut be);
    }
    let mut guard = sh.q.lock().unwrap();
    loop {
        if guard.fatal.is_some() {
            return;
        }
        match guard.next_batch(Instant::now()) {
            NextBatch::Ready(batch) => {
                drop(guard);
                sh.metrics.record_batch(
                    batch.len() as u64,
                    batch.iter().map(|c| c.len as u64).sum(),
                );
                execute_batch(&sh, &mut be, &batch);
                guard = sh.q.lock().unwrap();
            }
            NextBatch::Wait(deadline) => {
                let dur = deadline.saturating_duration_since(Instant::now());
                let (g, _) = sh.cv.wait_timeout(guard, dur).unwrap();
                guard = g;
            }
            NextBatch::Idle => {
                if guard.draining {
                    return;
                }
                guard = sh.cv.wait(guard).unwrap();
            }
        }
    }
}

/// Best-effort single-sample inference so the worker's weight-code cache
/// is populated before real traffic (results are identical either way —
/// the caches are semantically transparent).
fn warmup_backend(sh: &Shared, be: &mut Box<dyn Backend>) {
    let mut shape = vec![1usize];
    shape.extend_from_slice(&sh.sample_dims);
    let x = match sh.x_dtype {
        DType::F32 => Tensor::zeros(&shape),
        DType::I32 => Tensor::zeros_i32(&shape),
    };
    if sh.fused {
        let _ = be.infer_step(&sh.ckpt, &x, &sh.bits);
    } else {
        let y = Tensor::zeros_i32(&[1]);
        let _ = be.eval_step(&sh.ckpt, &x, &y, &sh.bits);
    }
}

fn execute_batch(sh: &Shared, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    if sh.fused {
        execute_fused(sh, be, batch);
    } else {
        execute_per_request(sh, be, batch);
    }
}

/// Fused mode: one forward pass over the concatenated chunk samples,
/// then per-request reassembly (row-independent kernels make the logits
/// independent of batch composition — see [`super::batcher`]).
fn execute_fused(sh: &Shared, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    let row: usize = sh.sample_dims.iter().product();
    let total: usize = batch.iter().map(|c| c.len).sum();
    let mut buf = Vec::with_capacity(total * row);
    for c in batch {
        let xs = c.pending.x.f32s();
        buf.extend_from_slice(&xs[c.offset * row..(c.offset + c.len) * row]);
    }
    let mut shape = vec![total];
    shape.extend_from_slice(&sh.sample_dims);
    let x = Tensor::from_f32(&shape, buf);
    match be.infer_step(&sh.ckpt, &x, &sh.bits) {
        Ok(logits) => {
            let classes = logits.shape.get(1).copied().unwrap_or(1);
            let ls = logits.f32s();
            let mut off = 0usize;
            for c in batch {
                c.pending.complete_chunk(
                    c.offset,
                    c.len,
                    classes,
                    &ls[off * classes..(off + c.len) * classes],
                );
                off += c.len;
            }
        }
        Err(e) => {
            let msg = format!("infer_step failed: {e}");
            for c in batch {
                c.pending.fail(&msg);
            }
        }
    }
}

/// Fallback mode: each chunk is a whole request; the worker's `eval_step`
/// call *is* the reference computation.
fn execute_per_request(sh: &Shared, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    for c in batch {
        match be.eval_step(&sh.ckpt, &c.pending.x, &c.pending.y, &sh.bits) {
            Ok((loss, evalout)) => c.pending.complete_whole(loss, evalout),
            Err(e) => c.pending.fail(&format!("eval_step failed: {e}")),
        }
    }
}
