//! The serving engine: N worker threads over one shared micro-batching
//! queue, each worker holding its own [`Backend`] instance.
//!
//! Per-worker backends matter twice over: PJRT clients are not `Sync`,
//! and the sim backend's [`crate::kernels`] weight-code/featurizer caches
//! are per-instance — a worker quantizes each layer's weights **once**
//! (on warmup or the first batch) and every subsequent request reuses the
//! codes, instead of re-materializing them per request.
//!
//! Execution modes (chosen at [`Engine::start`] from the manifest):
//!
//! * **fused** — the backend exposes an `infer_step` entry returning
//!   per-sample logits and the task is classification: chunks from many
//!   requests are concatenated into one forward pass of `≤ max_batch`
//!   samples, and responses are reassembled per request (see
//!   [`super::batcher`] for the bit-identity argument);
//! * **per-request** — fallback for backends without `infer_step` (or
//!   when [`ServeConfig::force_per_request`] is set): a micro-batch is a
//!   group of whole requests one worker dequeues together and runs
//!   through `eval_step` back to back on its warm caches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{Backend, SharedExecState, Task};
use crate::ckpt::Checkpoint;
use crate::tensor::{DType, Tensor};

use super::batcher::{BatchQueue, ChunkJob, NextBatch, Pending, Ticket};
use super::loadgen::FaultPlan;
use super::metrics::{Metrics, MetricsSnapshot};
use super::trace::{Stage, TraceSink};

/// One immutable serving configuration, version-stamped.  Admission
/// captures the active `Arc<EpochState>` under the queue lock; a
/// [`Engine::swap`] publishes a successor under the same lock.  In-flight
/// requests keep their admission epoch alive through their `Pending`
/// handles, so a swap never invalidates state a worker is executing on.
pub struct EpochState {
    /// Strictly increasing version (0 = the startup config).
    pub epoch: u64,
    pub ckpt: Checkpoint,
    /// Per-layer precision vector (`BitsConfig::to_f32`).
    pub bits: Vec<f32>,
    /// Shareable execution state materialized off the hot path (e.g. the
    /// sim backend's packed weight codes); `None` for backends whose
    /// execution state is per-call.
    pub shared_exec: Option<SharedExecState>,
    /// Budget fraction of the frontier record this config came from (NaN
    /// when the config is not frontier-derived, e.g. a startup uniform).
    pub budget_frac: f64,
    /// Human-readable tag for logs and `/metrics` ("startup",
    /// "eagl@0.60", ...).
    pub label: String,
}

/// Point-in-time epoch facts for `/metrics` and operator output.
#[derive(Debug, Clone)]
pub struct EpochInfo {
    pub epoch: u64,
    pub budget_frac: f64,
    pub label: String,
    /// Total successful hot-swaps since startup (monotone).
    pub swap_total: u64,
}

/// Source of per-worker backend instances (`Arc` so every worker thread
/// can hold it; cf. the coordinator's boxed [`crate::coordinator::Spawner`]).
pub type Spawner = Arc<dyn Fn() -> crate::Result<Box<dyn Backend>> + Send + Sync>;

/// Engine knobs.
#[derive(Clone)]
pub struct ServeConfig {
    /// Worker threads, each with its own backend.
    pub workers: usize,
    /// Micro-batch sample budget; also the chunk size oversized requests
    /// split into (fused mode).
    pub max_batch: usize,
    /// How long an under-full batch may wait for more traffic before a
    /// partial batch is dispatched.
    pub batch_timeout: Duration,
    /// Disable the fused `infer_step` path even when available (testing
    /// and apples-to-apples comparisons).
    pub force_per_request: bool,
    /// Run one throwaway single-sample inference per worker at startup so
    /// weight codes are materialized before the first real request.
    pub warmup: bool,
    /// Deterministic fault plan: seeded worker stalls keyed on request id
    /// (see [`FaultPlan`]); `None` disables injection.
    pub fault: Option<FaultPlan>,
    /// Budget fraction of the startup config, for the epoch-0
    /// [`EpochInfo`] (NaN when not frontier-derived).
    pub initial_budget: f64,
    /// Label of the startup config ("startup" by default).
    pub initial_label: String,
    /// Span recorder (`None` = tracing disabled; the only cost then is
    /// this one `Option` check at admission).  See
    /// [`crate::serve::trace`].
    pub trace: Option<Arc<TraceSink>>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: crate::coordinator::default_workers(),
            max_batch: 32,
            batch_timeout: Duration::from_millis(1),
            force_per_request: false,
            warmup: true,
            fault: None,
            initial_budget: f64::NAN,
            initial_label: "startup".to_string(),
            trace: None,
        }
    }
}

/// State shared between the submit path and the worker threads.  The
/// serving config itself lives in the queue's active [`EpochState`] (one
/// lock orders admission and swaps); this struct carries only the
/// epoch-invariant model contract.
struct Shared {
    q: Mutex<BatchQueue>,
    cv: Condvar,
    metrics: Arc<Metrics>,
    fused: bool,
    /// Per-sample x dims (manifest eval shape minus the batch dim).
    sample_dims: Vec<usize>,
    x_dtype: DType,
    y_dtype: DType,
    /// Deterministic worker-stall injection (tests and smoke drills).
    fault: Option<FaultPlan>,
    /// Successful hot-swaps since startup (monotone, for `/metrics`).
    swap_total: AtomicU64,
    /// Span recorder; `None` disables every tracing hook.
    trace: Option<Arc<TraceSink>>,
}

/// A running serving engine.  `submit` is thread-safe; [`Engine::drain`]
/// stops intake, finishes all queued work, and joins the workers.
pub struct Engine {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Kept for [`Engine::swap`]: a fresh probe backend validates and
    /// materializes each candidate config off the hot path.
    spawner: Spawner,
}

impl Engine {
    /// Validate the model contract, pick the execution mode, and spawn
    /// the worker pool.  `ckpt` is the checkpoint to serve and `bits`
    /// the per-layer precision vector (`BitsConfig::to_f32`).
    pub fn start(
        spawner: Spawner,
        ckpt: Checkpoint,
        bits: Vec<f32>,
        cfg: ServeConfig,
    ) -> crate::Result<Engine> {
        crate::ensure!(cfg.workers >= 1, "serve: --workers must be at least 1");
        crate::ensure!(cfg.max_batch >= 1, "serve: --max-batch must be at least 1");
        // Probe one backend for the model contract, then let every worker
        // open its own.  The probe cannot be handed to a worker thread:
        // `Box<dyn Backend>` carries no `Send` bound (PJRT clients must
        // stay on the thread that opened them), so backends are only ever
        // constructed inside their worker.
        let (fused, sample_dims, x_dtype, y_dtype, shared_exec) = {
            let mut probe = spawner()?;
            let m = probe.manifest();
            // Fused batching needs per-sample logits (infer_step), the
            // classification reassembly semantics, and f32 inputs (the
            // chunk concatenation copies f32 rows); anything else takes
            // the per-request eval_step path.
            let fused = !cfg.force_per_request
                && m.task == Task::Cls
                && m.x_dtype == DType::F32
                && m.entries.contains_key("infer_step");
            let dims = m.x_eval_shape.get(1..).unwrap_or(&[]).to_vec();
            crate::ensure!(
                !dims.is_empty(),
                "serve: model '{}' manifest has no eval input shape",
                m.model
            );
            let (x_dtype, y_dtype) = (m.x_dtype, m.y_dtype);
            // Validate the config against the contract and materialize
            // any shareable execution state (e.g. packed weight codes)
            // once, on the probe, before the workers spawn.
            let shared_exec = materialize(&mut probe, &ckpt, &bits)?;
            (fused, dims, x_dtype, y_dtype, shared_exec)
        };
        let epoch0 = Arc::new(EpochState {
            epoch: 0,
            ckpt,
            bits,
            shared_exec,
            budget_frac: cfg.initial_budget,
            label: cfg.initial_label.clone(),
        });
        let shared = Arc::new(Shared {
            q: Mutex::new(BatchQueue::new(cfg.max_batch, cfg.batch_timeout, epoch0)),
            cv: Condvar::new(),
            metrics: Arc::new(Metrics::new()),
            fused,
            sample_dims,
            x_dtype,
            y_dtype,
            fault: cfg.fault,
            swap_total: AtomicU64::new(0),
            trace: cfg.trace.clone(),
        });
        let mut handles = Vec::with_capacity(cfg.workers);
        for wi in 0..cfg.workers {
            let sh = Arc::clone(&shared);
            let sp = Arc::clone(&spawner);
            let warmup = cfg.warmup;
            let handle = std::thread::Builder::new()
                .name(format!("mpq-serve-{wi}"))
                .spawn(move || worker_loop(sh, sp, warmup))?;
            handles.push(handle);
        }
        Ok(Engine { shared, handles, spawner })
    }

    /// Atomically replace the serving config: validate `(ckpt, bits)`
    /// against the model contract and materialize its execution state on
    /// a fresh probe backend **off the hot path**, then publish the new
    /// [`EpochState`] under the queue lock.  Requests admitted before the
    /// publish finish on the config that admitted them; requests admitted
    /// after are served by the new one.  Any validation or
    /// materialization failure — and a swap during drain — fails closed:
    /// the old config stays live and the error is returned.
    ///
    /// Returns the new serving epoch.
    pub fn swap(
        &self,
        ckpt: Checkpoint,
        bits: Vec<f32>,
        budget_frac: f64,
        label: &str,
    ) -> crate::Result<u64> {
        // Materialization happens before the lock is taken: the hot path
        // never waits on packing, and a failure here leaves the active
        // epoch untouched.
        let shared_exec = {
            let mut probe = (self.spawner)()?;
            materialize(&mut probe, &ckpt, &bits)?
        };
        let epoch = {
            let mut q = self.shared.q.lock().unwrap();
            // Draining and swapping must have a defined order: drain
            // flushes deadline-parked batches on the config that admitted
            // them, so a swap arriving after intake closed is rejected
            // rather than published into a queue nothing will ever be
            // admitted to again.
            crate::ensure!(!q.draining, "serve: engine is draining — swap rejected");
            if let Some(f) = &q.fatal {
                crate::bail!("serve: engine failed: {f}");
            }
            let epoch = q.active.epoch + 1;
            q.active = Arc::new(EpochState {
                epoch,
                ckpt,
                bits,
                shared_exec,
                budget_frac,
                label: label.to_string(),
            });
            self.shared.swap_total.fetch_add(1, Ordering::Relaxed); // relaxed-ok: incremented under the queue mutex; the lock provides ordering
            epoch
        };
        // Wake parked workers so an under-full pre-swap batch is not the
        // only thing standing between the new config and first traffic.
        self.shared.cv.notify_all();
        Ok(epoch)
    }

    /// The serving epoch new submissions are currently admitted under.
    pub fn current_epoch(&self) -> u64 {
        self.shared.q.lock().unwrap().active.epoch
    }

    /// Epoch facts for `/metrics` and operator output.
    pub fn epoch_info(&self) -> EpochInfo {
        let q = self.shared.q.lock().unwrap();
        EpochInfo {
            epoch: q.active.epoch,
            budget_frac: q.active.budget_frac,
            label: q.active.label.clone(),
            swap_total: self.shared.swap_total.load(Ordering::Relaxed), // relaxed-ok: read under the queue mutex; see the swap_total increment
        }
    }

    /// Raw latency-histogram bucket counts (cumulative since startup) —
    /// the controller diffs successive snapshots for windowed quantiles.
    pub fn latency_buckets(&self) -> Vec<u64> {
        self.shared.metrics.latency_buckets()
    }

    /// Whether the fused `infer_step` batching path is active.
    pub fn fused(&self) -> bool {
        self.shared.fused
    }

    /// The span recorder, when tracing is enabled.
    pub fn trace(&self) -> Option<&Arc<TraceSink>> {
        self.shared.trace.as_ref()
    }

    /// Submit one request (`x`: `[samples, <per-sample dims>]`, `y`:
    /// matching labels).  Returns a [`Ticket`] whose id is strictly
    /// increasing in submission order.
    pub fn submit(&self, x: Tensor, y: Tensor) -> crate::Result<Ticket> {
        let samples = x.shape.first().copied().unwrap_or(0);
        crate::ensure!(samples > 0, "serve: request must contain at least one sample");
        crate::ensure!(
            x.shape.len() == self.shared.sample_dims.len() + 1
                && x.shape[1..] == self.shared.sample_dims[..],
            "serve: request x shape {:?} does not match per-sample dims {:?}",
            x.shape,
            self.shared.sample_dims
        );
        crate::ensure!(
            x.dtype() == self.shared.x_dtype,
            "serve: request x dtype {:?} does not match the model's {:?}",
            x.dtype(),
            self.shared.x_dtype
        );
        crate::ensure!(
            y.shape.first().copied().unwrap_or(0) == samples,
            "serve: y covers {} sample(s) but x has {}",
            y.shape.first().copied().unwrap_or(0),
            samples
        );
        // Reject label buffers a backend (or the fused softmax) would
        // choke on — a panic inside a worker thread would strand the
        // ticket forever, so labels must be validated at the door.
        crate::ensure!(
            y.dtype() == self.shared.y_dtype,
            "serve: request y dtype {:?} does not match the model's {:?}",
            y.dtype(),
            self.shared.y_dtype
        );
        if self.shared.fused {
            crate::ensure!(
                y.shape.len() == 1,
                "serve: classification labels must be rank-1 [samples], got shape {:?}",
                y.shape
            );
        }
        // Admission-span start (sink presence is the one check tracing
        // costs on the disabled path).
        let t_sub = self.shared.trace.as_ref().map(|s| s.now_ns());
        let ticket = {
            let mut q = self.shared.q.lock().unwrap();
            crate::ensure!(!q.draining, "serve: engine is draining — submission rejected");
            if let Some(f) = &q.fatal {
                crate::bail!("serve: engine failed: {f}");
            }
            let id = q.alloc_id();
            let total_chunks = q.chunks_for(samples, self.shared.fused);
            // Sampling is a pure function of the id (`id % N == 0`), so
            // the traced set is identical across reruns.
            let trace = self.shared.trace.as_ref().and_then(|s| s.begin(id));
            let pending = Arc::new(Pending::new(
                id,
                x,
                y,
                samples,
                total_chunks,
                Arc::clone(&q.active),
                Arc::clone(&self.shared.metrics),
                trace,
            ));
            let ticket = pending.ticket();
            // Admission closes (and queue-wait opens) *before* the
            // enqueue makes the chunk claimable — a worker may record
            // queue_wait the instant the lock drops.
            if let Some(rt) = &pending.trace {
                let t1 = rt.now_ns();
                rt.span(Stage::Admission, pending.epoch(), t_sub.unwrap_or(t1), t1);
                rt.set_admitted(t1, pending.epoch());
            }
            q.enqueue(&pending, self.shared.fused);
            self.shared.metrics.record_submitted();
            ticket
        };
        // Wake every idle worker: a multi-chunk request can fan out
        // across several of them at once.
        self.shared.cv.notify_all();
        Ok(ticket)
    }

    /// Point-in-time metrics (exact after [`drain`](Engine::drain)).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Samples queued and not yet claimed by a worker — the queue-depth
    /// gauge exposed on `/metrics`.
    pub fn queued_samples(&self) -> usize {
        self.shared.q.lock().unwrap().queued_samples()
    }

    /// Close intake without joining the workers: new submissions and
    /// swaps are rejected from this point on, queued work still flushes.
    /// [`Engine::drain`] calls this first; exposed separately so tests
    /// can pin the drain/swap ordering without racing a full join.
    pub fn begin_drain(&self) {
        {
            self.shared.q.lock().unwrap().draining = true;
        }
        self.shared.cv.notify_all();
    }

    /// Graceful shutdown: reject new submissions, flush every queued
    /// batch (ignoring the batch timeout), join the workers, and verify
    /// nothing was left unresolved.
    pub fn drain(mut self) -> crate::Result<MetricsSnapshot> {
        self.begin_drain();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        {
            let q = self.shared.q.lock().unwrap();
            if let Some(f) = &q.fatal {
                crate::bail!("serve: engine failed before drain completed: {f}");
            }
            crate::ensure!(q.is_empty(), "serve: drain left work queued");
        }
        let snap = self.shared.metrics.snapshot();
        crate::ensure!(
            snap.submitted == snap.completed + snap.failed,
            "serve: drain left {} request(s) unresolved",
            snap.submitted - snap.completed - snap.failed
        );
        Ok(snap)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if self.handles.is_empty() {
            return; // already drained
        }
        {
            self.shared.q.lock().unwrap().draining = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Record an unrecoverable engine error: fail everything queued and
/// reject all future submissions.
fn fatal(sh: &Shared, msg: &str) {
    crate::warn!("serve: fatal: {msg}");
    let jobs = {
        let mut q = sh.q.lock().unwrap();
        q.fatal = Some(msg.to_string());
        q.drain_all()
    };
    for j in &jobs {
        j.pending.fail(msg);
    }
    sh.cv.notify_all();
}

fn worker_loop(sh: Arc<Shared>, spawner: Spawner, warmup: bool) {
    let mut be = match spawner() {
        Ok(b) => b,
        Err(e) => {
            fatal(&sh, &format!("worker backend open failed: {e}"));
            return;
        }
    };
    // Adopt the startup epoch's shared execution state (e.g. packed
    // weight codes) before any request: the expensive per-layer
    // materialization happened exactly once, on the probe that validated
    // the config.
    let ep0 = Arc::clone(&sh.q.lock().unwrap().active);
    if let Some(h) = &ep0.shared_exec {
        if let Err(e) = be.adopt_shared(h) {
            fatal(&sh, &format!("worker failed to adopt shared state: {e}"));
            return;
        }
    }
    let mut adopted = ep0.epoch;
    if warmup {
        warmup_backend(&sh, &ep0, &mut be);
    }
    drop(ep0);
    let mut guard = sh.q.lock().unwrap();
    loop {
        if guard.fatal.is_some() {
            return;
        }
        match guard.next_batch(Instant::now()) {
            NextBatch::Ready(batch) => {
                drop(guard);
                // Queue-wait closes at claim time, per chunk (a
                // multi-chunk request gets one span per chunk, all
                // starting at its admission end).
                if let Some(sink) = &sh.trace {
                    let t_claim = sink.now_ns();
                    for c in &batch {
                        if let Some(rt) = &c.pending.trace {
                            rt.span(
                                Stage::QueueWait,
                                c.pending.epoch(),
                                rt.admitted_ns(),
                                t_claim,
                            );
                        }
                    }
                }
                sh.metrics.record_batch(
                    batch.len() as u64,
                    batch.iter().map(|c| c.len as u64).sum(),
                );
                // Batches are epoch-pure (see `BatchQueue::next_batch`);
                // when this one's admission epoch differs from the last
                // adopted, re-point the backend at that epoch's shared
                // state before executing.
                let ep = Arc::clone(&batch[0].pending.epoch_state);
                if ep.epoch != adopted {
                    if let Some(h) = &ep.shared_exec {
                        if let Err(e) = be.adopt_shared(h) {
                            fatal(
                                &sh,
                                &format!("worker failed to adopt epoch {}: {e}", ep.epoch),
                            );
                            return;
                        }
                    }
                    adopted = ep.epoch;
                }
                if let Some(fp) = &sh.fault {
                    // Injected stall: the worker sleeps while holding the
                    // batch (not the lock) — queued traffic behind it
                    // piles up exactly as a real straggler would cause.
                    let stall = batch
                        .iter()
                        .map(|c| fp.stall_wall_for(c.pending.id))
                        .max()
                        .unwrap_or(Duration::ZERO);
                    if stall > Duration::ZERO {
                        std::thread::sleep(stall);
                    }
                }
                execute_batch(&sh, &ep, &mut be, &batch);
                guard = sh.q.lock().unwrap();
            }
            NextBatch::Wait(deadline) => {
                let dur = deadline.saturating_duration_since(Instant::now());
                let (g, _) = sh.cv.wait_timeout(guard, dur).unwrap();
                guard = g;
            }
            NextBatch::Idle => {
                if guard.draining {
                    return;
                }
                guard = sh.cv.wait(guard).unwrap();
            }
        }
    }
}

/// Validate `(ckpt, bits)` against the probe's model contract and
/// materialize any shareable execution state — the fail-closed gate both
/// [`Engine::start`] and [`Engine::swap`] pass a config through before
/// it can be published.
fn materialize(
    probe: &mut Box<dyn Backend>,
    ckpt: &Checkpoint,
    bits: &[f32],
) -> crate::Result<Option<SharedExecState>> {
    let m = probe.manifest();
    crate::ensure!(
        bits.len() == m.n_bits,
        "serve: bits vector has {} entries, model '{}' expects {}",
        bits.len(),
        m.model,
        m.n_bits
    );
    crate::ensure!(
        ckpt.names.len() == m.n_params(),
        "serve: checkpoint has {} tensors, model '{}' expects {}",
        ckpt.names.len(),
        m.model,
        m.n_params()
    );
    probe.prepare_shared(ckpt, bits)
}

/// Best-effort single-sample inference so the worker's weight-code cache
/// is populated before real traffic (results are identical either way —
/// the caches are semantically transparent).
fn warmup_backend(sh: &Shared, ep: &EpochState, be: &mut Box<dyn Backend>) {
    let mut shape = vec![1usize];
    shape.extend_from_slice(&sh.sample_dims);
    let x = match sh.x_dtype {
        DType::F32 => Tensor::zeros(&shape),
        DType::I32 => Tensor::zeros_i32(&shape),
    };
    if sh.fused {
        let _ = be.infer_step(&ep.ckpt, &x, &ep.bits);
    } else {
        let y = Tensor::zeros_i32(&[1]);
        let _ = be.eval_step(&ep.ckpt, &x, &y, &ep.bits);
    }
}

fn execute_batch(sh: &Shared, ep: &EpochState, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    if sh.fused {
        execute_fused(sh, ep, be, batch);
    } else {
        execute_per_request(sh, ep, be, batch);
    }
}

/// Fused mode: one forward pass over the concatenated chunk samples,
/// then per-request reassembly (row-independent kernels make the logits
/// independent of batch composition — see [`super::batcher`]).
fn execute_fused(sh: &Shared, ep: &EpochState, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    // Trace hooks fire only when the batch carries at least one sampled
    // request; the assembly window and the per-layer GEMM timings are
    // shared batch costs, attributed to each traced rider.
    let sink = sh
        .trace
        .as_ref()
        .filter(|_| batch.iter().any(|c| c.pending.trace.is_some()));
    let t_asm0 = sink.map(|s| s.now_ns());
    let row: usize = sh.sample_dims.iter().product();
    let total: usize = batch.iter().map(|c| c.len).sum();
    let mut buf = Vec::with_capacity(total * row);
    for c in batch {
        let xs = c.pending.x.f32s();
        buf.extend_from_slice(&xs[c.offset * row..(c.offset + c.len) * row]);
    }
    let mut shape = vec![total];
    shape.extend_from_slice(&sh.sample_dims);
    let x = Tensor::from_f32(&shape, buf);
    if let (Some(s), Some(t0)) = (sink, t_asm0) {
        let t1 = s.now_ns();
        for c in batch {
            if let Some(rt) = &c.pending.trace {
                rt.span(Stage::BatchAssembly, ep.epoch, t0, t1);
            }
        }
    }
    // Per-layer GEMM capture: the forward runs layers in order on this
    // thread, so the nth timing is layer n (see `kernels::ltrace`).
    let gemm_base = sink.map(|s| {
        crate::kernels::ltrace::begin();
        s.now_ns()
    });
    let result = be.infer_step(&ep.ckpt, &x, &ep.bits);
    if let Some(base) = gemm_base {
        for t in crate::kernels::ltrace::take() {
            for c in batch {
                if let Some(rt) = &c.pending.trace {
                    rt.record(
                        Stage::LayerGemm,
                        ep.epoch,
                        t.seq as i32,
                        t.bits,
                        t.variant,
                        base + t.t_start_ns,
                        base + t.t_end_ns,
                    );
                }
            }
        }
    }
    match result {
        Ok(logits) => {
            let classes = logits.shape.get(1).copied().unwrap_or(1);
            let ls = logits.f32s();
            // A backend returning a wrong-sized logit tensor would panic
            // the per-chunk slices below on this worker thread, stranding
            // every ticket in the batch — fail them cleanly instead.
            if ls.len() != total * classes {
                let msg = format!(
                    "infer_step returned {} logit value(s) for {total} sample(s) x \
                     {classes} class(es)",
                    ls.len()
                );
                for c in batch {
                    c.pending.fail(&msg);
                }
                return;
            }
            let mut off = 0usize;
            for c in batch {
                c.pending.complete_chunk(
                    c.offset,
                    c.len,
                    classes,
                    &ls[off * classes..(off + c.len) * classes],
                );
                off += c.len;
            }
        }
        Err(e) => {
            let msg = format!("infer_step failed: {e}");
            for c in batch {
                c.pending.fail(&msg);
            }
        }
    }
}

/// Fallback mode: each chunk is a whole request; the worker's `eval_step`
/// call *is* the reference computation.  Traced requests get queue-wait
/// and per-layer GEMM spans only — `eval_step` computes its softmax
/// internally, so there is no separate assembly/reassembly/epilogue
/// window to attribute (fused mode is the fully-staged path).
fn execute_per_request(sh: &Shared, ep: &EpochState, be: &mut Box<dyn Backend>, batch: &[ChunkJob]) {
    for c in batch {
        let gemm_base = match (&sh.trace, &c.pending.trace) {
            (Some(s), Some(_)) => {
                crate::kernels::ltrace::begin();
                Some(s.now_ns())
            }
            _ => None,
        };
        let result = be.eval_step(&ep.ckpt, &c.pending.x, &c.pending.y, &ep.bits);
        if let (Some(base), Some(rt)) = (gemm_base, &c.pending.trace) {
            for t in crate::kernels::ltrace::take() {
                rt.record(
                    Stage::LayerGemm,
                    ep.epoch,
                    t.seq as i32,
                    t.bits,
                    t.variant,
                    base + t.t_start_ns,
                    base + t.t_end_ns,
                );
            }
        }
        match result {
            Ok((loss, evalout)) => c.pending.complete_whole(loss, evalout),
            Err(e) => c.pending.fail(&format!("eval_step failed: {e}")),
        }
    }
}
