//! Lazy JSON field scanner for the `/infer` request body.
//!
//! The hot ingest path needs two integers out of a tiny JSON object; a
//! full tree parse ([`crate::jsonio::parse`]) allocates a `BTreeMap` plus
//! a `String` per key for values we immediately discard.  Following the
//! mik-sdk ADR-002 idiom (SNIPPETS.md Snippet 3), this module scans the
//! raw bytes for the requested *top-level* field and parses only its
//! value, skipping everything else token by token — nested objects,
//! arrays, and escaped strings are stepped over without materializing
//! anything.
//!
//! Agreement contract: for any body the full parser accepts, a scan
//! returns exactly the value `jsonio::parse(body).get(field)` holds —
//! same unescaping (including `\uXXXX`), same number grammar (the token
//! is handed to the identical `f64` parse).  The property test below
//! generates bodies with escapes, nested objects, and field-order
//! permutations and checks the two against each other.
//!
//! Laziness caveat (by design): the scan stops as soon as the requested
//! field's value is parsed, so garbage *after* that point in the body
//! goes undetected.  The server treats scan errors as a 400; documents
//! that are broken only beyond the needed fields are accepted — the
//! fields themselves are still exactly what the full parser would have
//! produced.  A nested occurrence of the field name never matches: only
//! top-level keys are compared.

/// Scan `body` for top-level `field` and parse its value as a number.
/// `Ok(None)` = well-formed prefix but no such field.
pub fn scan_f64(body: &[u8], field: &str) -> crate::Result<Option<f64>> {
    match scan_field(body, field)? {
        None => Ok(None),
        Some(mut s) => s.number().map(Some),
    }
}

/// [`scan_f64`] restricted to non-negative integers that fit exactly in
/// an f64 (so the value round-trips identically through the full parser's
/// f64 representation).
pub fn scan_u64(body: &[u8], field: &str) -> crate::Result<Option<u64>> {
    let Some(v) = scan_f64(body, field)? else {
        return Ok(None);
    };
    crate::ensure!(
        v >= 0.0 && v.fract() == 0.0 && v <= 9e15,
        "field '{field}' must be a non-negative integer, got {v}"
    );
    Ok(Some(v as u64))
}

/// Scan `body` for top-level `field` and parse its value as a string
/// (full unescaping, identical to the tree parser's).
pub fn scan_str(body: &[u8], field: &str) -> crate::Result<Option<String>> {
    match scan_field(body, field)? {
        None => Ok(None),
        Some(mut s) => s.string().map(Some),
    }
}

/// Walk the top-level object until `field` is found; the returned scanner
/// is positioned at the start of its value.
fn scan_field<'a>(body: &'a [u8], field: &str) -> crate::Result<Option<Scan<'a>>> {
    let mut s = Scan { b: body, i: 0 };
    s.ws();
    s.expect(b'{')?;
    s.ws();
    if s.peek() == Some(b'}') {
        return Ok(None);
    }
    loop {
        s.ws();
        let key = s.string()?;
        s.ws();
        s.expect(b':')?;
        s.ws();
        if key == field {
            return Ok(Some(s));
        }
        s.skip_value()?;
        s.ws();
        match s.bump()? {
            b',' => continue,
            b'}' => return Ok(None),
            c => crate::bail!("lazyjson: expected ',' or '}}', got '{}'", c as char),
        }
    }
}

struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> crate::Result<u8> {
        let c = self
            .peek()
            .ok_or_else(|| crate::err!("lazyjson: unexpected end of body"))?;
        self.i += 1;
        Ok(c)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, want: u8) -> crate::Result<()> {
        let got = self.bump()?;
        crate::ensure!(
            got == want,
            "lazyjson: expected '{}', got '{}'",
            want as char,
            got as char
        );
        Ok(())
    }

    /// Parse a string token with the exact unescaping semantics of
    /// [`crate::jsonio`]'s parser (incl. BMP `\u` escapes; invalid code
    /// points become U+FFFD, matching it).
    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| crate::err!("lazyjson: bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => crate::bail!("lazyjson: bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    crate::ensure!(
                        start + len <= self.b.len(),
                        "lazyjson: truncated UTF-8 sequence"
                    );
                    self.i = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| crate::err!("lazyjson: invalid UTF-8 in string"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    /// Parse a number token with the identical grammar + `f64` parse the
    /// tree parser uses, so the two can never disagree on a value.
    fn number(&mut self) -> crate::Result<f64> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| crate::err!("lazyjson: bad number token"))?;
        text.parse::<f64>()
            .map_err(|e| crate::err!("lazyjson: bad number '{text}': {e}"))
    }

    /// Step over one value of any type without materializing it.
    fn skip_value(&mut self) -> crate::Result<()> {
        self.ws();
        match self.peek() {
            Some(b'"') => {
                self.skip_string()?;
            }
            Some(b'{') | Some(b'[') => {
                // Depth-walk: strings inside may contain brackets, so they
                // are skipped with full escape awareness.
                let mut depth = 0usize;
                loop {
                    match self.peek() {
                        Some(b'"') => {
                            self.skip_string()?;
                        }
                        Some(b'{') | Some(b'[') => {
                            depth += 1;
                            self.i += 1;
                        }
                        Some(b'}') | Some(b']') => {
                            depth -= 1;
                            self.i += 1;
                            if depth == 0 {
                                return Ok(());
                            }
                        }
                        Some(_) => self.i += 1,
                        None => crate::bail!("lazyjson: unterminated container"),
                    }
                }
            }
            Some(b't') => self.lit("true")?,
            Some(b'f') => self.lit("false")?,
            Some(b'n') => self.lit("null")?,
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                self.number()?;
            }
            other => crate::bail!("lazyjson: unexpected {other:?} where a value was expected"),
        }
        Ok(())
    }

    fn lit(&mut self, word: &str) -> crate::Result<()> {
        crate::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "lazyjson: bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(())
    }

    /// Skip a string token (escape-aware, no allocation).
    fn skip_string(&mut self) -> crate::Result<()> {
        self.expect(b'"')?;
        loop {
            match self.bump()? {
                b'"' => return Ok(()),
                b'\\' => {
                    self.bump()?;
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::{self, Json};
    use crate::prop;
    use crate::rng::Pcg32;

    #[test]
    fn finds_fields_regardless_of_position_and_whitespace() {
        let body = b" { \"samples\" : 3 ,\n\t\"index\": 42 } ";
        assert_eq!(scan_u64(body, "index").unwrap(), Some(42));
        assert_eq!(scan_u64(body, "samples").unwrap(), Some(3));
        assert_eq!(scan_u64(body, "missing").unwrap(), None);
        assert_eq!(scan_u64(b"{}", "index").unwrap(), None);
    }

    #[test]
    fn nested_occurrences_of_the_field_name_do_not_match() {
        let body = br#"{"meta":{"index":999,"deep":{"samples":[1,2]}},"index":7,"samples":2}"#;
        assert_eq!(scan_u64(body, "index").unwrap(), Some(7));
        assert_eq!(scan_u64(body, "samples").unwrap(), Some(2));
    }

    #[test]
    fn skips_strings_containing_braces_and_escapes() {
        let body = br#"{"note":"a \"}{\" [ brace soup \\","index":5}"#;
        assert_eq!(scan_u64(body, "index").unwrap(), Some(5));
        assert_eq!(
            scan_str(body, "note").unwrap().unwrap(),
            "a \"}{\" [ brace soup \\"
        );
    }

    #[test]
    fn unicode_escapes_match_the_tree_parser() {
        let body = "{\"name\":\"caf\\u00e9 \\n \\u2603\",\"index\":1}";
        let lazy = scan_str(body.as_bytes(), "name").unwrap().unwrap();
        let tree = jsonio::parse(body).unwrap();
        assert_eq!(Some(lazy.as_str()), tree.at(&["name"]).as_str());
        assert_eq!(lazy, "café \n ☃");
    }

    #[test]
    fn rejects_malformed_bodies_and_wrong_types() {
        assert!(scan_u64(b"", "index").is_err());
        assert!(scan_u64(b"[1,2]", "index").is_err());
        assert!(scan_u64(b"{\"index\" 7}", "index").is_err());
        assert!(scan_u64(b"{\"index\":", "index").is_err());
        assert!(scan_u64(br#"{"index":"seven"}"#, "index").is_err());
        assert!(scan_u64(br#"{"index":-3}"#, "index").is_err());
        assert!(scan_u64(br#"{"index":2.5}"#, "index").is_err());
    }

    // -- property: agreement with the full jsonio parser -------------------

    /// Random JSON value (depth-bounded); keys drawn from a pool that
    /// exercises escapes and non-ASCII.
    fn gen_value(rng: &mut Pcg32, depth: usize) -> Json {
        let roll = rng.below(if depth == 0 { 5 } else { 7 });
        match roll {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::num(match rng.below(4) {
                0 => rng.below(1_000_000) as f64,
                1 => -(rng.below(1000) as f64),
                2 => rng.uniform() as f64 * 1e3,
                _ => (rng.below(100) as f64) / 8.0,
            }),
            3 | 4 => Json::Str(gen_string(rng)),
            5 => Json::arr((0..rng.below(4)).map(|_| gen_value(rng, depth - 1))),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|k| (format!("k{k}_{}", rng.below(10)), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    fn gen_string(rng: &mut Pcg32) -> String {
        const POOL: &[&str] = &["a", "β", "\\", "\"", "\n", "\t", "}", "{", "[", ":", "é", "☃"];
        (0..rng.below(8))
            .map(|_| POOL[rng.below(POOL.len() as u32) as usize])
            .collect()
    }

    /// Serialize pairs in the given order with random whitespace — the
    /// tree emitter would sort keys, and the whole point is to check the
    /// scanner against arbitrary field orderings and layouts.
    fn emit(rng: &mut Pcg32, pairs: &[(String, Json)]) -> String {
        const WS: &[&str] = &["", " ", "\n", "\t", "  "];
        let ws = |rng: &mut Pcg32| WS[rng.below(WS.len() as u32) as usize];
        let mut out = String::from("{");
        for (i, (k, v)) in pairs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out += ws(rng);
            out += &Json::Str(k.clone()).to_string_compact();
            out += ws(rng);
            out.push(':');
            out += ws(rng);
            out += &v.to_string_compact();
            out += ws(rng);
        }
        out.push('}');
        out
    }

    #[test]
    fn lazy_scan_agrees_with_the_full_parser_on_generated_bodies() {
        prop::forall(
            &prop::Config { cases: 300, seed: 0x1a2b },
            |rng| {
                // The fields the server actually scans, plus decoys with
                // hostile names/values, in shuffled order.
                let mut pairs: Vec<(String, Json)> = vec![
                    ("index".into(), Json::num(rng.below(1_000_000) as f64)),
                    ("samples".into(), Json::num(rng.below(1024) as f64)),
                    ("tag".into(), Json::Str(gen_string(rng))),
                ];
                for d in 0..rng.below(4) {
                    pairs.push((format!("decoy{d}_{}", gen_string(rng)), gen_value(rng, 2)));
                }
                rng.shuffle(&mut pairs);
                // Duplicate keys would make "which occurrence wins"
                // implementation-defined in both parsers; keep keys unique.
                let mut seen = std::collections::BTreeSet::new();
                pairs.retain(|(k, _)| seen.insert(k.clone()));
                emit(rng, &pairs)
            },
            |body| {
                let tree = jsonio::parse(body).map_err(|e| format!("emitter produced invalid JSON: {e}"))?;
                for field in ["index", "samples"] {
                    let lazy = scan_f64(body.as_bytes(), field)
                        .map_err(|e| format!("scan_f64({field}): {e}"))?;
                    let full = tree.at(&[field]).as_f64();
                    if lazy.map(f64::to_bits) != full.map(f64::to_bits) {
                        return Err(format!("{field}: lazy {lazy:?} != tree {full:?}"));
                    }
                }
                let lazy = scan_str(body.as_bytes(), "tag")
                    .map_err(|e| format!("scan_str(tag): {e}"))?;
                if lazy.as_deref() != tree.at(&["tag"]).as_str() {
                    return Err(format!(
                        "tag: lazy {lazy:?} != tree {:?}",
                        tree.at(&["tag"]).as_str()
                    ));
                }
                if scan_f64(body.as_bytes(), "no_such_field")
                    .map_err(|e| e.to_string())?
                    .is_some()
                {
                    return Err("absent field reported present".into());
                }
                Ok(())
            },
        );
    }
}
