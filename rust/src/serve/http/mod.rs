//! HTTP/1.1 front door for the serving engine (`mpq serve --listen`).
//!
//! Pure std networking — `TcpListener` + the same thread substrate the
//! engine already uses; zero new dependencies.  One acceptor thread hands
//! each connection to its own handler thread, which feeds the existing
//! batching [`Engine`] and writes responses back in request order:
//!
//! ```text
//! TcpListener ── acceptor ──> conn thread: RequestParser (incremental)
//!                                  │  lazy JSON scan: {"index","samples"}
//!                                  │  admission gate ──> Engine::submit
//!                                  └─ FIFO reply queue ──> socket (in order)
//! ```
//!
//! ## Endpoints
//!
//! * `POST /infer` — body `{"index": I, "samples": N}`.  The server
//!   materializes the request's `(x, y)` from its own [`Dataset`] at
//!   eval-split index `I` with `N` samples — the same deterministic
//!   tensors the in-process loadgen builds, which is what makes socket
//!   responses bit-comparable to in-process runs.  `200` body carries the
//!   response with **exact** f32 transport: `loss_bits`/`evalout_bits`
//!   are `f32::to_bits` values as JSON numbers (u32 < 2⁵³, so the f64
//!   JSON number representation is lossless).
//! * `GET /metrics` — stable text rendering of the engine's lock-free
//!   latency histogram (p50/p95/p99), throughput, batch occupancy, and
//!   the front door's admission counters.  Field names and order are
//!   pinned by a golden test; lines are only ever appended.
//! * `GET /trace` — Chrome trace-event JSON of the retained sampled
//!   request spans (see [`super::trace`]); 503 when the server was
//!   started without tracing.
//! * `GET /healthz` — liveness probe, `200 ok`.
//! * `POST /swap` — body `{"level": L}`; atomically hot-swaps the
//!   engine onto frontier level `L` from the server's [`SwapRegistry`]
//!   (503 when the server was started without one).  `200` body carries
//!   the new serving epoch, level, and budget.  In-flight requests
//!   finish on the config that admitted them; every `/infer` response
//!   is tagged with its serving `epoch`.
//!
//! ## Status codes (the full contract)
//!
//! | status | meaning                                      | connection |
//! |--------|----------------------------------------------|------------|
//! | 200    | success                                      | keep-alive |
//! | 400    | malformed request line/header/Content-Length | close      |
//! | 400    | well-framed request, bad JSON body/fields    | keep-alive |
//! | 404    | unknown path                                 | keep-alive |
//! | 405    | known path, wrong method                     | keep-alive |
//! | 413    | body over `max_body_bytes`                   | close      |
//! | 431    | headers over `max_header_bytes`              | close      |
//! | 500    | engine failed the request                    | keep-alive |
//! | 503    | admission queue full / engine unavailable    | keep-alive* |
//! | 501    | Transfer-Encoding unsupported                | close      |
//! | 505    | HTTP version not 1.0/1.1                     | close      |
//!
//! (*queue-full 503 keeps the connection; engine-unavailable 503 closes.
//! Every 503 carries `Retry-After`.)  Protocol-level errors close because
//! the byte stream is no longer trustworthy; application-level errors
//! keep the connection because the request was correctly framed.
//!
//! ## Backpressure and admission control
//!
//! Two bounds, both fail-fast rather than buffering unboundedly:
//!
//! * **global admission gate** — at most [`HttpConfig::queue_capacity`]
//!   requests admitted (submitted to the engine, response not yet
//!   written); beyond it `/infer` answers `503` + `Retry-After`
//!   immediately.  Once admitted, a request is never dropped: the
//!   accounting invariant `admitted == answered + failed + aborted`
//!   (aborted = connection died before its response could be written)
//!   holds after shutdown and is asserted by the tests.
//! * **per-connection in-flight bound** — at most
//!   [`HttpConfig::max_inflight_per_conn`] pipelined requests are parsed
//!   ahead per connection; further buffered requests wait until responses
//!   drain.  Keep-alive serves at most
//!   [`HttpConfig::max_requests_per_conn`] requests, then answers the
//!   last one with `Connection: close`.
//!
//! ## Graceful drain
//!
//! [`HttpServer::shutdown`] stops the acceptor (new connects are
//! refused), lets every connection thread finish writing the responses
//! for all *admitted* requests (engine workers keep running throughout),
//! joins the threads, and only then calls the engine's own
//! [`Engine::drain`] — which flushes anything still queued and asserts
//! nothing was left unresolved.  Connections idle at drain time close
//! after one read-timeout tick; partially-received requests were never
//! admitted and are dropped with the socket.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::{Dataset, Split};
use crate::jsonio::Json;
use crate::tensor::{DType, Tensor};

use super::batcher::{Response, Ticket};
use super::controller::FrontierStep;
use super::engine::Engine;
use super::metrics::{family, MetricsSnapshot};
use super::trace::Stage;

pub mod client;
pub mod lazyjson;
pub mod parser;

use parser::{reason, HttpError, Request, RequestParser};

/// Front-door knobs (the engine has its own [`super::ServeConfig`]).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; port 0 picks a free port (see
    /// [`HttpServer::local_addr`]).
    pub addr: String,
    /// Global admission bound: max requests admitted to the engine with
    /// their response not yet written.  Beyond it `/infer` is 503.
    pub queue_capacity: usize,
    /// Max pipelined requests parsed ahead per connection.
    pub max_inflight_per_conn: usize,
    /// Keep-alive budget: requests served per connection before the
    /// server answers with `Connection: close`.
    pub max_requests_per_conn: usize,
    /// Max concurrent connections; beyond it new connects get an
    /// immediate 503 and are closed.
    pub max_conns: usize,
    pub max_header_bytes: usize,
    pub max_body_bytes: usize,
    /// Upper bound for the `samples` field of `/infer` (guards huge
    /// allocations from a single request).
    pub max_request_samples: usize,
    /// Socket read poll tick — how quickly idle connections notice a
    /// drain.
    pub read_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            queue_capacity: 1024,
            max_inflight_per_conn: 8,
            max_requests_per_conn: 4096,
            max_conns: 128,
            max_header_bytes: 8 * 1024,
            max_body_bytes: 64 * 1024,
            max_request_samples: 1024,
            read_timeout: Duration::from_millis(50),
        }
    }
}

/// Point-in-time front-door counters (exact after
/// [`HttpServer::shutdown`]).
#[derive(Debug, Clone)]
pub struct HttpStatsSnapshot {
    pub connections: u64,
    /// `/infer` requests submitted to the engine.
    pub admitted: u64,
    /// 503s: admission gate full, connection limit, engine unavailable.
    pub rejected: u64,
    /// Admitted requests answered 200.
    pub answered: u64,
    /// Admitted requests answered 500 (engine failed them).
    pub failed: u64,
    /// Admitted requests whose connection died before the response could
    /// be written (the engine still completed them).
    pub aborted: u64,
    /// Non-2xx, non-503 responses: protocol errors, 404/405, bad bodies.
    pub bad_requests: u64,
    pub metrics_scrapes: u64,
    /// Gauge: admitted requests currently awaiting their response.
    pub inflight: u64,
}

/// Lock-free front-door counters (relaxed atomics, like the engine's
/// [`super::metrics::Metrics`]).
#[derive(Default)]
struct HttpStats {
    connections: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    answered: AtomicU64,
    failed: AtomicU64,
    aborted: AtomicU64,
    bad_requests: AtomicU64,
    metrics_scrapes: AtomicU64,
}

macro_rules! bump {
    ($sh:expr, $field:ident) => {
        $sh.stats.$field.fetch_add(1, Ordering::Relaxed) // relaxed-ok: monotone stats counter; snapshot reads tolerate tearing
    };
}

/// The set of pre-materialized frontier configs `POST /swap` may switch
/// between.  Built once at startup (each step carries its own checkpoint
/// + bits), so a swap request never does model prep on the request path.
pub struct SwapRegistry {
    pub steps: Vec<FrontierStep>,
}

/// State shared by the acceptor and every connection thread.
struct HttpShared {
    engine: Arc<Engine>,
    data: Dataset,
    cfg: HttpConfig,
    /// `POST /swap` targets; `None` answers every swap with 503.
    swaps: Option<Arc<SwapRegistry>>,
    stats: HttpStats,
    /// The admission gate: requests admitted, response not yet written.
    inflight: AtomicUsize,
    active_conns: AtomicUsize,
    draining: AtomicBool,
    started: Instant,
}

impl HttpShared {
    /// Try to take one admission permit.
    fn try_admit(&self) -> bool {
        let mut cur = self.inflight.load(Ordering::Relaxed); // relaxed-ok: optimistic first read of a CAS loop; failure path re-reads
        loop {
            if cur >= self.cfg.queue_capacity {
                return false;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed, // relaxed-ok: CAS failure ordering; the retry loop re-reads the current value
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    fn release_permit(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    fn stats_snapshot(&self) -> HttpStatsSnapshot {
        let s = &self.stats;
        HttpStatsSnapshot {
            connections: s.connections.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            admitted: s.admitted.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            rejected: s.rejected.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            answered: s.answered.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            failed: s.failed.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            aborted: s.aborted.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            bad_requests: s.bad_requests.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            metrics_scrapes: s.metrics_scrapes.load(Ordering::Relaxed), // relaxed-ok: stats snapshot; per-field staleness acceptable
            inflight: self.inflight.load(Ordering::Relaxed) as u64, // relaxed-ok: gauge snapshot for reporting only
        }
    }
}

/// A running front door.  Owns the engine for its lifetime;
/// [`HttpServer::shutdown`] drains and returns the final metrics.
pub struct HttpServer {
    shared: Option<Arc<HttpShared>>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `cfg.addr`, take ownership of the (already started) engine,
    /// and start accepting.  `data` must be the dataset the engine's
    /// checkpoint was built against — `/infer` materializes request
    /// tensors from it.
    pub fn start(engine: Engine, data: Dataset, cfg: HttpConfig) -> crate::Result<HttpServer> {
        HttpServer::start_with(engine, data, cfg, None)
    }

    /// [`HttpServer::start`] plus a [`SwapRegistry`] enabling
    /// `POST /swap` between pre-materialized frontier configs.
    pub fn start_with(
        engine: Engine,
        data: Dataset,
        cfg: HttpConfig,
        swaps: Option<Arc<SwapRegistry>>,
    ) -> crate::Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| crate::err!("http: bind {}: {e}", cfg.addr))?;
        // Non-blocking accept so the acceptor can poll the drain flag.
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::err!("http: set_nonblocking: {e}"))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| crate::err!("http: local_addr: {e}"))?;
        let shared = Arc::new(HttpShared {
            engine: Arc::new(engine),
            data,
            cfg,
            swaps,
            stats: HttpStats::default(),
            inflight: AtomicUsize::new(0),
            active_conns: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            started: Instant::now(),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let sh = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("mpq-http-accept".to_string())
                .spawn(move || accept_loop(listener, sh, conns))
                .map_err(|e| crate::err!("http: spawn acceptor: {e}"))?
        };
        Ok(HttpServer {
            shared: Some(shared),
            local_addr,
            acceptor: Some(acceptor),
            conns,
        })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn stats(&self) -> HttpStatsSnapshot {
        self.shared.as_ref().expect("server running").stats_snapshot()
    }

    pub fn engine_metrics(&self) -> MetricsSnapshot {
        self.shared.as_ref().expect("server running").engine.metrics()
    }

    /// A handle to the served engine, for driving swaps from outside the
    /// socket (the SLO controller thread).  The clone MUST be dropped
    /// before [`HttpServer::shutdown`], which asserts sole ownership.
    pub fn engine_handle(&self) -> Arc<Engine> {
        Arc::clone(&self.shared.as_ref().expect("server running").engine)
    }

    /// Signal drain and join the acceptor + every connection thread.
    /// Returns the shared state once this server holds the only
    /// reference.
    fn stop_threads(&mut self) -> Option<Arc<HttpShared>> {
        let shared = self.shared.take()?;
        shared.draining.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        Some(shared)
    }

    /// Graceful drain: stop accepting, finish every admitted request,
    /// close the sockets, then flush the engine via [`Engine::drain`].
    pub fn shutdown(mut self) -> crate::Result<(MetricsSnapshot, HttpStatsSnapshot)> {
        let shared = self
            .stop_threads()
            .ok_or_else(|| crate::err!("http: shutdown called on a stopped server"))?;
        let stats = shared.stats_snapshot();
        let shared = Arc::try_unwrap(shared)
            .map_err(|_| crate::err!("http: internal: shared state still referenced after joins"))?;
        let engine = Arc::try_unwrap(shared.engine)
            .map_err(|_| crate::err!("http: internal: engine still referenced after joins"))?;
        let snap = engine.drain()?;
        crate::ensure!(
            stats.admitted == stats.answered + stats.failed + stats.aborted,
            "http: drain lost accepted work: admitted {} != answered {} + failed {} + aborted {}",
            stats.admitted,
            stats.answered,
            stats.failed,
            stats.aborted
        );
        Ok((snap, stats))
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // Best-effort cleanup when shutdown() was never called (e.g. a
        // panicking test): stop the threads; the engine drains via its
        // own Drop when the last Arc goes.
        let _ = self.stop_threads();
    }
}

fn accept_loop(
    listener: TcpListener,
    sh: Arc<HttpShared>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        if sh.draining.load(Ordering::SeqCst) {
            return; // drops the listener: new connects are refused
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                bump!(sh, connections);
                let _ = stream.set_nonblocking(false);
                if sh.active_conns.load(Ordering::Relaxed) >= sh.cfg.max_conns { // relaxed-ok: advisory connection cap; a racing accept may overshoot by one harmlessly
                    bump!(sh, rejected);
                    let body = error_body("connection limit reached");
                    let _ = write_response(&mut stream, 503, "application/json", &body, true, true);
                    continue;
                }
                sh.active_conns.fetch_add(1, Ordering::Relaxed); // relaxed-ok: connection gauge; guards only the advisory cap above
                let sh2 = Arc::clone(&sh);
                let spawned = std::thread::Builder::new()
                    .name("mpq-http-conn".to_string())
                    .spawn(move || {
                        handle_conn(&sh2, stream);
                        sh2.active_conns.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: connection gauge decrement; thread join is not ordered on it
                    });
                match spawned {
                    Ok(h) => conns.lock().unwrap().push(h),
                    Err(_) => {
                        sh.active_conns.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: connection gauge rollback on spawn failure
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// A response waiting to be written, FIFO per connection so pipelined
/// requests are answered in order.
enum Reply {
    /// An admitted `/infer` request: wait the ticket, then write.
    Infer { ticket: Ticket, close: bool },
    /// Anything answerable immediately.
    Done {
        status: u16,
        content_type: &'static str,
        body: Vec<u8>,
        retry_after: bool,
        close: bool,
    },
}

fn handle_conn(sh: &Arc<HttpShared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(sh.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut parser = RequestParser::new(sh.cfg.max_header_bytes, sh.cfg.max_body_bytes);
    let mut queue: VecDeque<Reply> = VecDeque::new();
    let mut served = 0usize;
    // Set once a close-carrying error reply is queued: the byte stream
    // past it is untrustworthy, so parsing stops.
    let mut poisoned = false;
    let mut rdbuf = vec![0u8; 16 * 1024];
    loop {
        // Admit buffered pipelined requests up to the per-conn bound and
        // the keep-alive budget.
        while !poisoned
            && queue.len() < sh.cfg.max_inflight_per_conn
            && served + queue.len() < sh.cfg.max_requests_per_conn
        {
            // Parse-window capture (tracing only): the poll that yields a
            // request is the parse compute; socket waits are not "parse".
            let t_parse0 = sh.engine.trace().map(|s| s.now_ns());
            match parser.poll() {
                Ok(Some(req)) => {
                    let parse_win =
                        sh.engine.trace().map(|s| (t_parse0.unwrap_or(0), s.now_ns()));
                    queue.push_back(route(sh, &req, parse_win));
                }
                Ok(None) => break,
                Err(e) => {
                    bump!(sh, bad_requests);
                    queue.push_back(protocol_error_reply(&e));
                    poisoned = true;
                }
            }
        }
        // Answer the oldest queued request before reading more input:
        // responses drain in request order, and a full reply queue is the
        // per-connection backpressure signal.
        if let Some(reply) = queue.pop_front() {
            served += 1;
            let at_budget = served >= sh.cfg.max_requests_per_conn;
            match write_reply(sh, &mut stream, reply, at_budget) {
                Ok(false) => continue,
                Ok(true) => return, // close requested and written
                Err(_) => {
                    // Peer gone mid-write.  Admitted requests still in the
                    // queue must be resolved so the accounting invariant
                    // (admitted == answered + failed + aborted) survives.
                    for r in queue.drain(..) {
                        if let Reply::Infer { ticket, .. } = r {
                            let _ = ticket.wait();
                            sh.release_permit();
                            bump!(sh, aborted);
                        }
                    }
                    return;
                }
            }
        }
        if poisoned {
            return; // error reply already written with close
        }
        // Reply queue empty and nothing parseable buffered: read more.
        match stream.read(&mut rdbuf) {
            Ok(0) => return, // EOF: any partial request was never admitted
            Ok(n) => parser.push(&rdbuf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle tick.  During a drain that means this connection
                // has answered everything it admitted — close it.
                if sh.draining.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Protocol-level errors answer with their status and close (the stream
/// is no longer in a parseable state).
fn protocol_error_reply(e: &HttpError) -> Reply {
    Reply::Done {
        status: e.status,
        content_type: "application/json",
        body: error_body(&e.msg),
        retry_after: false,
        close: true,
    }
}

fn route(sh: &Arc<HttpShared>, req: &Request, parse_win: Option<(u64, u64)>) -> Reply {
    let ka = req.keep_alive;
    match (req.method.as_str(), req.target.as_str()) {
        ("POST", "/infer") => route_infer(sh, req, parse_win),
        ("POST", "/swap") => route_swap(sh, req),
        ("GET", "/metrics") => {
            bump!(sh, metrics_scrapes);
            Reply::Done {
                status: 200,
                content_type: "text/plain; version=0.0.4",
                body: render_metrics(sh).into_bytes(),
                retry_after: false,
                close: !ka,
            }
        }
        ("GET", "/trace") => match sh.engine.trace() {
            Some(sink) => Reply::Done {
                status: 200,
                content_type: "application/json",
                body: sink.chrome_trace_json().to_string_compact().into_bytes(),
                retry_after: false,
                close: !ka,
            },
            None => {
                bump!(sh, rejected);
                Reply::Done {
                    status: 503,
                    content_type: "application/json",
                    body: error_body(
                        "tracing disabled: start with --trace-sample or --trace-out",
                    ),
                    retry_after: false,
                    close: !ka,
                }
            }
        },
        ("GET", "/healthz") => Reply::Done {
            status: 200,
            content_type: "text/plain",
            body: b"ok\n".to_vec(),
            retry_after: false,
            close: !ka,
        },
        (_, "/infer") | (_, "/swap") | (_, "/metrics") | (_, "/trace") | (_, "/healthz") => {
            bump!(sh, bad_requests);
            Reply::Done {
                status: 405,
                content_type: "application/json",
                body: error_body(&format!("method {} not allowed here", req.method)),
                retry_after: false,
                close: !ka,
            }
        }
        (_, path) => {
            bump!(sh, bad_requests);
            Reply::Done {
                status: 404,
                content_type: "application/json",
                body: error_body(&format!("no such path '{path}'")),
                retry_after: false,
                close: !ka,
            }
        }
    }
}

/// `/infer`: admission gate → lazy body scan → dataset materialization →
/// engine submit.  Body errors are 400 but keep the connection (the
/// request was correctly framed); queue-full is an immediate 503.
fn route_infer(sh: &Arc<HttpShared>, req: &Request, parse_win: Option<(u64, u64)>) -> Reply {
    let ka = req.keep_alive;
    if !sh.try_admit() {
        bump!(sh, rejected);
        return Reply::Done {
            status: 503,
            content_type: "application/json",
            body: error_body("admission queue full"),
            retry_after: true,
            close: !ka,
        };
    }
    // Permit held from here: every early return must release it.
    let parsed = (|| -> crate::Result<(u64, usize)> {
        let index = lazyjson::scan_u64(&req.body, "index")?
            .ok_or_else(|| crate::err!("missing field 'index'"))?;
        let samples = lazyjson::scan_u64(&req.body, "samples")?
            .ok_or_else(|| crate::err!("missing field 'samples'"))? as usize;
        crate::ensure!(
            samples >= 1 && samples <= sh.cfg.max_request_samples,
            "'samples' must be in 1..={}, got {samples}",
            sh.cfg.max_request_samples
        );
        Ok((index, samples))
    })();
    let (index, samples) = match parsed {
        Ok(v) => v,
        Err(e) => {
            sh.release_permit();
            bump!(sh, bad_requests);
            return Reply::Done {
                status: 400,
                content_type: "application/json",
                body: error_body(&e.to_string()),
                retry_after: false,
                close: !ka,
            };
        }
    };
    let (x, y) = sh.data.batch(Split::Eval, index, samples);
    match sh.engine.submit(x, y) {
        Ok(ticket) => {
            bump!(sh, admitted);
            // The parse window happened before a request id existed;
            // record it retroactively now that sampling has decided.
            if let (Some(rt), Some((t0, t1))) = (ticket.trace(), parse_win) {
                rt.span(Stage::HttpParse, rt.epoch(), t0, t1);
            }
            Reply::Infer { ticket, close: !ka }
        }
        Err(e) => {
            // The engine only refuses well-formed requests when it is
            // draining or fatally wedged — service unavailability, not a
            // client error.
            sh.release_permit();
            bump!(sh, rejected);
            Reply::Done {
                status: 503,
                content_type: "application/json",
                body: error_body(&e.to_string()),
                retry_after: true,
                close: true,
            }
        }
    }
}

/// `POST /swap`: hot-swap the engine onto a pre-materialized frontier
/// level.  Fails closed — any error leaves the old config serving.
fn route_swap(sh: &Arc<HttpShared>, req: &Request) -> Reply {
    let ka = req.keep_alive;
    let Some(reg) = sh.swaps.as_ref() else {
        bump!(sh, rejected);
        return Reply::Done {
            status: 503,
            content_type: "application/json",
            body: error_body("no swap registry: server started without --frontier-from"),
            retry_after: true,
            close: !ka,
        };
    };
    let level = match lazyjson::scan_u64(&req.body, "level") {
        Ok(Some(l)) if (l as usize) < reg.steps.len() => l as usize,
        Ok(_) | Err(_) => {
            bump!(sh, bad_requests);
            return Reply::Done {
                status: 400,
                content_type: "application/json",
                body: error_body(&format!(
                    "'level' must be an integer in 0..{}",
                    reg.steps.len()
                )),
                retry_after: false,
                close: !ka,
            };
        }
    };
    let step = &reg.steps[level];
    match sh.engine.swap(
        step.ckpt.clone(),
        step.bits.clone(),
        step.budget_frac,
        &step.label(),
    ) {
        Ok(epoch) => Reply::Done {
            status: 200,
            content_type: "application/json",
            body: Json::obj(vec![
                ("epoch", Json::num(epoch as f64)),
                ("level", Json::num(level as f64)),
                ("budget", Json::num(step.budget_frac)),
            ])
            .to_string_compact()
            .into_bytes(),
            retry_after: false,
            close: !ka,
        },
        // Swap refused (engine draining or wedged): old config stays
        // live; the caller may retry.
        Err(e) => {
            bump!(sh, rejected);
            Reply::Done {
                status: 503,
                content_type: "application/json",
                body: error_body(&e.to_string()),
                retry_after: true,
                close: !ka,
            }
        }
    }
}

/// Write one reply; for `Infer` this blocks on the engine ticket first.
/// Returns whether the connection is to close.
fn write_reply(
    sh: &Arc<HttpShared>,
    stream: &mut TcpStream,
    reply: Reply,
    at_budget: bool,
) -> std::io::Result<bool> {
    match reply {
        Reply::Done {
            status,
            content_type,
            body,
            retry_after,
            close,
        } => {
            let close = close || at_budget;
            write_response(stream, status, content_type, &body, retry_after, close)?;
            Ok(close)
        }
        Reply::Infer { ticket, close } => {
            let close = close || at_budget;
            // Keep the span buffer alive past wait() (which consumes the
            // ticket): this clone records the serialize/write spans, and
            // its drop — the request's true end — publishes the whole
            // span set to the sink's ring.
            let rt = ticket.trace().cloned();
            let waited = ticket.wait();
            sh.release_permit();
            match waited {
                Ok(resp) => {
                    bump!(sh, answered);
                    let t_ser = rt.as_ref().map(|r| r.now_ns());
                    let body = infer_response_json(&resp).into_bytes();
                    if let (Some(r), Some(t0)) = (&rt, t_ser) {
                        r.span(Stage::Serialize, resp.epoch, t0, r.now_ns());
                    }
                    let t_wr = rt.as_ref().map(|r| r.now_ns());
                    write_response(stream, 200, "application/json", &body, false, close)?;
                    if let (Some(r), Some(t0)) = (&rt, t_wr) {
                        r.span(Stage::SocketWrite, resp.epoch, t0, r.now_ns());
                    }
                }
                Err(e) => {
                    bump!(sh, failed);
                    let body = error_body(&e.to_string());
                    write_response(stream, 500, "application/json", &body, false, close)?;
                }
            }
            Ok(close)
        }
    }
}

/// Serialize one HTTP/1.1 response (always `Content-Length`-framed).
fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    retry_after: bool,
    close: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nserver: mpq\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\n",
        reason(status),
        body.len()
    );
    if retry_after {
        head += "retry-after: 1\r\n";
    }
    if close {
        head += "connection: close\r\n";
    }
    head += "\r\n";
    let mut bytes = head.into_bytes();
    bytes.extend_from_slice(body);
    stream.write_all(&bytes)
}

fn error_body(msg: &str) -> Vec<u8> {
    Json::obj(vec![("error", Json::str(msg))])
        .to_string_compact()
        .into_bytes()
}

/// The `200 /infer` body.  f32 payloads travel as `to_bits()` u32 values
/// in JSON numbers — f64 represents every u32 exactly, so the transport
/// is bit-lossless in both directions.
pub fn infer_response_json(r: &Response) -> String {
    let (dtype, bits): (&str, Vec<Json>) = match r.evalout.dtype() {
        DType::F32 => (
            "f32",
            r.evalout
                .f32s()
                .iter()
                .map(|v| Json::num(v.to_bits() as f64))
                .collect(),
        ),
        DType::I32 => (
            "i32",
            r.evalout
                .i32s()
                .iter()
                .map(|&v| Json::num(v as u32 as f64))
                .collect(),
        ),
    };
    Json::obj(vec![
        ("id", Json::num(r.id as f64)),
        ("samples", Json::num(r.samples as f64)),
        ("loss_bits", Json::num(r.loss.to_bits() as f64)),
        ("evalout_dtype", Json::str(dtype)),
        (
            "evalout_shape",
            Json::arr(r.evalout.shape.iter().map(|&d| Json::num(d as f64))),
        ),
        ("evalout_bits", Json::arr(bits)),
        ("latency_s", Json::num(r.latency_s)),
        ("epoch", Json::num(r.epoch as f64)),
    ])
    .to_string_compact()
}

/// Inverse of [`infer_response_json`] — the socket loadgen reconstructs
/// full [`Response`] values so socket runs produce the same `LoadReport`
/// shape (and bit-identity assertions) as in-process runs.
pub fn parse_infer_response(body: &[u8]) -> crate::Result<Response> {
    let text = std::str::from_utf8(body)
        .map_err(|_| crate::err!("infer response is not UTF-8"))?;
    let v = crate::jsonio::parse(text)?;
    let num = |k: &str| {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| crate::err!("infer response missing numeric field '{k}'"))
    };
    let shape = v
        .get("evalout_shape")
        .ok_or_else(|| crate::err!("infer response missing 'evalout_shape'"))?
        .usize_vec();
    let bits = v
        .get("evalout_bits")
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::err!("infer response missing 'evalout_bits'"))?;
    let dtype = v.at(&["evalout_dtype"]).as_str().unwrap_or("f32");
    let evalout = match dtype {
        "f32" => Tensor::from_f32(
            &shape,
            bits.iter()
                .map(|b| f32::from_bits(b.as_f64().unwrap_or(0.0) as u32))
                .collect(),
        ),
        "i32" => Tensor::from_i32(
            &shape,
            bits.iter()
                .map(|b| b.as_f64().unwrap_or(0.0) as u32 as i32)
                .collect(),
        ),
        other => crate::bail!("infer response has unknown evalout dtype '{other}'"),
    };
    Ok(Response {
        id: num("id")? as u64,
        samples: num("samples")? as usize,
        loss: f32::from_bits(num("loss_bits")? as u32),
        evalout,
        latency_s: num("latency_s")?,
        // Absent in pre-swap payloads: epoch 0 (the startup config).
        epoch: v.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) as u64,
    })
}

/// `GET /metrics` text.  **Stable format**: the golden test in
/// `rust/tests/http_serve_integration.rs` pins every field name and the
/// order — only ever append new lines at the end of a section.
fn render_metrics(sh: &HttpShared) -> String {
    let h = sh.stats_snapshot();
    let mut out = String::with_capacity(4096);
    out += "# mpq serve /metrics v1\n";
    family(&mut out, "mpq_http_connections_total", "counter", "Connections accepted by the front door.");
    out += &format!("mpq_http_connections_total {}\n", h.connections);
    family(&mut out, "mpq_http_requests_admitted_total", "counter", "Requests admitted to the engine.");
    out += &format!("mpq_http_requests_admitted_total {}\n", h.admitted);
    family(&mut out, "mpq_http_requests_rejected_total", "counter", "Requests rejected with 503.");
    out += &format!("mpq_http_requests_rejected_total {}\n", h.rejected);
    family(&mut out, "mpq_http_requests_answered_total", "counter", "Admitted requests answered 200.");
    out += &format!("mpq_http_requests_answered_total {}\n", h.answered);
    family(&mut out, "mpq_http_requests_failed_total", "counter", "Admitted requests answered 500.");
    out += &format!("mpq_http_requests_failed_total {}\n", h.failed);
    family(&mut out, "mpq_http_requests_aborted_total", "counter", "Admitted requests whose connection died first.");
    out += &format!("mpq_http_requests_aborted_total {}\n", h.aborted);
    family(&mut out, "mpq_http_bad_requests_total", "counter", "Non-2xx, non-503 responses.");
    out += &format!("mpq_http_bad_requests_total {}\n", h.bad_requests);
    family(&mut out, "mpq_http_metrics_scrapes_total", "counter", "GET /metrics requests served.");
    out += &format!("mpq_http_metrics_scrapes_total {}\n", h.metrics_scrapes);
    family(&mut out, "mpq_http_inflight_requests", "gauge", "Admitted requests awaiting their response.");
    out += &format!("mpq_http_inflight_requests {}\n", h.inflight);
    family(&mut out, "mpq_engine_queue_samples", "gauge", "Samples queued and not yet claimed by a worker.");
    out += &format!("mpq_engine_queue_samples {}\n", sh.engine.queued_samples());
    let ep = sh.engine.epoch_info();
    family(&mut out, "mpq_ctl_epoch", "gauge", "Current serving epoch.");
    out += &format!("mpq_ctl_epoch {}\n", ep.epoch);
    family(&mut out, "mpq_ctl_swap_total", "counter", "Successful hot-swaps since startup.");
    out += &format!("mpq_ctl_swap_total {}\n", ep.swap_total);
    family(&mut out, "mpq_ctl_active_budget", "gauge", "Budget fraction of the active config.");
    out += &format!("mpq_ctl_active_budget {}\n", ep.budget_frac);
    family(&mut out, "mpq_ctl_frontier_levels", "gauge", "Pre-materialized frontier levels available to /swap.");
    out += &format!(
        "mpq_ctl_frontier_levels {}\n",
        sh.swaps.as_ref().map_or(0, |r| r.steps.len())
    );
    sh.engine
        .metrics()
        .render_prometheus(&mut out, sh.started.elapsed().as_secs_f64());
    // Per-stage latency histograms, present only while tracing is on
    // (the sink exists) — appended last so the tracing-off rendering is
    // a strict prefix of the tracing-on one.
    if let Some(sink) = sh.engine.trace() {
        sink.render_stage_metrics(&mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_response_json_round_trips_bit_exactly() {
        let r = Response {
            id: 17,
            samples: 3,
            loss: 1.234567e-3_f32,
            evalout: Tensor::from_f32(&[], vec![2.0]),
            latency_s: 0.001953125, // dyadic: exact through the emitter
            epoch: 5,
        };
        let back = parse_infer_response(infer_response_json(&r).as_bytes()).unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.samples, r.samples);
        assert_eq!(back.loss.to_bits(), r.loss.to_bits());
        assert_eq!(back.evalout, r.evalout);
        assert_eq!(back.latency_s.to_bits(), r.latency_s.to_bits());
        assert_eq!(back.epoch, r.epoch);
        // Awkward f32 values (negative zero, subnormal, NaN payloads
        // aside) survive the bits transport.
        for loss in [-0.0f32, f32::MIN_POSITIVE / 2.0, 3.4e38, -1.5e-39] {
            let r2 = Response { loss, ..r.clone() };
            let b2 = parse_infer_response(infer_response_json(&r2).as_bytes()).unwrap();
            assert_eq!(b2.loss.to_bits(), loss.to_bits(), "loss {loss}");
        }
        // i32 evalout path.
        let r3 = Response {
            evalout: Tensor::from_i32(&[2], vec![-7, 42]),
            ..r
        };
        let b3 = parse_infer_response(infer_response_json(&r3).as_bytes()).unwrap();
        assert_eq!(b3.evalout, r3.evalout);
    }

    #[test]
    fn error_body_is_valid_json_even_with_quotes_in_the_message() {
        let body = error_body("bad \"field\" \\ value");
        let v = crate::jsonio::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.at(&["error"]).as_str(), Some("bad \"field\" \\ value"));
    }
}
