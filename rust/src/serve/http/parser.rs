//! Incremental HTTP/1.1 request parser.
//!
//! Bytes arrive from the socket in arbitrary fragments; the parser owns a
//! growable buffer, and [`RequestParser::poll`] re-examines it after every
//! [`RequestParser::push`] until a complete request (head + declared body)
//! is present.  Parsing is a pure function of the buffered bytes, so a
//! request split at *any* byte boundary parses identically to the same
//! request delivered whole (asserted for every boundary in the tests
//! below).
//!
//! ## Protocol surface and status-code contract
//!
//! Deliberately the smallest HTTP/1.1 subset the serving front door
//! needs; everything outside it maps to a *documented* status code and
//! leaves the connection in a defined state (pinned by
//! `rust/tests/http_serve_integration.rs`):
//!
//! | condition                                   | status | connection |
//! |---------------------------------------------|--------|------------|
//! | malformed request line / header / encoding  | 400    | close      |
//! | bad or conflicting `Content-Length`         | 400    | close      |
//! | body larger than the configured limit       | 413    | close      |
//! | header block larger than the limit          | 431    | close      |
//! | `Transfer-Encoding` (chunked unsupported)   | 501    | close      |
//! | HTTP version other than 1.0/1.1             | 505    | close      |
//!
//! A truncated body is not an error: the parser reports "need more" until
//! the peer either completes the request or closes the socket.  Both CRLF
//! and bare-LF line endings are accepted (robustness against hand-rolled
//! clients); leading empty lines before the request line are skipped per
//! RFC 9112 §2.2.

/// Protocol-level parse failure: the HTTP status to answer with before
/// closing the connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError { status, msg: msg.into() }
    }
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Content Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// One fully-received request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub target: String,
    /// False for HTTP/1.0 (affects the keep-alive default).
    pub version_11: bool,
    /// Resolved keep-alive semantics: 1.1 defaults to true unless
    /// `Connection: close`; 1.0 defaults to false unless
    /// `Connection: keep-alive`.
    pub keep_alive: bool,
    /// Header (name, value) pairs; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with the given (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Byte offset one past the blank line terminating the header block, for
/// CRLF (`\r\n\r\n`), bare-LF (`\n\n`), and mixed (`\n\r\n`) endings.
/// Shared with the response parser in [`super::client`].
pub(crate) fn find_header_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
            if buf.get(i + 1) == Some(&b'\r') && buf.get(i + 2) == Some(&b'\n') {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// Incremental parser over one connection's byte stream.  Repeated
/// [`poll`](RequestParser::poll) calls yield pipelined requests in order;
/// unconsumed bytes stay buffered for the next request.
pub struct RequestParser {
    buf: Vec<u8>,
    max_header: usize,
    max_body: usize,
}

impl RequestParser {
    pub fn new(max_header: usize, max_body: usize) -> RequestParser {
        RequestParser {
            buf: Vec::new(),
            max_header: max_header.max(64),
            max_body,
        }
    }

    /// Append freshly-read socket bytes.
    pub fn push(&mut self, data: &[u8]) {
        self.buf.extend_from_slice(data);
    }

    /// Bytes buffered but not yet consumed by a completed request.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Try to parse one complete request off the front of the buffer.
    /// `Ok(None)` means "need more bytes" — a defined wait state, never an
    /// error.  `Err` carries the status code to answer with before
    /// closing.
    pub fn poll(&mut self) -> Result<Option<Request>, HttpError> {
        // Skip empty line(s) before the request line (RFC 9112 §2.2).
        let mut start = 0;
        while start < self.buf.len() && (self.buf[start] == b'\r' || self.buf[start] == b'\n') {
            start += 1;
        }
        if start > 0 {
            self.buf.drain(..start);
        }
        let Some(head_end) = find_header_end(&self.buf) else {
            if self.buf.len() > self.max_header {
                return Err(HttpError::new(
                    431,
                    format!("header block exceeds {} bytes", self.max_header),
                ));
            }
            return Ok(None);
        };
        if head_end > self.max_header {
            return Err(HttpError::new(
                431,
                format!("header block exceeds {} bytes", self.max_header),
            ));
        }
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
        let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
        let request_line = lines.next().unwrap_or("");
        let parts: Vec<&str> = request_line.split_whitespace().collect();
        if parts.len() != 3 {
            return Err(HttpError::new(
                400,
                format!("malformed request line '{request_line}'"),
            ));
        }
        let (method, target, version) = (parts[0], parts[1], parts[2]);
        if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
            return Err(HttpError::new(400, format!("malformed method '{method}'")));
        }
        let version_11 = match version {
            "HTTP/1.1" => true,
            "HTTP/1.0" => false,
            other => {
                return Err(HttpError::new(
                    505,
                    format!("unsupported protocol version '{other}'"),
                ))
            }
        };
        let mut headers: Vec<(String, String)> = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue; // the terminating blank line
            }
            let Some(colon) = line.find(':') else {
                return Err(HttpError::new(400, format!("malformed header line '{line}'")));
            };
            let name = line[..colon].trim().to_ascii_lowercase();
            if name.is_empty() {
                return Err(HttpError::new(400, format!("malformed header line '{line}'")));
            }
            headers.push((name, line[colon + 1..].trim().to_string()));
        }
        if headers.iter().any(|(n, _)| n == "transfer-encoding") {
            return Err(HttpError::new(
                501,
                "Transfer-Encoding is not supported (use Content-Length)",
            ));
        }
        let mut body_len = 0usize;
        let mut seen_cl: Option<&str> = None;
        for (n, v) in &headers {
            if n != "content-length" {
                continue;
            }
            if let Some(prev) = seen_cl {
                if prev != v {
                    return Err(HttpError::new(
                        400,
                        format!("conflicting Content-Length headers '{prev}' vs '{v}'"),
                    ));
                }
                continue;
            }
            seen_cl = Some(v);
            body_len = v
                .parse::<usize>()
                .map_err(|_| HttpError::new(400, format!("bad Content-Length '{v}'")))?;
        }
        if body_len > self.max_body {
            return Err(HttpError::new(
                413,
                format!("body of {body_len} bytes exceeds the {} byte limit", self.max_body),
            ));
        }
        let total = head_end + body_len;
        if self.buf.len() < total {
            return Ok(None); // truncated body: wait for the rest
        }
        let connection = headers
            .iter()
            .find(|(n, _)| n == "connection")
            .map(|(_, v)| v.to_ascii_lowercase());
        let keep_alive = match connection.as_deref() {
            Some("close") => false,
            Some("keep-alive") => true,
            _ => version_11,
        };
        let req = Request {
            method: method.to_string(),
            target: target.to_string(),
            version_11,
            keep_alive,
            headers,
            body: self.buf[head_end..total].to_vec(),
        };
        self.buf.drain(..total);
        Ok(Some(req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> RequestParser {
        RequestParser::new(8 * 1024, 64 * 1024)
    }

    fn parse_whole(bytes: &[u8]) -> Result<Option<Request>, HttpError> {
        let mut p = parser();
        p.push(bytes);
        p.poll()
    }

    const POST: &[u8] =
        b"POST /infer HTTP/1.1\r\nHost: x\r\nContent-Length: 25\r\n\r\n{\"index\":7,\"samples\":3}\r\n";

    #[test]
    fn whole_request_parses() {
        let r = parse_whole(POST).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.target, "/infer");
        assert!(r.version_11);
        assert!(r.keep_alive);
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.body, b"{\"index\":7,\"samples\":3}\r\n");
    }

    /// The satellite contract: splitting a valid request at *every* byte
    /// boundary must parse to the identical request, with the first poll
    /// reporting need-more (never an error) whenever the prefix is
    /// incomplete.
    #[test]
    fn split_reads_at_every_byte_boundary_parse_identically() {
        let whole = parse_whole(POST).unwrap().unwrap();
        for cut in 1..POST.len() {
            let mut p = parser();
            p.push(&POST[..cut]);
            let first = p.poll().unwrap_or_else(|e| {
                panic!("prefix of {cut} bytes must not error: {e:?}")
            });
            assert!(first.is_none(), "request complete after only {cut} bytes?");
            p.push(&POST[cut..]);
            let got = p.poll().unwrap().expect("complete after both fragments");
            assert_eq!(got, whole, "split at byte {cut} changed the parse");
            assert_eq!(p.buffered(), 0);
        }
    }

    #[test]
    fn pipelined_requests_parse_in_order_from_one_push() {
        let mut p = parser();
        p.push(b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
        let a = p.poll().unwrap().unwrap();
        assert_eq!(a.target, "/healthz");
        assert!(a.keep_alive);
        let b = p.poll().unwrap().unwrap();
        assert_eq!(b.target, "/metrics");
        assert!(!b.keep_alive);
        assert!(p.poll().unwrap().is_none());
        assert_eq!(p.buffered(), 0);
    }

    #[test]
    fn bare_lf_and_mixed_line_endings_are_accepted() {
        let r = parse_whole(b"POST /infer HTTP/1.1\nContent-Length: 2\n\nok")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"ok");
        let r = parse_whole(b"GET /healthz HTTP/1.1\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.target, "/healthz");
    }

    #[test]
    fn leading_empty_lines_are_skipped() {
        let r = parse_whole(b"\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.target, "/healthz");
    }

    #[test]
    fn truncated_body_waits_instead_of_erroring() {
        let mut p = parser();
        p.push(b"POST /infer HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        assert!(p.poll().unwrap().is_none());
        assert!(p.poll().unwrap().is_none(), "re-poll must stay in the wait state");
        p.push(b"defghij");
        assert_eq!(p.poll().unwrap().unwrap().body, b"abcdefghij");
    }

    #[test]
    fn oversized_header_block_is_431() {
        // Terminated but oversized.
        let mut p = RequestParser::new(128, 1024);
        let mut big = b"GET / HTTP/1.1\r\n".to_vec();
        big.extend_from_slice(format!("X-Pad: {}\r\n\r\n", "a".repeat(200)).as_bytes());
        p.push(&big);
        assert_eq!(p.poll().unwrap_err().status, 431);
        // Unterminated and already past the limit: fail fast, don't buffer
        // forever.
        let mut p = RequestParser::new(128, 1024);
        p.push("GET / HTTP/1.1\r\nX-Pad: ".as_bytes());
        p.push("a".repeat(200).as_bytes());
        assert_eq!(p.poll().unwrap_err().status, 431);
    }

    #[test]
    fn bad_content_length_is_400() {
        for cl in ["abc", "-1", "1e3", "18446744073709551616"] {
            let req = format!("POST /infer HTTP/1.1\r\nContent-Length: {cl}\r\n\r\n");
            let err = parse_whole(req.as_bytes()).unwrap_err();
            assert_eq!(err.status, 400, "Content-Length '{cl}'");
            assert!(err.msg.contains("Content-Length"), "{}", err.msg);
        }
        // Conflicting duplicates are 400; agreeing duplicates are fine.
        let err = parse_whole(b"POST / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\n")
            .unwrap_err();
        assert_eq!(err.status, 400);
        let r = parse_whole(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"ok");
    }

    #[test]
    fn body_over_limit_is_413_before_the_body_arrives() {
        let mut p = RequestParser::new(1024, 16);
        p.push(b"POST /infer HTTP/1.1\r\nContent-Length: 17\r\n\r\n");
        assert_eq!(p.poll().unwrap_err().status, 413);
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for line in ["GET /x", "GET  HTTP/1.1", "just-garbage", "get /x HTTP/1.1"] {
            let req = format!("{line}\r\n\r\n");
            assert_eq!(parse_whole(req.as_bytes()).unwrap_err().status, 400, "{line}");
        }
        assert_eq!(
            parse_whole(b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap_err().status,
            400
        );
        // Non-UTF-8 head.
        assert_eq!(parse_whole(b"GET /\xff HTTP/1.1\r\n\r\n").unwrap_err().status, 400);
    }

    #[test]
    fn unsupported_version_is_505() {
        for v in ["HTTP/2.0", "HTTP/0.9", "ICY/1.0"] {
            let req = format!("GET / {v}\r\n\r\n");
            assert_eq!(parse_whole(req.as_bytes()).unwrap_err().status, 505, "{v}");
        }
    }

    #[test]
    fn transfer_encoding_is_501() {
        let err = parse_whole(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn keep_alive_defaults_follow_the_http_version() {
        let ka = |req: &str| parse_whole(req.as_bytes()).unwrap().unwrap().keep_alive;
        assert!(ka("GET / HTTP/1.1\r\n\r\n"));
        assert!(!ka("GET / HTTP/1.1\r\nConnection: close\r\n\r\n"));
        assert!(!ka("GET / HTTP/1.0\r\n\r\n"));
        assert!(ka("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"));
    }
}
