//! Minimal blocking HTTP/1.1 client over std `TcpStream`.
//!
//! Exactly the subset the socket loadgen, the smoke target, and the test
//! suite need: keep-alive request/response over one connection, with
//! `send`/`recv` split so tests and the open-loop loadgen can pipeline a
//! bounded number of requests.  Responses must carry `Content-Length`
//! (our server always does); chunked responses are out of scope.

use std::io::{Read, Write};
use std::net::TcpStream;

use super::parser::find_header_end;

/// One parsed response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    /// Header (name, value) pairs; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// A keep-alive client connection.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> crate::Result<HttpClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| crate::err!("http client: connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(HttpClient { stream, buf: Vec::new() })
    }

    /// Write one request without waiting for the response (pipelining
    /// building block; pair each `send` with one later [`recv`]).
    pub fn send(&mut self, method: &str, path: &str, body: Option<&[u8]>) -> crate::Result<()> {
        let mut req = format!("{method} {path} HTTP/1.1\r\nhost: mpq\r\n");
        if let Some(b) = body {
            req += &format!(
                "content-type: application/json\r\ncontent-length: {}\r\n",
                b.len()
            );
        }
        req += "\r\n";
        let mut bytes = req.into_bytes();
        if let Some(b) = body {
            bytes.extend_from_slice(b);
        }
        self.stream
            .write_all(&bytes)
            .map_err(|e| crate::err!("http client: write: {e}"))
    }

    /// Raw bytes straight onto the socket (robustness tests drive the
    /// server with hand-crafted malformed requests through this).
    pub fn send_raw(&mut self, bytes: &[u8]) -> crate::Result<()> {
        self.stream
            .write_all(bytes)
            .map_err(|e| crate::err!("http client: write: {e}"))
    }

    /// Stop sending (half-close).  The server sees EOF after any buffered
    /// bytes — how truncated-body handling is exercised end to end.
    pub fn shutdown_write(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Write);
    }

    /// Block until one full response is read off the connection.  Errors
    /// on EOF — which is how tests observe "server closed the connection".
    pub fn recv(&mut self) -> crate::Result<HttpResponse> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(resp) = self.try_parse()? {
                return Ok(resp);
            }
            let n = self
                .stream
                .read(&mut chunk)
                .map_err(|e| crate::err!("http client: read: {e}"))?;
            if n == 0 {
                crate::bail!("http client: connection closed by server");
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }

    /// One complete request/response exchange.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> crate::Result<HttpResponse> {
        self.send(method, path, body)?;
        self.recv()
    }

    pub fn get(&mut self, path: &str) -> crate::Result<HttpResponse> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &[u8]) -> crate::Result<HttpResponse> {
        self.request("POST", path, Some(body))
    }

    fn try_parse(&mut self) -> crate::Result<Option<HttpResponse>> {
        let Some(head_end) = find_header_end(&self.buf) else {
            return Ok(None);
        };
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| crate::err!("http client: response head is not UTF-8"))?;
        let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
        let status_line = lines.next().unwrap_or("");
        let mut parts = status_line.split_whitespace();
        let proto = parts.next().unwrap_or("");
        crate::ensure!(
            proto.starts_with("HTTP/1."),
            "http client: bad status line '{status_line}'"
        );
        let status: u16 = parts
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| crate::err!("http client: bad status in '{status_line}'"))?;
        let mut headers: Vec<(String, String)> = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Some(colon) = line.find(':') else {
                crate::bail!("http client: malformed response header '{line}'");
            };
            headers.push((
                line[..colon].trim().to_ascii_lowercase(),
                line[colon + 1..].trim().to_string(),
            ));
        }
        let body_len: usize = match headers.iter().find(|(n, _)| n == "content-length") {
            Some((_, v)) => v
                .parse()
                .map_err(|_| crate::err!("http client: bad Content-Length '{v}'"))?,
            None => 0,
        };
        let total = head_end + body_len;
        if self.buf.len() < total {
            return Ok(None);
        }
        let body = self.buf[head_end..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(HttpResponse { status, headers, body }))
    }
}
