//! Per-request span tracing + hot-path stage profiler for `mpq serve`.
//!
//! The serving stack used to expose exactly one latency number — the
//! end-to-end request histogram in [`crate::serve::metrics`].  The SLO
//! controller and the packed-kernel variants both make decisions that
//! hinge on *where* time goes (queue wait vs batch assembly vs per-layer
//! packed GEMM vs serialization), so this module records the full
//! request lifecycle as compact span events:
//!
//! ```text
//! http_parse → admission → queue_wait → batch_assembly
//!            → layer_gemm (one span per layer, tagged bits+variant)
//!            → reassembly → epilogue → serialize → socket_write
//! ```
//!
//! ## Design
//!
//! * **Sampling is deterministic**: a pure function of the engine-
//!   assigned request id (`id % sample == 0`), so reruns trace the same
//!   requests and tests can predict the sampled set exactly.
//! * **Recording is allocation-light and uncontended**: spans append to
//!   a per-request buffer ([`RequestSpans`]) that only one thread
//!   touches at a time (conn thread → worker → conn thread), so its
//!   mutex never blocks in steady state; per-stage histograms are
//!   relaxed atomics, same as [`crate::serve::Metrics`].
//! * **Memory is bounded, whole requests only**: when the last handle to
//!   a request's spans drops, the completed set publishes into one of a
//!   fixed number of fixed-capacity rings; a full ring drops its
//!   *oldest whole request* — a partial span set is never observable.
//! * **Disabled tracing is near-free**: the engine checks one
//!   `Option<Arc<TraceSink>>` at admission; every later hook is gated on
//!   the request's own `Option<ReqTrace>` being `Some`.
//! * **Bit-identity is untouched**: tracing only reads clocks and
//!   copies metadata — the serve/http/packed identity suites run with
//!   tracing enabled to pin that.
//!
//! ## Exposure
//!
//! * [`TraceSink::chrome_trace_json`] — Chrome trace-event JSON
//!   (Perfetto-loadable) behind `GET /trace` and `--trace-out FILE`;
//! * [`TraceSink::render_stage_metrics`] — pinned `mpq_stage_*` summary
//!   lines appended to `GET /metrics`;
//! * [`crate::serve::controller::decisions_jsonl`] — the structured
//!   controller decision log (byte-identical under `--degrade` reruns).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use crate::jsonio::Json;
use crate::serve::metrics::{
    bucket_index, bucket_rep_ns, family, quantile_from_counts, N_BUCKETS,
};

/// Pipeline stages, in nominal lifecycle order.  `name()` strings are
/// part of the pinned `/metrics` + trace-JSON format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// HTTP/1.1 request parse window on the connection thread.
    HttpParse,
    /// Engine admission: validation, id allocation, enqueue.
    Admission,
    /// Enqueue → a worker claims the chunk.
    QueueWait,
    /// Fused input assembly (chunk rows → one batch tensor).
    BatchAssembly,
    /// One per-layer GEMM inside the backend forward (bits + variant).
    LayerGemm,
    /// Plan-order logit-row reassembly into the request buffer.
    Reassembly,
    /// Per-request softmax-CE epilogue over the reassembled logits.
    Epilogue,
    /// Response JSON serialization on the connection thread.
    Serialize,
    /// Socket write of the serialized response.
    SocketWrite,
}

/// All stages in nominal order (also the `/metrics` emission order).
pub const STAGES: [Stage; 9] = [
    Stage::HttpParse,
    Stage::Admission,
    Stage::QueueWait,
    Stage::BatchAssembly,
    Stage::LayerGemm,
    Stage::Reassembly,
    Stage::Epilogue,
    Stage::Serialize,
    Stage::SocketWrite,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::HttpParse => "http_parse",
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::LayerGemm => "layer_gemm",
            Stage::Reassembly => "reassembly",
            Stage::Epilogue => "epilogue",
            Stage::Serialize => "serialize",
            Stage::SocketWrite => "socket_write",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::HttpParse => 0,
            Stage::Admission => 1,
            Stage::QueueWait => 2,
            Stage::BatchAssembly => 3,
            Stage::LayerGemm => 4,
            Stage::Reassembly => 5,
            Stage::Epilogue => 6,
            Stage::Serialize => 7,
            Stage::SocketWrite => 8,
        }
    }

    pub fn from_name(name: &str) -> Option<Stage> {
        STAGES.iter().copied().find(|s| s.name() == name)
    }
}

/// One compact span event.  Timestamps are nanoseconds since the sink's
/// creation instant (one clock for the whole trace).
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub request_id: u64,
    pub epoch: u64,
    pub stage: Stage,
    /// Layer index for [`Stage::LayerGemm`], else -1.
    pub layer: i32,
    /// Effective layer precision for [`Stage::LayerGemm`], else 0.
    pub bits: u32,
    /// Kernel variant name for [`Stage::LayerGemm`] (`""` elsewhere).
    pub variant: &'static str,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    /// Small dense id of the recording thread (not the OS tid).
    pub thread: u64,
}

/// One controller decision event (windowed p99, queue depth, chosen
/// level, epoch) — rendered as an instant event in the Chrome trace.
#[derive(Clone, Debug)]
pub struct CtlEvent {
    pub tick: u64,
    pub queue_depth: usize,
    pub p99_s: f64,
    pub decision: String,
    pub level: usize,
    pub epoch: u64,
    pub t_ns: u64,
}

/// Tracing configuration (CLI: `--trace-sample`, internal knobs for
/// tests and the bench harness).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Keep request ids where `id % sample == 0` (1 = every request).
    pub sample: u64,
    /// Max retained *whole requests* across all rings.
    pub capacity: usize,
    /// Ring count (bounds publication contention; capacity is split
    /// evenly across rings).
    pub shards: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { sample: 1, capacity: 4096, shards: 8 }
    }
}

/// A completed request's span set, as retained by the rings.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub request_id: u64,
    pub spans: Vec<SpanEvent>,
}

/// Per-stage latency histogram (same bucket scheme as the engine's
/// request histogram; relaxed atomics only).
struct StageHist {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_ns: AtomicU64,
}

impl StageHist {
    fn new() -> StageHist {
        StageHist {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn record(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone histogram bucket; reporting reads tolerate staleness
        self.total.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone counter; cross-field tearing acceptable in reports
        self.sum_ns.fetch_add(ns, Ordering::Relaxed); // relaxed-ok: monotone sum; cross-field tearing acceptable in reports
    }

    fn snapshot(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect() // relaxed-ok: reporting-only snapshot; staleness acceptable
    }
}

/// Dense per-thread tag for [`SpanEvent::thread`] — assigned on first
/// use, stable for the thread's lifetime.
fn thread_tag() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TAG: u64 = NEXT.fetch_add(1, Ordering::Relaxed); // relaxed-ok: unique tag allocation only; no data published through this counter
    }
    TAG.with(|t| *t)
}

/// The span recorder.  One per engine (shared with the HTTP front door
/// via the engine handle); create with [`TraceSink::new`], hand the
/// `Arc` to [`crate::serve::ServeConfig::trace`].
pub struct TraceSink {
    start: Instant,
    sample: u64,
    shard_cap: usize,
    shards: Vec<Mutex<VecDeque<RequestRecord>>>,
    hist: Vec<StageHist>,
    ctl: Mutex<Vec<CtlEvent>>,
    published: AtomicU64,
    dropped: AtomicU64,
}

impl TraceSink {
    pub fn new(cfg: TraceConfig) -> Arc<TraceSink> {
        let shards = cfg.shards.max(1);
        let shard_cap = (cfg.capacity / shards).max(1);
        Arc::new(TraceSink {
            start: Instant::now(),
            sample: cfg.sample.max(1),
            shard_cap,
            shards: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            hist: STAGES.iter().map(|_| StageHist::new()).collect(),
            ctl: Mutex::new(Vec::new()),
            published: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        })
    }

    /// Nanoseconds since the sink was created — the trace's time base.
    pub fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// The configured sampling modulus.
    pub fn sample(&self) -> u64 {
        self.sample
    }

    /// Is request `id` in the deterministic sample set?
    pub fn sampled(&self, id: u64) -> bool {
        id % self.sample == 0
    }

    /// Sampling gate at admission: a span buffer for sampled ids, `None`
    /// otherwise.  The buffer publishes itself into the rings when its
    /// last clone drops (i.e. when the request's lifecycle truly ends —
    /// after the socket write on the HTTP path).
    pub fn begin(self: &Arc<Self>, request_id: u64) -> Option<ReqTrace> {
        if !self.sampled(request_id) {
            return None;
        }
        Some(Arc::new(RequestSpans {
            sink: Arc::downgrade(self),
            request_id,
            admitted_ns: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            spans: Mutex::new(Vec::with_capacity(12)),
        }))
    }

    fn record(&self, rt: &RequestSpans, ev: SpanEvent) {
        self.hist[ev.stage.index()].record(ev.t_end_ns.saturating_sub(ev.t_start_ns));
        rt.spans.lock().unwrap().push(ev);
    }

    fn publish(&self, rec: RequestRecord) {
        if rec.spans.is_empty() {
            return;
        }
        let shard = (thread_tag() as usize) % self.shards.len();
        let mut ring = self.shards[shard].lock().unwrap();
        while ring.len() >= self.shard_cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone drop counter; ring contents are guarded by the shard mutex
        }
        ring.push_back(rec);
        self.published.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone publish counter; ring contents are guarded by the shard mutex
    }

    /// Record one controller decision.
    pub fn ctl_event(
        &self,
        tick: u64,
        queue_depth: usize,
        p99_s: f64,
        decision: &str,
        level: usize,
        epoch: u64,
    ) {
        let ev = CtlEvent {
            tick,
            queue_depth,
            p99_s,
            decision: decision.to_string(),
            level,
            epoch,
            t_ns: self.now_ns(),
        };
        self.ctl.lock().unwrap().push(ev);
    }

    /// Whole requests published so far (completed span sets).
    pub fn published(&self) -> u64 {
        self.published.load(Ordering::Relaxed) // relaxed-ok: reporting-only counter load; staleness acceptable
    }

    /// Whole requests evicted from full rings (oldest first).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed) // relaxed-ok: reporting-only counter load; staleness acceptable
    }

    /// Spans recorded for `stage` (count across sampled requests,
    /// including ones later evicted from the rings).
    pub fn stage_count(&self, stage: Stage) -> u64 {
        self.hist[stage.index()].total.load(Ordering::Relaxed) // relaxed-ok: reporting-only counter load; staleness acceptable
    }

    /// Snapshot of the retained whole-request records, oldest first per
    /// ring, sorted by first span start across rings.
    pub fn requests(&self) -> Vec<RequestRecord> {
        let mut out: Vec<RequestRecord> = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().unwrap().iter().cloned());
        }
        out.sort_by_key(|r| {
            (
                r.spans.iter().map(|s| s.t_start_ns).min().unwrap_or(0),
                r.request_id,
            )
        });
        out
    }

    /// Chrome trace-event JSON (the `chrome://tracing` / Perfetto
    /// format): one complete (`"ph":"X"`) event per span with
    /// microsecond timestamps, one instant (`"ph":"I"`) event per
    /// controller decision.  Built with [`crate::jsonio`] — no deps.
    pub fn chrome_trace_json(&self) -> Json {
        let mut spans: Vec<SpanEvent> = Vec::new();
        for rec in self.requests() {
            spans.extend(rec.spans);
        }
        spans.sort_by_key(|s| (s.t_start_ns, s.request_id, s.stage.index()));
        let mut events: Vec<Json> = Vec::with_capacity(spans.len());
        for s in &spans {
            let mut args = vec![
                ("epoch", Json::num(s.epoch as f64)),
                ("request_id", Json::num(s.request_id as f64)),
            ];
            if s.stage == Stage::LayerGemm {
                args.push(("bits", Json::num(s.bits as f64)));
                args.push(("layer", Json::num(s.layer as f64)));
                args.push(("variant", Json::str(s.variant)));
            }
            events.push(Json::obj(vec![
                ("args", Json::obj(args)),
                ("cat", Json::str("serve")),
                ("dur", Json::num(s.t_end_ns.saturating_sub(s.t_start_ns) as f64 / 1e3)),
                ("name", Json::str(s.stage.name())),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.thread as f64)),
                ("ts", Json::num(s.t_start_ns as f64 / 1e3)),
            ]));
        }
        for c in self.ctl.lock().unwrap().iter() {
            events.push(Json::obj(vec![
                (
                    "args",
                    Json::obj(vec![
                        ("decision", Json::str(&c.decision)),
                        ("epoch", Json::num(c.epoch as f64)),
                        ("level", Json::num(c.level as f64)),
                        ("p99_s", Json::num(c.p99_s)),
                        ("queue_depth", Json::num(c.queue_depth as f64)),
                        ("tick", Json::num(c.tick as f64)),
                    ]),
                ),
                ("cat", Json::str("ctl")),
                ("name", Json::str("ctl_tick")),
                ("ph", Json::str("I")),
                ("pid", Json::num(1.0)),
                ("s", Json::str("g")),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(c.t_ns as f64 / 1e3)),
            ]));
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// Write the Chrome trace JSON to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> crate::Result<()> {
        let text = self.chrome_trace_json().to_string_compact();
        std::fs::write(path, text)
            .map_err(|e| crate::err!("trace: writing {}: {e}", path.display()))
    }

    /// Append the pinned `mpq_stage_*` section to a `/metrics` scrape:
    /// per-stage p50/p99 + count + sum over sampled traced requests.
    /// Emitted only when tracing is enabled (the sink exists); stage
    /// order is [`STAGES`] order.  **Stable format** — pinned by
    /// `rust/tests/http_serve_integration.rs`; only ever append.
    pub fn render_stage_metrics(&self, out: &mut String) {
        family(
            out,
            "mpq_stage_latency_seconds",
            "summary",
            "Per-stage latency over sampled traced requests.",
        );
        for stage in STAGES {
            let h = &self.hist[stage.index()];
            let counts = h.snapshot();
            for (label, q) in [("0.5", 0.5f64), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "mpq_stage_latency_seconds{{stage=\"{}\",quantile=\"{label}\"}} {}\n",
                    stage.name(),
                    quantile_from_counts(&counts, q)
                ));
            }
            out.push_str(&format!(
                "mpq_stage_latency_seconds_count{{stage=\"{}\"}} {}\n",
                stage.name(),
                h.total.load(Ordering::Relaxed) // relaxed-ok: render-time counter load; staleness acceptable
            ));
            out.push_str(&format!(
                "mpq_stage_latency_seconds_sum{{stage=\"{}\"}} {}\n",
                stage.name(),
                h.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 // relaxed-ok: render-time sum load; staleness acceptable
            ));
        }
    }
}

/// Shared handle to one request's in-flight span buffer.
pub type ReqTrace = Arc<RequestSpans>;

/// A sampled request's span buffer.  Clones travel with the request
/// (ticket → pending → reply); whoever records a span appends here, and
/// the **last clone's drop** publishes the completed set into the sink's
/// rings — so rings only ever hold whole requests.
pub struct RequestSpans {
    sink: Weak<TraceSink>,
    request_id: u64,
    /// End of the admission span (= queue-wait start), sink-relative ns.
    admitted_ns: AtomicU64,
    /// Serving epoch captured at admission (HTTP-side spans are recorded
    /// by threads that never see the `Pending`).
    epoch: AtomicU64,
    spans: Mutex<Vec<SpanEvent>>,
}

impl RequestSpans {
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// Sink-relative timestamp, 0 if the sink is gone.
    pub fn now_ns(&self) -> u64 {
        self.sink.upgrade().map(|s| s.now_ns()).unwrap_or(0)
    }

    /// Record one span (stage timing + metadata).  Feeds the stage
    /// histogram and appends to the request's buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        stage: Stage,
        epoch: u64,
        layer: i32,
        bits: u32,
        variant: &'static str,
        t_start_ns: u64,
        t_end_ns: u64,
    ) {
        let Some(sink) = self.sink.upgrade() else { return };
        sink.record(
            self,
            SpanEvent {
                request_id: self.request_id,
                epoch,
                stage,
                layer,
                bits,
                variant,
                t_start_ns,
                t_end_ns: t_end_ns.max(t_start_ns),
                thread: thread_tag(),
            },
        );
    }

    /// Shorthand for stages with no layer metadata.
    pub fn span(&self, stage: Stage, epoch: u64, t_start_ns: u64, t_end_ns: u64) {
        self.record(stage, epoch, -1, 0, "", t_start_ns, t_end_ns);
    }

    /// Mark the admission end (= queue-wait start) and pin the serving
    /// epoch this request was admitted under.
    pub fn set_admitted(&self, t_ns: u64, epoch: u64) {
        self.admitted_ns.store(t_ns, Ordering::Relaxed); // relaxed-ok: written at admission; the request handoff mutex orders it before reads
        self.epoch.store(epoch, Ordering::Relaxed); // relaxed-ok: epoch pinned at admission; ordered by the request handoff mutex
    }

    /// Admission end timestamp (queue-wait spans start here).
    pub fn admitted_ns(&self) -> u64 {
        self.admitted_ns.load(Ordering::Relaxed) // relaxed-ok: read after request handoff; see set_admitted
    }

    /// The serving epoch pinned at admission (0 before then).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed) // relaxed-ok: read after request handoff; see set_admitted
    }
}

impl Drop for RequestSpans {
    fn drop(&mut self) {
        if let Some(sink) = self.sink.upgrade() {
            let spans = match self.spans.get_mut() {
                Ok(v) => std::mem::take(v),
                Err(p) => std::mem::take(p.into_inner()),
            };
            sink.publish(RequestRecord { request_id: self.request_id, spans });
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-file validation (the `mpq trace` subcommand / `make trace-smoke`)
// ---------------------------------------------------------------------------

/// Summary of a validated Chrome trace file.
#[derive(Debug)]
pub struct TraceCheck {
    /// Total events (spans + instants).
    pub events: usize,
    /// Distinct request ids with at least one span.
    pub requests: usize,
    /// Stage names present, in [`STAGES`] order.
    pub stages: Vec<&'static str>,
    /// Controller instant events.
    pub ctl_events: usize,
}

/// Parse + validate Chrome trace-event JSON text: every event must have
/// non-negative `ts`, complete events non-negative `dur`, and every
/// traced request a complete engine-stage span set (admission,
/// queue_wait, batch_assembly, ≥1 layer_gemm, reassembly, epilogue) with
/// `admission` starting no later than any of its other engine spans.
/// HTTP stages (http_parse/serialize/socket_write) are required per
/// request only when any request in the file carries them (i.e. the
/// trace came from a `--listen` run).
pub fn check_trace_text(text: &str) -> crate::Result<TraceCheck> {
    let v = crate::jsonio::parse(text)?;
    let events = match v.at(&["traceEvents"]) {
        Json::Arr(a) => a,
        _ => crate::bail!("trace: no traceEvents array"),
    };
    let mut by_req: std::collections::BTreeMap<u64, Vec<(Stage, f64, f64)>> =
        std::collections::BTreeMap::new();
    let mut seen = vec![false; STAGES.len()];
    let mut ctl_events = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ts = ev
            .at(&["ts"])
            .as_f64()
            .ok_or_else(|| crate::err!("trace: event {i} missing ts"))?;
        crate::ensure!(ts >= 0.0, "trace: event {i} has negative ts {ts}");
        let name = match ev.at(&["name"]).as_str() {
            Some(n) => n.to_string(),
            None => crate::bail!("trace: event {i} missing name"),
        };
        let ph = ev.at(&["ph"]).as_str().unwrap_or("").to_string();
        if ph == "I" {
            ctl_events += 1;
            continue;
        }
        crate::ensure!(ph == "X", "trace: event {i} ('{name}') has ph '{ph}'");
        let dur = ev
            .at(&["dur"])
            .as_f64()
            .ok_or_else(|| crate::err!("trace: event {i} ('{name}') missing dur"))?;
        crate::ensure!(dur >= 0.0, "trace: event {i} ('{name}') has negative dur {dur}");
        let stage = Stage::from_name(&name)
            .ok_or_else(|| crate::err!("trace: event {i} has unknown stage '{name}'"))?;
        seen[stage.index()] = true;
        let rid = ev
            .at(&["args", "request_id"])
            .as_f64()
            .ok_or_else(|| crate::err!("trace: event {i} ('{name}') missing request_id"))?;
        by_req.entry(rid as u64).or_default().push((stage, ts, dur));
    }
    let any_http = seen[Stage::HttpParse.index()];
    let engine_required = [
        Stage::Admission,
        Stage::QueueWait,
        Stage::BatchAssembly,
        Stage::LayerGemm,
        Stage::Reassembly,
        Stage::Epilogue,
    ];
    for (rid, spans) in &by_req {
        for need in engine_required {
            crate::ensure!(
                spans.iter().any(|(s, _, _)| *s == need),
                "trace: request {rid} is missing stage '{}'",
                need.name()
            );
        }
        if any_http {
            for need in [Stage::HttpParse, Stage::Serialize, Stage::SocketWrite] {
                crate::ensure!(
                    spans.iter().any(|(s, _, _)| *s == need),
                    "trace: request {rid} is missing http stage '{}'",
                    need.name()
                );
            }
        }
        let admit = spans
            .iter()
            .filter(|(s, _, _)| *s == Stage::Admission)
            .map(|&(_, ts, _)| ts)
            .fold(f64::INFINITY, f64::min);
        for (s, ts, _) in spans {
            if *s != Stage::HttpParse {
                crate::ensure!(
                    *ts + 1e-9 >= admit,
                    "trace: request {rid} stage '{}' starts before admission",
                    s.name()
                );
            }
        }
    }
    crate::ensure!(!by_req.is_empty(), "trace: no request spans recorded");
    Ok(TraceCheck {
        events: events.len(),
        requests: by_req.len(),
        stages: STAGES
            .iter()
            .filter(|s| seen[s.index()])
            .map(|s| s.name())
            .collect(),
        ctl_events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink(sample: u64, capacity: usize, shards: usize) -> Arc<TraceSink> {
        TraceSink::new(TraceConfig { sample, capacity, shards })
    }

    #[test]
    fn sampling_is_pure_modulus() {
        let s = sink(4, 64, 1);
        for id in 0..32u64 {
            assert_eq!(s.begin(id).is_some(), id % 4 == 0, "id {id}");
        }
        // sample=1 traces everything, sample=0 clamps to 1.
        assert!(sink(1, 64, 1).begin(17).is_some());
        assert!(sink(0, 64, 1).begin(17).is_some());
    }

    #[test]
    fn drop_publishes_whole_requests_and_ring_evicts_oldest() {
        let s = sink(1, 4, 1);
        for id in 0..10u64 {
            let rt = s.begin(id).unwrap();
            rt.span(Stage::Admission, 0, id * 100, id * 100 + 10);
            rt.span(Stage::QueueWait, 0, id * 100 + 10, id * 100 + 30);
            drop(rt);
        }
        assert_eq!(s.published(), 10);
        assert_eq!(s.dropped(), 6);
        let reqs = s.requests();
        assert_eq!(reqs.len(), 4, "ring capacity bounds retained requests");
        // Oldest whole requests were dropped; survivors are complete.
        let ids: Vec<u64> = reqs.iter().map(|r| r.request_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        for r in &reqs {
            assert_eq!(r.spans.len(), 2, "whole request retained, never partial");
        }
    }

    #[test]
    fn in_flight_requests_are_not_visible_until_last_handle_drops() {
        let s = sink(1, 16, 2);
        let rt = s.begin(0).unwrap();
        rt.span(Stage::Admission, 0, 0, 5);
        let clone = Arc::clone(&rt);
        drop(rt);
        assert_eq!(s.published(), 0, "live clone still holds the buffer");
        assert!(s.requests().is_empty());
        clone.span(Stage::QueueWait, 0, 5, 9);
        drop(clone);
        assert_eq!(s.published(), 1);
        assert_eq!(s.requests()[0].spans.len(), 2);
    }

    #[test]
    fn chrome_json_round_trips_the_validator() {
        let s = sink(1, 16, 1);
        let rt = s.begin(2).unwrap();
        rt.span(Stage::Admission, 0, 100, 200);
        rt.span(Stage::QueueWait, 0, 200, 400);
        rt.span(Stage::BatchAssembly, 0, 400, 450);
        rt.record(Stage::LayerGemm, 0, 0, 4, "unrolled", 450, 500);
        rt.record(Stage::LayerGemm, 0, 1, 2, "unrolled", 500, 560);
        rt.span(Stage::Reassembly, 0, 560, 580);
        rt.span(Stage::Epilogue, 0, 580, 600);
        drop(rt);
        s.ctl_event(3, 7, 0.012, "down:0->1", 1, 1);
        let text = s.chrome_trace_json().to_string_compact();
        let check = check_trace_text(&text).unwrap();
        assert_eq!(check.requests, 1);
        assert_eq!(check.ctl_events, 1);
        assert_eq!(check.events, 8);
        assert!(check.stages.contains(&"layer_gemm"));
        assert!(!check.stages.contains(&"http_parse"));
        // Layer metadata survives the round trip.
        let v = crate::jsonio::parse(&text).unwrap();
        let evs = match v.at(&["traceEvents"]) {
            Json::Arr(a) => a,
            _ => unreachable!(),
        };
        let gemm: Vec<_> = evs
            .iter()
            .filter(|e| e.at(&["name"]).as_str() == Some("layer_gemm"))
            .collect();
        assert_eq!(gemm.len(), 2);
        assert_eq!(gemm[0].at(&["args", "layer"]).as_f64(), Some(0.0));
        assert_eq!(gemm[1].at(&["args", "bits"]).as_f64(), Some(2.0));
        assert_eq!(gemm[0].at(&["args", "variant"]).as_str(), Some("unrolled"));
    }

    #[test]
    fn validator_rejects_incomplete_requests() {
        let s = sink(1, 16, 1);
        let rt = s.begin(0).unwrap();
        rt.span(Stage::Admission, 0, 0, 10);
        drop(rt);
        let text = s.chrome_trace_json().to_string_compact();
        let err = check_trace_text(&text).unwrap_err().to_string();
        assert!(err.contains("missing stage"), "{err}");
    }

    #[test]
    fn stage_metrics_render_pinned_lines() {
        let s = sink(1, 16, 1);
        let rt = s.begin(0).unwrap();
        rt.span(Stage::QueueWait, 0, 0, 1_000_000);
        rt.span(Stage::QueueWait, 0, 0, 3_000_000);
        drop(rt);
        let mut out = String::new();
        s.render_stage_metrics(&mut out);
        assert!(out.contains("# TYPE mpq_stage_latency_seconds summary"));
        for stage in STAGES {
            assert!(
                out.contains(&format!(
                    "mpq_stage_latency_seconds_count{{stage=\"{}\"}}",
                    stage.name()
                )),
                "missing count line for {}",
                stage.name()
            );
        }
        assert!(out.contains("mpq_stage_latency_seconds_count{stage=\"queue_wait\"} 2"));
        // p99 of {1ms, 3ms} lands in the 3ms bucket.
        let p99_line = out
            .lines()
            .find(|l| l.contains("stage=\"queue_wait\",quantile=\"0.99\""))
            .unwrap();
        let v: f64 = p99_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(v > 0.002 && v < 0.004, "queue_wait p99 = {v}");
        // bucket_rep is exposed for the trace histograms — sanity.
        assert!(bucket_rep_ns(bucket_index(1000)) >= 1000.0 * 0.99);
    }
}
