//! `mpq serve` — a batched mixed-precision inference engine with a
//! deterministic load generator.
//!
//! The selection pipeline (EAGL/ALPS → knapsack → LSQ fine-tune) ends in
//! a checkpoint plus a [`crate::quant::BitsConfig`]; this subsystem is
//! what actually *serves* that pair, putting a measured requests/s and
//! latency axis behind the paper's accuracy–throughput frontier instead
//! of a proxy cost:
//!
//! ```text
//! submit(x, y) ─┬─> BatchQueue ── size/deadline micro-batches ──> worker 0 (Backend + caches)
//!               │       │  (requests > max_batch split into chunks)  worker 1 ...
//!   Ticket <────┘       └─> plan-order reassembly → softmax-CE per request → Response
//! ```
//!
//! * [`Engine`] ([`engine`]) — worker pool over one shared submission
//!   queue; each worker owns a [`crate::backend::Backend`] whose
//!   [`crate::kernels`] weight-code cache materializes quantized codes
//!   once per layer, not per request.  On the packed kernel path
//!   (`--kernel packed`, the sim serving default) the bit-packed codes
//!   ([`crate::kernels::packed`]) are materialized **once at startup**
//!   and shared across all N workers via
//!   `Backend::prepare_shared`/`adopt_shared`.  Graceful
//!   [`Engine::drain`].
//! * [`batcher`] — size/deadline-triggered micro-batching with request
//!   splitting and plan-order response reassembly.  Batching is
//!   **invisible**: responses are bit-identical at any batch
//!   composition, `max_batch`, and worker count (the module docs carry
//!   the argument; `rust/tests/serve_integration.rs` the assertions).
//!   Against direct single-request `eval_step` they are bit-identical
//!   on the reference kernels (and in per-request mode); the packed
//!   fused path is epsilon-equal with identical accuracy
//!   ([`crate::kernels::packed::PACKED_LOGIT_EPS`],
//!   `rust/tests/packed_kernels.rs`).
//! * [`metrics`] — lock-free latency histogram (p50/p95/p99),
//!   throughput and batch-occupancy counters.
//! * [`loadgen`] — deterministic seeded closed-loop/open-loop load
//!   generation over [`crate::data::Dataset`] eval batches.
//!
//! CLI: `mpq serve` (engine + loadgen + metrics report) and `mpq infer`
//! (one-shot request); `make serve-smoke` wires the whole path into
//! `make verify`.

pub mod batcher;
pub mod engine;
pub mod loadgen;
pub mod metrics;

pub use batcher::{Response, Ticket};
pub use engine::{Engine, ServeConfig, Spawner};
pub use loadgen::{LoadMode, LoadReport, LoadSpec};
pub use metrics::{Metrics, MetricsSnapshot};
