//! `mpq serve` — a batched mixed-precision inference engine with a
//! deterministic load generator.
//!
//! The selection pipeline (EAGL/ALPS → knapsack → LSQ fine-tune) ends in
//! a checkpoint plus a [`crate::quant::BitsConfig`]; this subsystem is
//! what actually *serves* that pair, putting a measured requests/s and
//! latency axis behind the paper's accuracy–throughput frontier instead
//! of a proxy cost:
//!
//! ```text
//! submit(x, y) ─┬─> BatchQueue ── size/deadline micro-batches ──> worker 0 (Backend + caches)
//!               │       │  (requests > max_batch split into chunks)  worker 1 ...
//!   Ticket <────┘       └─> plan-order reassembly → softmax-CE per request → Response
//! ```
//!
//! * [`Engine`] ([`engine`]) — worker pool over one shared submission
//!   queue; each worker owns a [`crate::backend::Backend`] whose
//!   [`crate::kernels`] weight-code cache materializes quantized codes
//!   once per layer, not per request.  On the packed kernel path
//!   (`--kernel packed`, the sim serving default) the bit-packed codes
//!   ([`crate::kernels::packed`]) are materialized **once at startup**
//!   and shared across all N workers via
//!   `Backend::prepare_shared`/`adopt_shared`.  Graceful
//!   [`Engine::drain`].
//! * [`batcher`] — size/deadline-triggered micro-batching with request
//!   splitting and plan-order response reassembly.  Batching is
//!   **invisible**: responses are bit-identical at any batch
//!   composition, `max_batch`, and worker count (the module docs carry
//!   the argument; `rust/tests/serve_integration.rs` the assertions).
//!   Against direct single-request `eval_step` they are bit-identical
//!   on the reference kernels (and in per-request mode); the packed
//!   fused path is epsilon-equal with identical accuracy
//!   ([`crate::kernels::packed::PACKED_LOGIT_EPS`],
//!   `rust/tests/packed_kernels.rs`).
//! * [`metrics`] — lock-free latency histogram (p50/p95/p99),
//!   throughput and batch-occupancy counters.
//! * [`loadgen`] — deterministic seeded closed-loop/open-loop load
//!   generation over [`crate::data::Dataset`] eval batches, in-process
//!   ([`loadgen::run`]) or over real loopback sockets
//!   ([`loadgen::run_http`]).
//! * [`http`] — the HTTP/1.1 front door (`mpq serve --listen`): std
//!   `TcpListener` acceptor, incremental request parser, lazy JSON
//!   field scanner, admission control with fail-fast `503`,
//!   per-connection backpressure, graceful drain, a stable-format
//!   `GET /metrics` endpoint, and a `POST /swap` admin hook for manual
//!   frontier steps.  Zero new dependencies.
//! * [`controller`] — the SLO-driven precision controller: epoch-
//!   versioned config hot-swap ([`Engine::swap`]) walked up and down
//!   the recorded accuracy-throughput frontier by a pure, replayable
//!   decision function, plus the deterministic sim-time degradation
//!   harness (`--degrade`) and seeded fault injection
//!   ([`loadgen::FaultPlan`]).
//!
//! * [`trace`] — per-request span tracing + hot-path stage profiler:
//!   deterministic-sampled span events over the full lifecycle (HTTP
//!   parse → admission → queue wait → batch assembly → per-layer packed
//!   GEMM → reassembly → epilogue → serialize → socket write), exported
//!   as Chrome trace-event JSON (`GET /trace`, `--trace-out`), pinned
//!   `mpq_stage_*` histogram lines on `/metrics`, and controller
//!   decision instants.
//!
//! CLI: `mpq serve` (engine + loadgen + metrics report; `--listen` for
//! the socket front door, `--target` for a pure socket client),
//! `mpq infer` (one-shot request), and `mpq trace` (validate a trace
//! file); `make serve-smoke`, `make http-smoke` and `make trace-smoke`
//! wire the paths into `make verify`.

pub mod batcher;
pub mod controller;
pub mod engine;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod trace;

pub use batcher::{Response, Ticket};
pub use controller::{
    decide, decisions_jsonl, render_log, run_degrade, Controller, CtlState, Decision,
    DegradeConfig, DegradeOutcome, FrontierStep, SimProfile, SloThresholds, Window,
};
pub use engine::{Engine, EpochInfo, EpochState, ServeConfig, Spawner};
pub use http::{HttpConfig, HttpServer, HttpStatsSnapshot, SwapRegistry};
pub use loadgen::{latency_jsonl, FaultPlan, LoadMode, LoadReport, LoadSpec};
pub use metrics::{Metrics, MetricsSnapshot};
pub use trace::{check_trace_text, Stage, TraceCheck, TraceConfig, TraceSink, STAGES};
