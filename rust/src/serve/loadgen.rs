//! Deterministic seeded load generator over [`Dataset`] inputs.
//!
//! Request *content* is a pure function of (loadgen seed, request index):
//! request `i` carries `size_i` samples (seeded RNG in
//! `1..=max_request_samples`) drawn from the dataset's eval split at a
//! dedicated index range.  Two runs with the same spec therefore submit
//! bit-identical requests — and because the engine's responses are
//! bit-identical to direct single-request evaluation at any worker count
//! or batch composition, whole load runs are reproducible end to end
//! (asserted in `rust/tests/serve_integration.rs`).
//!
//! Two arrival models:
//!
//! * **closed-loop** — `concurrency` clients, each submitting its next
//!   request only after the previous response returns (classic
//!   latency-bound serving benchmark);
//! * **open-loop** — requests submitted at a fixed rate regardless of
//!   completions (throughput/saturation benchmark), all tickets awaited
//!   at the end.
//!
//! Both models run either **in-process** ([`run`], straight into an
//! [`Engine`]) or **over real loopback sockets** ([`run_http`], against
//! an `mpq serve --listen` front door).  The request stream is identical
//! either way — over HTTP the request carries only `(index, samples)`
//! and the server materializes the same deterministic tensors from its
//! own dataset — so socket runs are bit-comparable to in-process runs
//! (asserted in `rust/tests/http_serve_integration.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::data::{Dataset, Split};
use crate::rng::{splitmix64, Pcg32};
use crate::tensor::Tensor;

use super::batcher::Response;
use super::engine::Engine;
use super::http::client::HttpClient;
use super::http::parse_infer_response;

/// Eval-split index base for loadgen batches, clear of the indices the
/// evaluation loop replays (0..eval_batches).
const LOADGEN_INDEX_BASE: u64 = 1_000;

/// Give up on a request after this many consecutive 503 sheds — bounded
/// so a permanently saturated server still fails the run loudly instead
/// of spinning forever.
const MAX_RETRIES_PER_REQUEST: usize = 32;

/// Ceiling on one backoff sleep.  Serve deployments answer `Retry-After`
/// in whole seconds; a benchmark driver that obeyed it literally would
/// measure its own sleeping, so the hint is capped here and jittered
/// below it.
const RETRY_SLEEP_CAP_S: f64 = 0.025;

/// Arrival model.
#[derive(Debug, Clone, Copy)]
pub enum LoadMode {
    /// `concurrency` clients in submit→wait loops.
    Closed { concurrency: usize },
    /// Fixed-rate submission (requests per second), waited at the end.
    Open { rate_hz: f64 },
}

/// One load run's specification.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub requests: usize,
    /// Request sizes are seeded-uniform in `1..=max_request_samples`.
    pub max_request_samples: usize,
    pub seed: u64,
    pub mode: LoadMode,
}

/// Deterministic fault injection: which requests stall a worker or carry
/// a latency spike is a **pure function of (plan seed, request index)**
/// — a seeded hash, not a clock or an RNG stream shared across threads —
/// so a fault schedule replays identically at any worker count, in both
/// the real engine (wall-clock stalls) and the controller's sim-time
/// queue model (work-unit stalls/spikes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Roughly one in `stall_every` requests stalls a worker (0 = never).
    pub stall_every: u64,
    /// Wall-clock stall in the real engine (the worker sleeps holding
    /// the batch, not the queue lock).
    pub stall_wall: Duration,
    /// The same stall expressed in sim-time work units (samples).
    pub stall_work: f64,
    /// Roughly one in `spike_every` requests carries a latency spike
    /// (0 = never).
    pub spike_every: u64,
    /// Spike size in sim-time work units.
    pub spike_work: f64,
}

impl FaultPlan {
    /// The no-fault plan (all schedules disabled).
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            stall_every: 0,
            stall_wall: Duration::ZERO,
            stall_work: 0.0,
            spike_every: 0,
            spike_work: 0.0,
        }
    }

    /// Seeded membership test: does request `index` hit a 1-in-`every`
    /// schedule?  `salt` separates the stall and spike streams.
    fn hits(&self, salt: u64, every: u64, index: u64) -> bool {
        if every == 0 {
            return false;
        }
        let mut s = self.seed ^ salt ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        splitmix64(&mut s) % every == 0
    }

    /// Whether request `index` stalls its worker (either clock).
    pub fn stalls(&self, index: u64) -> bool {
        self.hits(0x7374_616c_6c, self.stall_every, index) // "stall"
    }

    /// Wall-clock stall the real engine injects for request `index`.
    pub fn stall_wall_for(&self, index: u64) -> Duration {
        if self.stalls(index) {
            self.stall_wall
        } else {
            Duration::ZERO
        }
    }

    /// Extra sim-time work units request `index` carries in the
    /// controller's queue model (stall + spike contributions).
    pub fn sim_extra_work(&self, index: u64) -> f64 {
        let mut w = 0.0;
        if self.stalls(index) {
            w += self.stall_work;
        }
        if self.hits(0x7370_696b_65, self.spike_every, index) {
            // "spike"
            w += self.spike_work;
        }
        w
    }
}

/// Outcome of one load run.  `responses[i]` answers request `i` of the
/// deterministic request stream (request-index order — engine ids can be
/// interleaved differently across runs by closed-loop client racing, so
/// index order is what makes whole runs comparable bit for bit).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub wall_s: f64,
    pub responses: Vec<Response>,
    pub total_samples: usize,
    pub throughput_rps: f64,
    pub samples_per_s: f64,
    /// Sample-weighted classification accuracy (NaN for non-cls tasks).
    pub mean_accuracy: f64,
    /// 503-shed attempts retried after `Retry-After` backoff (HTTP
    /// closed-loop only; 0 elsewhere).
    pub retried: u64,
}

/// The deterministic per-request sample counts for a spec (seeded
/// uniform in `1..=max_request_samples`) — the part of the request
/// stream a socket client needs without a local dataset.
pub fn request_sizes(spec: &LoadSpec) -> Vec<usize> {
    let mut rng = Pcg32::new(spec.seed, 0x6c6f_6164); // "load"
    (0..spec.requests)
        .map(|_| 1 + rng.below(spec.max_request_samples as u32) as usize)
        .collect()
}

/// The eval-split dataset index request `i` draws from — shared by the
/// in-process path (which materializes tensors locally) and the HTTP
/// server (which materializes the same tensors from the wire request).
pub fn request_index(i: usize) -> u64 {
    LOADGEN_INDEX_BASE + i as u64
}

/// The deterministic request set for a spec: `(x, y)` per request.
pub fn request_set(data: &Dataset, spec: &LoadSpec) -> Vec<(Tensor, Tensor)> {
    request_sizes(spec)
        .into_iter()
        .enumerate()
        .map(|(i, size)| data.batch(Split::Eval, request_index(i), size))
        .collect()
}

/// Drive `engine` with the spec's deterministic request stream and
/// verify the serving invariants: every request answered exactly once,
/// response ids monotone and contiguous, nonzero wall time.
pub fn run(engine: &Engine, data: &Dataset, spec: &LoadSpec) -> crate::Result<LoadReport> {
    crate::ensure!(spec.requests >= 1, "loadgen: need at least one request");
    crate::ensure!(
        spec.max_request_samples >= 1,
        "loadgen: --max-request must be at least 1"
    );
    let inputs = request_set(data, spec);
    // (request index, response) pairs — collected in completion order,
    // re-sorted into request order below.
    let responses: Mutex<Vec<(usize, Response)>> = Mutex::new(Vec::with_capacity(spec.requests));
    let first_err: Mutex<Option<crate::error::Error>> = Mutex::new(None);
    let t0 = Instant::now();
    match spec.mode {
        LoadMode::Closed { concurrency } => {
            let clients = concurrency.max(1).min(spec.requests);
            std::thread::scope(|scope| {
                for ci in 0..clients {
                    let inputs = &inputs;
                    let responses = &responses;
                    let first_err = &first_err;
                    scope.spawn(move || {
                        let mut i = ci;
                        while i < inputs.len() {
                            if first_err.lock().unwrap().is_some() {
                                return;
                            }
                            let (x, y) = inputs[i].clone();
                            match engine.submit(x, y).and_then(|t| t.wait()) {
                                Ok(r) => responses.lock().unwrap().push((i, r)),
                                Err(e) => {
                                    first_err.lock().unwrap().get_or_insert(e);
                                    return;
                                }
                            }
                            i += clients;
                        }
                    });
                }
            });
        }
        LoadMode::Open { rate_hz } => {
            crate::ensure!(rate_hz > 0.0, "loadgen: --rate must be positive");
            let interval = Duration::from_secs_f64(1.0 / rate_hz);
            let mut tickets = Vec::with_capacity(spec.requests);
            for (i, (x, y)) in inputs.iter().enumerate() {
                let target = t0 + interval.mul_f64(i as f64);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                tickets.push(engine.submit(x.clone(), y.clone())?);
            }
            let mut out = responses.lock().unwrap();
            for (i, t) in tickets.into_iter().enumerate() {
                out.push((i, t.wait()?));
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    finalize(spec, wall_s, responses.into_inner().unwrap(), 0)
}

/// Drive an `mpq serve --listen` front door at `addr` (`host:port`) with
/// the same deterministic request stream as [`run`], over real TCP.
/// Requests carry only `{"index", "samples"}`; the server materializes
/// the tensors, so responses are bit-comparable to in-process runs.
/// The same serving invariants are verified (every request answered
/// exactly once, ids duplicate-free and contiguous).
pub fn run_http(addr: &str, spec: &LoadSpec) -> crate::Result<LoadReport> {
    crate::ensure!(spec.requests >= 1, "loadgen: need at least one request");
    crate::ensure!(
        spec.max_request_samples >= 1,
        "loadgen: --max-request must be at least 1"
    );
    let sizes = request_sizes(spec);
    let responses: Mutex<Vec<(usize, Response)>> = Mutex::new(Vec::with_capacity(spec.requests));
    let first_err: Mutex<Option<crate::error::Error>> = Mutex::new(None);
    let retried = AtomicU64::new(0);
    let t0 = Instant::now();
    match spec.mode {
        LoadMode::Closed { concurrency } => {
            // One socket per client, submit→wait loops striped over the
            // request stream; reconnects if the server retires the
            // connection at its keep-alive budget.  A 503 shed by the
            // admission gate is **not** terminal: the client honors
            // `Retry-After` with seeded jittered backoff (bounded
            // retries), so admission control and a closed-loop driver
            // compose instead of cascading one shed into a failed run.
            let clients = concurrency.max(1).min(spec.requests);
            std::thread::scope(|scope| {
                for ci in 0..clients {
                    let sizes = &sizes;
                    let responses = &responses;
                    let first_err = &first_err;
                    let retried = &retried;
                    scope.spawn(move || {
                        // Per-client backoff stream: seeded by (spec
                        // seed, client index) so reruns jitter
                        // identically while concurrent clients stay
                        // desynchronized.
                        let mut backoff = Pcg32::new(spec.seed ^ 0x7265_7472_79, ci as u64); // "retry"
                        let mut client = match HttpClient::connect(addr) {
                            Ok(c) => c,
                            Err(e) => {
                                first_err.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        };
                        let mut i = ci;
                        'requests: while i < sizes.len() {
                            let mut attempts = 0usize;
                            loop {
                                if first_err.lock().unwrap().is_some() {
                                    return;
                                }
                                // (response, closing): response None = a
                                // 503 shed carrying its Retry-After hint.
                                let exchange = client
                                    .post("/infer", &infer_body(i, sizes[i]))
                                    .and_then(|resp| {
                                        let closing =
                                            resp.header("connection") == Some("close");
                                        if resp.status == 503 {
                                            let ra = resp
                                                .header("retry-after")
                                                .and_then(|v| v.trim().parse::<f64>().ok())
                                                .unwrap_or(1.0);
                                            return Ok((None, closing, ra));
                                        }
                                        crate::ensure!(
                                            resp.status == 200,
                                            "loadgen: request {i}: HTTP {}: {}",
                                            resp.status,
                                            resp.body_str()
                                        );
                                        Ok((Some(parse_infer_response(&resp.body)?), closing, 0.0))
                                    });
                                let (resp, closing, retry_after_s) = match exchange {
                                    Ok(t) => t,
                                    Err(e) => {
                                        first_err.lock().unwrap().get_or_insert(e);
                                        return;
                                    }
                                };
                                if let Some(r) = &resp {
                                    responses.lock().unwrap().push((i, r.clone()));
                                }
                                let retrying = resp.is_none();
                                if retrying {
                                    attempts += 1;
                                    if attempts > MAX_RETRIES_PER_REQUEST {
                                        first_err.lock().unwrap().get_or_insert(crate::err!(
                                            "loadgen: request {i}: still shed (503) after \
                                             {MAX_RETRIES_PER_REQUEST} retries"
                                        ));
                                        return;
                                    }
                                    retried.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone retry counter; read only after the worker scope joins
                                }
                                // Reconnect when the server retired the
                                // connection and this client still has
                                // traffic (a retry or a later request).
                                if closing && (retrying || i + clients < sizes.len()) {
                                    match HttpClient::connect(addr) {
                                        Ok(c) => client = c,
                                        Err(e) => {
                                            first_err.lock().unwrap().get_or_insert(e);
                                            return;
                                        }
                                    }
                                }
                                if !retrying {
                                    i += clients;
                                    continue 'requests;
                                }
                                // The header conveys the server's intent;
                                // the sleep is capped so a shedding
                                // server can't park the driver for whole
                                // seconds, and jittered in [0.5, 1.0)× so
                                // shed clients don't return in lockstep.
                                let capped = retry_after_s.clamp(0.0, RETRY_SLEEP_CAP_S);
                                let jitter = 0.5 + 0.5 * backoff.uniform() as f64;
                                std::thread::sleep(Duration::from_secs_f64(capped * jitter));
                            }
                        }
                    });
                }
            });
        }
        LoadMode::Open { rate_hz } => {
            crate::ensure!(rate_hz > 0.0, "loadgen: --rate must be positive");
            // True open-loop arrivals need sends decoupled from receives:
            // a few connections round-robin the stream, each pipelining a
            // bounded window so a slow response can't stall the arrival
            // clock for long (and the bounded window keeps both sides'
            // socket buffers safe from deadlock).
            let interval = Duration::from_secs_f64(1.0 / rate_hz);
            let conns = 8.min(spec.requests).max(1);
            const PIPELINE_DEPTH: usize = 4;
            std::thread::scope(|scope| {
                for ci in 0..conns {
                    let sizes = &sizes;
                    let responses = &responses;
                    let first_err = &first_err;
                    scope.spawn(move || {
                        let run = || -> crate::Result<()> {
                            let mut client = HttpClient::connect(addr)?;
                            let mut outstanding: Vec<usize> = Vec::new();
                            fn recv_one(
                                client: &mut HttpClient,
                                outstanding: &mut Vec<usize>,
                                responses: &Mutex<Vec<(usize, Response)>>,
                            ) -> crate::Result<()> {
                                let i = outstanding.remove(0);
                                let resp = client.recv()?;
                                crate::ensure!(
                                    resp.status == 200,
                                    "loadgen: request {i}: HTTP {}: {}",
                                    resp.status,
                                    resp.body_str()
                                );
                                let r = parse_infer_response(&resp.body)?;
                                responses.lock().unwrap().push((i, r));
                                Ok(())
                            }
                            let mut i = ci;
                            while i < sizes.len() {
                                if first_err.lock().unwrap().is_some() {
                                    return Ok(());
                                }
                                if outstanding.len() >= PIPELINE_DEPTH {
                                    recv_one(&mut client, &mut outstanding, responses)?;
                                }
                                let target = t0 + interval.mul_f64(i as f64);
                                let now = Instant::now();
                                if target > now {
                                    std::thread::sleep(target - now);
                                }
                                client.send("POST", "/infer", Some(&infer_body(i, sizes[i])))?;
                                outstanding.push(i);
                                i += conns;
                            }
                            while !outstanding.is_empty() {
                                recv_one(&mut client, &mut outstanding, responses)?;
                            }
                            Ok(())
                        };
                        if let Err(e) = run() {
                            first_err.lock().unwrap().get_or_insert(e);
                        }
                    });
                }
            });
        }
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    finalize(
        spec,
        wall_s,
        responses.into_inner().unwrap(),
        retried.load(Ordering::Relaxed), // relaxed-ok: the scope join provides the happens-before for this read
    )
}

/// Render a load report's per-request latencies as JSONL
/// (`mpq serve --latency-out FILE`): one compact object per request in
/// request-index order, `{index, samples, epoch, latency_ns}` with keys
/// sorted.  The *shape* of the file is deterministic — indices, sample
/// counts, and (single-config runs) epochs replay exactly — while the
/// latencies themselves are wall-clock measurements and are not; pair
/// the file with the trace (`--trace-out`) when a latency outlier needs
/// a per-stage explanation.
pub fn latency_jsonl(report: &LoadReport) -> String {
    use crate::jsonio::Json;
    let mut s = String::new();
    for (i, r) in report.responses.iter().enumerate() {
        let j = Json::obj(vec![
            ("index", Json::num(i as f64)),
            ("samples", Json::num(r.samples as f64)),
            ("epoch", Json::num(r.epoch as f64)),
            ("latency_ns", Json::num((r.latency_s * 1e9).round())),
        ]);
        s.push_str(&j.to_string_compact());
        s.push('\n');
    }
    s
}

/// The `POST /infer` request body for request `i` of the stream.
fn infer_body(i: usize, samples: usize) -> Vec<u8> {
    format!("{{\"index\":{},\"samples\":{samples}}}", request_index(i)).into_bytes()
}

/// Shared tail of [`run`]/[`run_http`]: verify the serving invariants
/// and assemble the report from `(request index, response)` pairs.
fn finalize(
    spec: &LoadSpec,
    wall_s: f64,
    mut indexed: Vec<(usize, Response)>,
    retried: u64,
) -> crate::Result<LoadReport> {
    crate::ensure!(
        indexed.len() == spec.requests,
        "loadgen: {} of {} responses missing",
        spec.requests - indexed.len(),
        spec.requests
    );
    // Monotone-id invariant: the engine assigns strictly increasing ids
    // in submission order, and the loadgen is its only client here — so
    // the sorted id set must be duplicate-free and contiguous (a gap
    // means a request was lost or answered twice).
    let mut ids: Vec<u64> = indexed.iter().map(|(_, r)| r.id).collect();
    ids.sort_unstable();
    for w in ids.windows(2) {
        crate::ensure!(w[0] < w[1], "loadgen: duplicate response id {}", w[1]);
    }
    // `ids` can only be empty when `spec.requests == 0` (a degenerate
    // spec the CLI never builds) — report it instead of panicking.
    let (Some(&first), Some(&last)) = (ids.first(), ids.last()) else {
        crate::bail!("loadgen: no responses recorded (requests = {})", spec.requests);
    };
    let span = last - first + 1;
    crate::ensure!(
        span == spec.requests as u64,
        "loadgen: response ids not contiguous ({} ids over a span of {span})",
        spec.requests
    );
    indexed.sort_by_key(|(i, _)| *i);
    let responses: Vec<Response> = indexed.into_iter().map(|(_, r)| r).collect();
    let total_samples: usize = responses.iter().map(|r| r.samples).sum();
    let correct: f64 = responses
        .iter()
        .map(|r| if r.evalout.len() == 1 { r.evalout.item() as f64 } else { f64::NAN })
        .sum();
    Ok(LoadReport {
        wall_s,
        total_samples,
        throughput_rps: spec.requests as f64 / wall_s,
        samples_per_s: total_samples as f64 / wall_s,
        mean_accuracy: correct / total_samples as f64,
        retried,
        responses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Task;

    #[test]
    fn request_set_is_deterministic_and_sized() {
        let data = Dataset::for_task(Task::Cls, 7);
        let spec = LoadSpec {
            requests: 12,
            max_request_samples: 5,
            seed: 42,
            mode: LoadMode::Closed { concurrency: 2 },
        };
        let a = request_set(&data, &spec);
        let b = request_set(&data, &spec);
        assert_eq!(a.len(), 12);
        for ((xa, ya), (xb, yb)) in a.iter().zip(&b) {
            assert_eq!(xa, xb);
            assert_eq!(ya, yb);
            let n = xa.shape[0];
            assert!((1..=5).contains(&n));
            assert_eq!(ya.shape[0], n);
        }
        // A different seed shifts the size stream.
        let other = request_set(
            &data,
            &LoadSpec { seed: 43, ..spec.clone() },
        );
        assert!(
            a.iter().zip(&other).any(|((xa, _), (xo, _))| xa.shape != xo.shape),
            "different seeds should produce different request size streams"
        );
    }

    #[test]
    fn latency_jsonl_renders_request_order_with_sorted_keys() {
        let report = LoadReport {
            wall_s: 1.0,
            responses: vec![
                Response {
                    id: 1,
                    samples: 3,
                    loss: 0.5,
                    evalout: Tensor::from_f32(&[1], vec![2.0]),
                    latency_s: 0.5e-3,
                    epoch: 0,
                },
                Response {
                    id: 0,
                    samples: 1,
                    loss: 0.25,
                    evalout: Tensor::from_f32(&[1], vec![1.0]),
                    latency_s: 2e-3,
                    epoch: 1,
                },
            ],
            total_samples: 4,
            throughput_rps: 2.0,
            samples_per_s: 4.0,
            mean_accuracy: 0.75,
            retried: 0,
        };
        assert_eq!(
            latency_jsonl(&report),
            "{\"epoch\":0,\"index\":0,\"latency_ns\":500000,\"samples\":3}\n\
             {\"epoch\":1,\"index\":1,\"latency_ns\":2000000,\"samples\":1}\n"
        );
    }

    #[test]
    fn finalize_with_zero_requests_errors_instead_of_panicking() {
        let spec = LoadSpec {
            requests: 0,
            max_request_samples: 4,
            seed: 1,
            mode: LoadMode::Closed { concurrency: 1 },
        };
        let err = finalize(&spec, 0.5, Vec::new(), 0)
            .expect_err("empty response set must be reported, not unwrapped");
        let msg = format!("{err:#}");
        assert!(msg.contains("no responses recorded"), "unexpected error: {msg}");
    }

    #[test]
    fn fault_plan_is_a_pure_seeded_function_of_the_index() {
        let fp = FaultPlan {
            seed: 9,
            stall_every: 4,
            stall_wall: Duration::from_millis(1),
            stall_work: 8.0,
            spike_every: 4,
            spike_work: 5.0,
        };
        let stalls: Vec<bool> = (0..256).map(|i| fp.stalls(i)).collect();
        // Pure: the schedule replays identically.
        assert_eq!(stalls, (0..256).map(|i| fp.stalls(i)).collect::<Vec<bool>>());
        // Roughly 1-in-4 (seeded hash, not exact striding).
        let n = stalls.iter().filter(|&&h| h).count();
        assert!((16..=128).contains(&n), "1-in-4 over 256 requests hit {n} times");
        // Seed moves the schedule.
        let other = FaultPlan { seed: 10, ..fp };
        assert_ne!((0..256).map(|i| other.stalls(i)).collect::<Vec<bool>>(), stalls);
        // Stall and spike streams are salted apart, so per-index sim work
        // is one of the four combinations — and never negative.
        for i in 0..256 {
            let w = fp.sim_extra_work(i);
            assert!(
                [0.0, 5.0, 8.0, 13.0].contains(&w),
                "unexpected sim work {w} at index {i}"
            );
            if fp.stalls(i) {
                assert_eq!(fp.stall_wall_for(i), Duration::from_millis(1));
                assert!(w >= 8.0);
            } else {
                assert_eq!(fp.stall_wall_for(i), Duration::ZERO);
            }
        }
        // The disabled plan never fires.
        let none = FaultPlan::none();
        assert!((0..256).all(|i| !none.stalls(i) && none.sim_extra_work(i) == 0.0));
    }
}
