//! Lock-free serving metrics: a log-scaled latency histogram plus
//! throughput/batching counters.
//!
//! Every recorder is a relaxed atomic — workers and completion paths
//! never contend on a lock to account a request, so metrics cost nothing
//! on the hot path.  The histogram uses power-of-two octaves with 4
//! sub-buckets each (HDR-style, ≤ ~12% relative quantization error),
//! covering 1 ns .. ~2⁶³ ns; quantiles are read by walking cumulative
//! counts and reporting the bucket's geometric midpoint.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::jsonio::Json;

/// Sub-buckets per power-of-two octave.
const SUBS: usize = 4;
/// 4 exact buckets for 0..4 ns + 62 octaves × SUBS.  Shared with the
/// per-stage histograms in [`crate::serve::trace`].
pub(crate) const N_BUCKETS: usize = 4 + 62 * SUBS;

/// Histogram bucket index for a latency in nanoseconds.
pub(crate) fn bucket_index(ns: u64) -> usize {
    if ns < 4 {
        return ns as usize;
    }
    let exp = 63 - ns.leading_zeros() as usize; // floor(log2), >= 2
    let sub = ((ns >> (exp - 2)) & 0b11) as usize;
    (4 + (exp - 2) * SUBS + sub).min(N_BUCKETS - 1)
}

/// Representative latency (ns) of a bucket: its geometric midpoint.
pub(crate) fn bucket_rep_ns(idx: usize) -> f64 {
    if idx < 4 {
        return idx as f64;
    }
    let exp = (idx - 4) / SUBS + 2;
    let sub = (idx - 4) % SUBS;
    let quarter = (1u64 << exp) as f64 / 4.0;
    (1u64 << exp) as f64 + (sub as f64 + 0.5) * quarter
}

/// Shared, lock-free serving metrics (one per [`crate::serve::Engine`]).
pub struct Metrics {
    buckets: Vec<AtomicU64>,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    samples: AtomicU64,
    batches: AtomicU64,
    batch_samples: AtomicU64,
    batch_chunks: AtomicU64,
    lat_sum_ns: AtomicU64,
    lat_min_ns: AtomicU64,
    lat_max_ns: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_samples: AtomicU64::new(0),
            batch_chunks: AtomicU64::new(0),
            lat_sum_ns: AtomicU64::new(0),
            lat_min_ns: AtomicU64::new(u64::MAX),
            lat_max_ns: AtomicU64::new(0),
        }
    }

    pub fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone counter; snapshot tearing acceptable
    }

    /// One request completed successfully after `latency`.
    pub fn record_request(&self, samples: u64, latency: std::time::Duration) {
        let ns = latency.as_nanos().min(u64::MAX as u128) as u64;
        self.completed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone counter; snapshot tearing acceptable
        self.samples.fetch_add(samples, Ordering::Relaxed); // relaxed-ok: monotone counter; snapshot tearing acceptable
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed); // relaxed-ok: monotone latency sum; snapshot tearing acceptable
        self.lat_min_ns.fetch_min(ns, Ordering::Relaxed); // relaxed-ok: running min; commutative update needs no ordering
        self.lat_max_ns.fetch_max(ns, Ordering::Relaxed); // relaxed-ok: running max; commutative update needs no ordering
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone histogram bucket; snapshot tearing acceptable
    }

    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone counter; snapshot tearing acceptable
    }

    /// One micro-batch dispatched to a worker: `chunks` request chunks
    /// totalling `samples` samples.
    pub fn record_batch(&self, chunks: u64, samples: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone batch counter; snapshot tearing acceptable
        self.batch_chunks.fetch_add(chunks, Ordering::Relaxed); // relaxed-ok: monotone batch counter; snapshot tearing acceptable
        self.batch_samples.fetch_add(samples, Ordering::Relaxed); // relaxed-ok: monotone batch counter; snapshot tearing acceptable
    }

    /// Latency quantile (`q` in [0,1]) from the histogram; NaN when no
    /// request completed yet.
    fn quantile(&self, counts: &[u64], q: f64) -> f64 {
        quantile_from_counts(counts, q)
    }

    /// Raw histogram bucket counts (cumulative since startup).  Consumers
    /// that want a **windowed** quantile — e.g. the SLO controller —
    /// subtract a previous snapshot element-wise and feed the delta to
    /// [`quantile_from_counts`].
    pub fn latency_buckets(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect() // relaxed-ok: reporting-only bucket loads; staleness acceptable
    }

    /// Consistent point-in-time view (individual counters are relaxed, so
    /// a snapshot taken mid-flight can be off by in-flight requests; after
    /// [`crate::serve::Engine::drain`] it is exact).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(); // relaxed-ok: reporting-only bucket loads; staleness acceptable
        let completed = self.completed.load(Ordering::Relaxed); // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
        let sum_ns = self.lat_sum_ns.load(Ordering::Relaxed); // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
        let min_ns = self.lat_min_ns.load(Ordering::Relaxed); // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            completed,
            failed: self.failed.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            samples: self.samples.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            batches: self.batches.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            batch_chunks: self.batch_chunks.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            batch_samples: self.batch_samples.load(Ordering::Relaxed), // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            mean_latency_s: if completed > 0 {
                sum_ns as f64 / completed as f64 / 1e9
            } else {
                f64::NAN
            },
            min_latency_s: if min_ns == u64::MAX { f64::NAN } else { min_ns as f64 / 1e9 },
            max_latency_s: self.lat_max_ns.load(Ordering::Relaxed) as f64 / 1e9, // relaxed-ok: reporting-only snapshot load; per-field tearing acceptable
            p50_s: self.quantile(&counts, 0.50),
            p95_s: self.quantile(&counts, 0.95),
            p99_s: self.quantile(&counts, 0.99),
        }
    }
}

/// Latency quantile (`q` in [0,1], seconds) over raw histogram bucket
/// counts — [`Metrics::latency_buckets`] totals or a window delta of two
/// of them.  NaN when the counts are empty (an empty window is "no
/// signal", not "zero latency").  The rank is `ceil(q·total)` clamped to
/// `[1, total]`, identical to the snapshot quantiles.
pub fn quantile_from_counts(counts: &[u64], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return bucket_rep_ns(i) / 1e9;
        }
    }
    bucket_rep_ns(N_BUCKETS - 1) / 1e9
}

/// Point-in-time metrics view (see [`Metrics::snapshot`]).
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// Total samples across completed requests.
    pub samples: u64,
    /// Micro-batches dispatched to workers.
    pub batches: u64,
    /// Request chunks across all batches.
    pub batch_chunks: u64,
    /// Samples across all batches (= samples once drained).
    pub batch_samples: u64,
    pub mean_latency_s: f64,
    pub min_latency_s: f64,
    pub max_latency_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl MetricsSnapshot {
    /// Mean samples per dispatched micro-batch.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            return f64::NAN;
        }
        self.batch_samples as f64 / self.batches as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("samples", Json::num(self.samples as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("batch_chunks", Json::num(self.batch_chunks as f64)),
            ("batch_samples", Json::num(self.batch_samples as f64)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("min_latency_s", Json::num(self.min_latency_s)),
            ("max_latency_s", Json::num(self.max_latency_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("p99_s", Json::num(self.p99_s)),
        ])
    }

    /// Render the engine section of `GET /metrics` in Prometheus text
    /// style.  **Stable format** — field names, `# HELP`/`# TYPE`
    /// comments, and order are pinned by the golden test in
    /// `rust/tests/http_serve_integration.rs`; only ever append lines.
    /// `uptime_s` doubles as the throughput window (requests completed /
    /// uptime).
    pub fn render_prometheus(&self, out: &mut String, uptime_s: f64) {
        let throughput = if uptime_s > 0.0 {
            self.completed as f64 / uptime_s
        } else {
            0.0
        };
        family(
            out,
            "mpq_engine_requests_submitted_total",
            "counter",
            "Requests accepted into the batch queue.",
        );
        out.push_str(&format!(
            "mpq_engine_requests_submitted_total {}\n",
            self.submitted
        ));
        family(
            out,
            "mpq_engine_requests_completed_total",
            "counter",
            "Requests completed successfully.",
        );
        out.push_str(&format!(
            "mpq_engine_requests_completed_total {}\n",
            self.completed
        ));
        family(
            out,
            "mpq_engine_requests_failed_total",
            "counter",
            "Requests that failed inside the engine.",
        );
        out.push_str(&format!("mpq_engine_requests_failed_total {}\n", self.failed));
        family(
            out,
            "mpq_engine_samples_total",
            "counter",
            "Samples across completed requests.",
        );
        out.push_str(&format!("mpq_engine_samples_total {}\n", self.samples));
        family(
            out,
            "mpq_engine_batches_total",
            "counter",
            "Micro-batches dispatched to workers.",
        );
        out.push_str(&format!("mpq_engine_batches_total {}\n", self.batches));
        family(
            out,
            "mpq_engine_batch_chunks_total",
            "counter",
            "Request chunks across all dispatched batches.",
        );
        out.push_str(&format!(
            "mpq_engine_batch_chunks_total {}\n",
            self.batch_chunks
        ));
        family(
            out,
            "mpq_engine_batch_samples_total",
            "counter",
            "Samples across all dispatched batches.",
        );
        out.push_str(&format!(
            "mpq_engine_batch_samples_total {}\n",
            self.batch_samples
        ));
        family(
            out,
            "mpq_engine_batch_occupancy_mean",
            "gauge",
            "Mean samples per dispatched micro-batch.",
        );
        out.push_str(&format!(
            "mpq_engine_batch_occupancy_mean {}\n",
            self.mean_occupancy()
        ));
        family(
            out,
            "mpq_engine_throughput_rps",
            "gauge",
            "Completed requests per second of uptime.",
        );
        out.push_str(&format!("mpq_engine_throughput_rps {throughput}\n"));
        family(
            out,
            "mpq_engine_latency_seconds_mean",
            "gauge",
            "Mean request latency.",
        );
        out.push_str(&format!(
            "mpq_engine_latency_seconds_mean {}\n",
            self.mean_latency_s
        ));
        family(
            out,
            "mpq_engine_latency_seconds_min",
            "gauge",
            "Minimum request latency.",
        );
        out.push_str(&format!(
            "mpq_engine_latency_seconds_min {}\n",
            self.min_latency_s
        ));
        family(
            out,
            "mpq_engine_latency_seconds_max",
            "gauge",
            "Maximum request latency.",
        );
        out.push_str(&format!(
            "mpq_engine_latency_seconds_max {}\n",
            self.max_latency_s
        ));
        family(
            out,
            "mpq_engine_latency_seconds",
            "summary",
            "Request latency quantiles from the lock-free histogram.",
        );
        out.push_str(&format!(
            "mpq_engine_latency_seconds{{quantile=\"0.5\"}} {}\n",
            self.p50_s
        ));
        out.push_str(&format!(
            "mpq_engine_latency_seconds{{quantile=\"0.95\"}} {}\n",
            self.p95_s
        ));
        out.push_str(&format!(
            "mpq_engine_latency_seconds{{quantile=\"0.99\"}} {}\n",
            self.p99_s
        ));
        family(
            out,
            "mpq_engine_uptime_seconds",
            "gauge",
            "Seconds since the engine metrics window opened.",
        );
        out.push_str(&format!("mpq_engine_uptime_seconds {uptime_s}\n"));
    }
}

/// Append the `# HELP`/`# TYPE` header for one metric family (shared by
/// every `/metrics` section — engine here, http/ctl in
/// [`crate::serve::http`], stages in [`crate::serve::trace`]).
pub(crate) fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n"));
    out.push_str(&format!("# TYPE {name} {kind}\n"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for exp in 0..60u32 {
            let ns = 1u64 << exp;
            for probe in [ns, ns + ns / 4, ns + ns / 2] {
                let i = bucket_index(probe);
                assert!(i < N_BUCKETS);
                assert!(i >= prev, "index must not decrease: {probe} -> {i} < {prev}");
                prev = i;
            }
        }
        // Representative value lies within ~25% of the probed latency.
        for &ns in &[5u64, 123, 999, 1_000_000, 77_000_000_000] {
            let rep = bucket_rep_ns(bucket_index(ns));
            assert!(
                rep >= ns as f64 * 0.99 && rep <= ns as f64 * 1.26,
                "rep {rep} vs {ns}"
            );
        }
    }

    #[test]
    fn snapshot_counts_and_quantile_ordering() {
        let m = Metrics::new();
        assert!(m.snapshot().p50_s.is_nan());
        m.record_submitted();
        m.record_submitted();
        m.record_batch(2, 3);
        m.record_request(1, Duration::from_micros(100));
        m.record_request(2, Duration::from_micros(900));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.samples, 3);
        assert_eq!(s.batches, 1);
        assert!((s.mean_occupancy() - 3.0).abs() < 1e-12);
        assert!(s.min_latency_s <= s.p50_s + 1e-12);
        assert!(s.p50_s <= s.p95_s + 1e-12);
        assert!(s.p95_s <= s.p99_s + 1e-12);
        assert!(s.p99_s <= s.max_latency_s * 1.26);
        assert!(s.mean_latency_s > 0.0);
    }

    /// Regression: small histograms must never report the top bucket
    /// (~2⁶³ ns) for a valid quantile.  With a single recorded sample,
    /// `rank = ceil(q·total)` clamped to `[1, total]` is 1 for every q,
    /// so p50/p95/p99 all land in the sample's own bucket — a truncating
    /// or un-clamped rank (`q·total` rounding above the cumulative
    /// total) instead fell through the walk to `bucket_rep_ns(N_BUCKETS
    /// - 1)`.
    #[test]
    fn single_sample_quantiles_report_its_bucket_not_the_max() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_request(1, Duration::from_micros(100));
        let s = m.snapshot();
        let own_bucket_s = bucket_rep_ns(bucket_index(100_000)) / 1e9;
        for (name, q) in [("p50", s.p50_s), ("p95", s.p95_s), ("p99", s.p99_s)] {
            assert_eq!(
                q.to_bits(),
                own_bucket_s.to_bits(),
                "{name} of a one-sample histogram must be the sample's bucket, got {q}"
            );
            assert!(q < 1.0, "{name} reported {q}s for a 100µs sample (max-bucket fall-through)");
        }
        // NaN stays reserved for the genuinely empty histogram.
        assert!(Metrics::new().snapshot().p99_s.is_nan());
    }

    /// The controller's windowed-p99 primitive: subtracting an earlier
    /// bucket snapshot isolates the requests recorded in between, and an
    /// empty window reads NaN rather than a stale or zero latency.
    #[test]
    fn bucket_delta_quantile_sees_only_the_window() {
        let m = Metrics::new();
        m.record_request(1, Duration::from_micros(10));
        let before = m.latency_buckets();
        assert!(quantile_from_counts(
            &before
                .iter()
                .zip(before.iter())
                .map(|(a, b)| a - b)
                .collect::<Vec<_>>(),
            0.99
        )
        .is_nan());
        m.record_request(1, Duration::from_millis(50));
        let after = m.latency_buckets();
        let delta: Vec<u64> = after.iter().zip(before.iter()).map(|(a, b)| a - b).collect();
        let p99 = quantile_from_counts(&delta, 0.99);
        // Only the 50 ms request is in the window; the old 10 µs one must
        // not drag the quantile down.
        assert!(p99 > 0.04 && p99 < 0.07, "windowed p99 = {p99}");
        let p99_all = quantile_from_counts(&after, 0.99);
        assert_eq!(p99_all.to_bits(), p99.to_bits(), "2-sample p99 is the slow bucket");
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_request(4, Duration::from_millis(2));
        let v = m.snapshot().to_json();
        let parsed = crate::jsonio::parse(&v.to_string_compact()).unwrap();
        assert_eq!(parsed.at(&["completed"]).as_usize(), Some(1));
        assert_eq!(parsed.at(&["samples"]).as_usize(), Some(4));
    }
}
