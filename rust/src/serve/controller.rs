//! SLO-driven precision controller: the paper's accuracy-throughput
//! frontier made operational.
//!
//! The sweep store records, per model, the best (method, budget) points
//! on the accuracy-throughput frontier.  This module closes the loop at
//! serving time: a tick-driven controller watches windowed p99 latency
//! and queue depth, and when the SLO is violated walks the live config
//! **down** the frontier (cheaper bits — lower `gbops`, bounded accuracy
//! loss, exactly the trade the frontier record quantifies) via
//! [`super::Engine::swap`], then back **up** when pressure clears.
//!
//! ## Determinism
//!
//! Every decision is a pure function of `(window snapshot, controller
//! state, thresholds)` — see [`decide`].  Two execution harnesses feed
//! it:
//!
//! * [`run_degrade`] — **sim-time**: arrivals come from a seeded
//!   [`SimProfile`] rate schedule, service from a queue model whose
//!   capacity scales with the active level's recorded `gbops` (cheaper
//!   bits genuinely serve faster, the paper's premise), and faults from a
//!   [`FaultPlan`].  No wall clock enters the model, so the decision log
//!   is **byte-identical** across reruns, worker counts, and kernels —
//!   while the real engine runs alongside, answering every request under
//!   its admission epoch, which the driver verifies.
//! * [`Controller::tick`] — **live**: the same `decide` over windowed
//!   p99 from the engine's histogram (bucket-delta quantiles) and the
//!   real queue-depth gauge.  Wall-clock feeds make this one
//!   non-deterministic by nature; the hermetic tests pin the sim path.
//!
//! ## Hysteresis (no flapping)
//!
//! Overload requires *strictly* exceeding a threshold (`p99 > slo` or
//! `queue > queue_high`), recovery requires dropping *below* a distinct
//! low watermark (`p99 < slo·recover_frac` and `queue <= queue_low`),
//! and any swap starts a cooldown of N ticks during which the controller
//! holds.  Load sitting exactly on a threshold therefore changes
//! nothing — pinned by a unit test below.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::ckpt::Checkpoint;
use crate::data::{Dataset, Split};

use super::batcher::Response;
use super::engine::Engine;
use super::loadgen::{self, FaultPlan, LoadMode, LoadSpec};
use super::metrics::quantile_from_counts;

/// One step of the loaded frontier: a fully materialized serving config
/// plus the sweep-store facts that justify choosing it.  Level 0 is the
/// most accurate (highest budget); higher levels are cheaper.
#[derive(Clone)]
pub struct FrontierStep {
    pub budget_frac: f64,
    pub method: String,
    /// Recorded eval metric of this config — the accuracy bound a
    /// downgrade to this level inherits from the sweep.
    pub metric: f64,
    /// Recorded GBOPs of this config — the sim-time cost model, and the
    /// reason stepping down helps at all.
    pub gbops: f64,
    pub ckpt: Checkpoint,
    /// Per-layer precision vector (`BitsConfig::to_f32`).
    pub bits: Vec<f32>,
}

impl FrontierStep {
    /// Display tag used as the epoch label ("eagl@0.60").
    pub fn label(&self) -> String {
        format!("{}@{:.2}", self.method, self.budget_frac)
    }
}

/// Controller thresholds.  All of them surface as CLI flags; in sim mode
/// latencies are measured in ticks (1 tick ≙ 1 ms of the `--slo-p99-ms`
/// flag), in live mode in seconds.
#[derive(Debug, Clone, Copy)]
pub struct SloThresholds {
    /// Windowed-p99 ceiling.  Strictly above ⇒ overload.
    pub slo_p99: f64,
    /// Recovery low watermark as a fraction of `slo_p99`: stepping back
    /// up needs `p99 < slo_p99 * recover_frac` (hysteresis).
    pub recover_frac: f64,
    /// Queue-depth (samples) ceiling.  Strictly above ⇒ overload.
    pub queue_high: usize,
    /// Recovery needs queue depth at or below this.
    pub queue_low: usize,
    /// Ticks the controller holds after any swap before it may swap
    /// again.
    pub cooldown_ticks: u32,
    /// Never step down to a frontier level whose budget is below this —
    /// the operator's accuracy floor.
    pub floor_budget: f64,
}

impl Default for SloThresholds {
    fn default() -> SloThresholds {
        SloThresholds {
            slo_p99: 6.0,
            recover_frac: 0.5,
            queue_high: 64,
            queue_low: 8,
            cooldown_ticks: 3,
            floor_budget: 0.0,
        }
    }
}

/// One tick's observation window.
#[derive(Debug, Clone, Copy)]
pub struct Window {
    /// Windowed p99 (ticks in sim mode, seconds live); NaN when nothing
    /// completed in the window — treated as "no latency signal", which
    /// can never trip the overload test on its own.
    pub p99: f64,
    /// Queued samples not yet claimed by a worker.
    pub queue_depth: usize,
}

/// Why the controller held this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HoldReason {
    /// A recent swap's cooldown is still running.
    Cooldown,
    /// Neither the overload nor the recovery predicate fired.
    Steady,
    /// Overloaded but already at the cheapest level the floor allows.
    AtFloor,
    /// Calm and already at the most accurate level.
    AtTop,
}

/// The controller's verdict for one tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Hold(HoldReason),
    /// Step to a cheaper level (`to = from + 1`).
    Down { from: usize, to: usize },
    /// Step to a more accurate level (`to = from - 1`).
    Up { from: usize, to: usize },
}

impl Decision {
    /// The level a swap decision targets (None for holds).
    pub fn target(&self) -> Option<usize> {
        match self {
            Decision::Hold(_) => None,
            Decision::Down { to, .. } | Decision::Up { to, .. } => Some(*to),
        }
    }

    /// Stable log token ("hold:steady", "down:0->1", "up:2->1").
    pub fn render(&self) -> String {
        match self {
            Decision::Hold(HoldReason::Cooldown) => "hold:cooldown".to_string(),
            Decision::Hold(HoldReason::Steady) => "hold:steady".to_string(),
            Decision::Hold(HoldReason::AtFloor) => "hold:at-floor".to_string(),
            Decision::Hold(HoldReason::AtTop) => "hold:at-top".to_string(),
            Decision::Down { from, to } => format!("down:{from}->{to}"),
            Decision::Up { from, to } => format!("up:{from}->{to}"),
        }
    }
}

/// Mutable controller state threaded between ticks.
#[derive(Debug, Clone, Copy)]
pub struct CtlState {
    /// Active frontier level (index into the loaded frontier).
    pub level: usize,
    /// Remaining cooldown ticks (0 = may swap).
    pub cooldown: u32,
}

impl CtlState {
    pub fn new(level: usize) -> CtlState {
        CtlState { level, cooldown: 0 }
    }
}

/// The decision function — **pure** in (thresholds, frontier budgets,
/// state, window), so a recorded decision log replays exactly.
///
/// Predicates (note the strict inequalities — the hysteresis band):
///
/// * overload ⇔ `p99 > slo_p99` (finite p99 only) **or**
///   `queue_depth > queue_high`;
/// * calm ⇔ `queue_depth <= queue_low` **and** (`p99` has no signal or
///   `p99 < slo_p99 * recover_frac`).
///
/// Cooldown wins over everything; overload steps down one level unless
/// the next level would break the budget floor; calm steps up one level
/// unless already at the top; anything in between holds steady.
pub fn decide(th: &SloThresholds, budgets: &[f64], st: &CtlState, w: &Window) -> Decision {
    if st.cooldown > 0 {
        return Decision::Hold(HoldReason::Cooldown);
    }
    let overload =
        (w.p99.is_finite() && w.p99 > th.slo_p99) || w.queue_depth > th.queue_high;
    if overload {
        let to = st.level + 1;
        if to >= budgets.len() || budgets[to] < th.floor_budget {
            return Decision::Hold(HoldReason::AtFloor);
        }
        return Decision::Down { from: st.level, to };
    }
    let calm = w.queue_depth <= th.queue_low
        && (!w.p99.is_finite() || w.p99 < th.slo_p99 * th.recover_frac);
    if calm {
        if st.level == 0 {
            return Decision::Hold(HoldReason::AtTop);
        }
        return Decision::Up { from: st.level, to: st.level - 1 };
    }
    Decision::Hold(HoldReason::Steady)
}

/// Fold a decision into the controller state: swaps move the level and
/// start the cooldown, holds run the cooldown out.
pub fn apply(st: &mut CtlState, d: &Decision, cooldown_ticks: u32) {
    match d {
        Decision::Down { to, .. } | Decision::Up { to, .. } => {
            st.level = *to;
            st.cooldown = cooldown_ticks;
        }
        Decision::Hold(_) => st.cooldown = st.cooldown.saturating_sub(1),
    }
}

/// One line of the decision log.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    pub tick: u64,
    pub queue_depth: usize,
    /// Windowed p99 in the harness's units (ticks / seconds).
    pub p99: f64,
    pub decision: Decision,
    /// Frontier level after the decision was applied.
    pub level: usize,
    /// Serving epoch after the decision was applied.
    pub epoch: u64,
}

/// Render a decision log in its canonical byte-stable text form (the
/// form the determinism tests compare across reruns, worker counts, and
/// kernels).  f64 formatting in Rust is shortest-round-trip and the sim
/// p99 is an exact integer rank statistic, so the text is reproducible
/// byte for byte.
pub fn render_log(log: &[DecisionRecord]) -> String {
    let mut s = String::new();
    for r in log {
        s.push_str(&format!(
            "tick={} q={} p99={:?} {} level={} epoch={}\n",
            r.tick,
            r.queue_depth,
            r.p99,
            r.decision.render(),
            r.level,
            r.epoch
        ));
    }
    s
}

/// Render a decision log as structured JSONL: one compact JSON object
/// per line, keys sorted (BTreeMap), ints rendered without a fraction,
/// and a windowless p99 (NaN) rendered as `null` — all deterministic,
/// so the sim-time log (`--degrade --decision-log`) is **byte-identical**
/// across reruns, worker counts, and kernels, same as [`render_log`].
pub fn decisions_jsonl(log: &[DecisionRecord]) -> String {
    use crate::jsonio::Json;
    let mut s = String::new();
    for r in log {
        let j = Json::obj(vec![
            ("tick", Json::num(r.tick as f64)),
            ("queue_depth", Json::num(r.queue_depth as f64)),
            ("p99", Json::num(r.p99)),
            ("decision", Json::str(&r.decision.render())),
            ("level", Json::num(r.level as f64)),
            ("epoch", Json::num(r.epoch as f64)),
        ]);
        s.push_str(&j.to_string_compact());
        s.push('\n');
    }
    s
}

/// A seeded open-loop rate schedule in sim time: a sequence of phases,
/// each `ticks` long at `rate` requests/tick (fractional rates carry a
/// remainder accumulator across ticks).
#[derive(Debug, Clone)]
pub struct SimProfile {
    pub name: String,
    pub phases: Vec<(u64, f64)>,
}

impl SimProfile {
    /// A named profile (`quiet`, `ramp`, `spike`) or a custom spec of
    /// `TICKSxRATE` phases, comma-separated (e.g. `"20x1,10x8,40x1"`).
    pub fn named(name: &str) -> crate::Result<SimProfile> {
        let phases: Vec<(u64, f64)> = match name {
            "quiet" => vec![(40, 1.0)],
            "ramp" => vec![(10, 1.0), (10, 3.0), (10, 6.0), (15, 10.0), (45, 1.0)],
            "spike" => vec![(10, 1.0), (18, 10.0), (52, 1.0)],
            custom => {
                let mut out = Vec::new();
                for part in custom.split(',') {
                    let (t, r) = part
                        .split_once('x')
                        .ok_or_else(|| {
                            crate::err!(
                                "bad profile '{custom}': want quiet|ramp|spike or \
                                 TICKSxRATE[,TICKSxRATE...]"
                            )
                        })?;
                    let ticks: u64 = t
                        .trim()
                        .parse()
                        .map_err(|_| crate::err!("bad profile phase '{part}': ticks"))?;
                    let rate: f64 = r
                        .trim()
                        .parse()
                        .map_err(|_| crate::err!("bad profile phase '{part}': rate"))?;
                    crate::ensure!(
                        ticks > 0 && rate >= 0.0 && rate.is_finite(),
                        "bad profile phase '{part}': need ticks > 0 and finite rate >= 0"
                    );
                    out.push((ticks, rate));
                }
                crate::ensure!(!out.is_empty(), "empty profile '{custom}'");
                out
            }
        };
        Ok(SimProfile { name: name.to_string(), phases })
    }

    /// Deterministic arrivals per tick over the whole profile (the
    /// fractional-rate accumulator makes e.g. rate 0.5 arrive every
    /// other tick).
    pub fn arrivals_per_tick(&self) -> Vec<usize> {
        let mut out = Vec::new();
        let mut acc = 0.0f64;
        for &(ticks, rate) in &self.phases {
            for _ in 0..ticks {
                acc += rate;
                let n = acc.floor();
                acc -= n;
                out.push(n as usize);
            }
        }
        out
    }
}

/// Configuration of one sim-time degradation run.
#[derive(Clone)]
pub struct DegradeConfig {
    pub thresholds: SloThresholds,
    pub profile: SimProfile,
    pub fault: FaultPlan,
    /// Loadgen seed: request sizes (and thereby per-request sim work)
    /// come from the same seeded stream [`loadgen::request_sizes`] uses.
    pub seed: u64,
    pub max_request_samples: usize,
    /// Work units (samples) the modeled server retires per tick at
    /// frontier level 0; higher levels scale it by their recorded
    /// `gbops` advantage.
    pub capacity_per_tick: f64,
    /// Completions within this many ticks feed the windowed p99.
    pub window_ticks: u64,
    /// Extra ticks past the profile to let the backlog drain and the
    /// controller recover before the run stops.
    pub drain_ticks_max: u64,
}

impl DegradeConfig {
    pub fn new(profile: SimProfile) -> DegradeConfig {
        DegradeConfig {
            thresholds: SloThresholds::default(),
            profile,
            fault: FaultPlan::none(),
            seed: 42,
            max_request_samples: 4,
            capacity_per_tick: 8.0,
            window_ticks: 8,
            drain_ticks_max: 200,
        }
    }
}

/// Outcome of a [`run_degrade`] run.
pub struct DegradeOutcome {
    pub log: Vec<DecisionRecord>,
    /// [`render_log`] of `log` — the byte-comparable artifact.
    pub log_text: String,
    pub swaps_down: usize,
    pub swaps_up: usize,
    pub requests: usize,
    /// Frontier level serving each epoch (`epoch_levels[e]` = level of
    /// epoch `e`; epoch 0 is the startup config at level 0).
    pub epoch_levels: Vec<usize>,
    /// `(expected epoch, response)` per request, in stream order.  The
    /// driver has already verified `response.epoch` matches.
    pub responses: Vec<(u64, Response)>,
}

/// Drive a full "overload → degrade → recover" sequence: a sim-time
/// queue model paces the controller deterministically while the **real**
/// engine serves the identical request stream and hot-swaps on every
/// controller decision.
///
/// The engine must be freshly started on `frontier[0]`'s config (epoch
/// 0); the driver is its only submitter and swapper, so engine request
/// ids equal stream indices and the epoch sequence is exactly the
/// decision log's.
///
/// Zero-drop guarantee checked here: every submitted request is answered
/// under precisely the epoch that admitted it.
pub fn run_degrade(
    engine: &Engine,
    data: &Dataset,
    frontier: &[FrontierStep],
    cfg: &DegradeConfig,
) -> crate::Result<DegradeOutcome> {
    crate::ensure!(!frontier.is_empty(), "degrade: empty frontier");
    crate::ensure!(
        engine.current_epoch() == 0,
        "degrade: engine must be freshly started on frontier level 0"
    );
    crate::ensure!(cfg.capacity_per_tick > 0.0, "degrade: capacity must be positive");
    let budgets: Vec<f64> = frontier.iter().map(|s| s.budget_frac).collect();
    let arrivals = cfg.profile.arrivals_per_tick();
    let total: usize = arrivals.iter().sum();
    crate::ensure!(total >= 1, "degrade: profile '{}' admits no requests", cfg.profile.name);
    let spec = LoadSpec {
        requests: total,
        max_request_samples: cfg.max_request_samples,
        seed: cfg.seed,
        mode: LoadMode::Closed { concurrency: 1 },
    };
    let sizes = loadgen::request_sizes(&spec);

    // Sim queue model: (arrival tick, remaining work) FIFO.  Work is the
    // request's sample count plus any injected fault work; capacity per
    // tick scales with the active level's recorded gbops advantage —
    // cheaper bits retire the backlog faster, which is the entire point
    // of stepping down the frontier.
    let mut simq: VecDeque<(u64, f64)> = VecDeque::new();
    let mut window: VecDeque<(u64, u64)> = VecDeque::new(); // (completion tick, latency)
    let mut st = CtlState::new(0);
    let mut cur_epoch = engine.current_epoch();
    let mut epoch_levels = vec![0usize];
    let mut tickets = Vec::with_capacity(total);
    let mut log = Vec::new();
    let (mut swaps_down, mut swaps_up) = (0usize, 0usize);
    let mut next = 0usize; // next stream index to submit
    let profile_ticks = arrivals.len() as u64;
    let mut tick = 0u64;
    loop {
        // 1. Arrivals: submit to the real engine and enqueue in the model.
        let n_arrive = if tick < profile_ticks { arrivals[tick as usize] } else { 0 };
        for _ in 0..n_arrive {
            let size = sizes[next];
            let (x, y) = data.batch(Split::Eval, loadgen::request_index(next), size);
            let t = engine.submit(x, y)?;
            crate::ensure!(
                t.id() == next as u64,
                "degrade: engine id {} != stream index {next} (single-submitter invariant)",
                t.id()
            );
            tickets.push((cur_epoch, t));
            simq.push_back((tick, size as f64 + cfg.fault.sim_extra_work(next as u64)));
            next += 1;
        }
        // 2. Service: retire work FIFO at the level-scaled capacity.
        let speedup = frontier[0].gbops / frontier[st.level].gbops;
        let mut cap = cfg.capacity_per_tick * speedup.max(1.0);
        while cap > 0.0 {
            let Some(front) = simq.front_mut() else { break };
            if front.1 <= cap {
                cap -= front.1;
                let arrived = front.0;
                let _ = simq.pop_front();
                window.push_back((tick, tick - arrived + 1));
            } else {
                front.1 -= cap;
                break;
            }
        }
        while window.front().is_some_and(|&(done, _)| done + cfg.window_ticks <= tick) {
            window.pop_front();
        }
        // 3. Observe → decide → (maybe) swap the real engine.
        let queue_depth = simq.iter().map(|&(_, w)| w).sum::<f64>().ceil() as usize;
        let p99 = {
            let mut lats: Vec<u64> = window.iter().map(|&(_, l)| l).collect();
            if lats.is_empty() {
                f64::NAN
            } else {
                lats.sort_unstable();
                let rank = ((0.99 * lats.len() as f64).ceil() as usize).clamp(1, lats.len());
                lats[rank - 1] as f64
            }
        };
        let w = Window { p99, queue_depth };
        let d = decide(&cfg.thresholds, &budgets, &st, &w);
        if let Some(to) = d.target() {
            let step = &frontier[to];
            cur_epoch =
                engine.swap(step.ckpt.clone(), step.bits.clone(), step.budget_frac, &step.label())?;
            epoch_levels.push(to);
            crate::ensure!(
                cur_epoch as usize + 1 == epoch_levels.len(),
                "degrade: non-contiguous epoch {cur_epoch} (single-swapper invariant)"
            );
            match d {
                Decision::Down { .. } => swaps_down += 1,
                Decision::Up { .. } => swaps_up += 1,
                Decision::Hold(_) => unreachable!(),
            }
        }
        apply(&mut st, &d, cfg.thresholds.cooldown_ticks);
        let rec = DecisionRecord {
            tick,
            queue_depth,
            p99,
            decision: d,
            level: st.level,
            epoch: cur_epoch,
        };
        if let Some(sink) = engine.trace() {
            sink.ctl_event(
                rec.tick,
                rec.queue_depth,
                rec.p99,
                &rec.decision.render(),
                rec.level,
                rec.epoch,
            );
        }
        log.push(rec);
        tick += 1;
        if tick >= profile_ticks && (simq.is_empty() || tick >= profile_ticks + cfg.drain_ticks_max)
        {
            break;
        }
    }
    // 4. Collect every real response and verify the zero-drop, epoch-pure
    // guarantee: answered exactly once, under the admitting epoch.
    let mut responses = Vec::with_capacity(tickets.len());
    for (i, (expect, t)) in tickets.into_iter().enumerate() {
        let r = t.wait().map_err(|e| crate::err!("degrade: request {i} dropped: {e}"))?;
        crate::ensure!(
            r.epoch == expect,
            "degrade: request {i} answered under epoch {} but admitted under {expect}",
            r.epoch
        );
        responses.push((expect, r));
    }
    crate::ensure!(
        responses.len() == total,
        "degrade: {} of {total} requests unanswered",
        total - responses.len()
    );
    Ok(DegradeOutcome {
        log_text: render_log(&log),
        log,
        swaps_down,
        swaps_up,
        requests: total,
        epoch_levels,
        responses,
    })
}

/// Live-mode controller: ticks against a running engine on a wall-clock
/// cadence, reading windowed p99 from histogram bucket deltas and the
/// queue-depth gauge.  Same `decide`/`apply` core as the sim harness.
pub struct Controller {
    pub thresholds: SloThresholds,
    pub frontier: Arc<Vec<FrontierStep>>,
    pub state: CtlState,
    pub log: Vec<DecisionRecord>,
    pub swaps_down: usize,
    pub swaps_up: usize,
    last_buckets: Vec<u64>,
    tick: u64,
}

impl Controller {
    pub fn new(thresholds: SloThresholds, frontier: Arc<Vec<FrontierStep>>) -> crate::Result<Controller> {
        crate::ensure!(!frontier.is_empty(), "controller: empty frontier");
        Ok(Controller {
            thresholds,
            frontier,
            state: CtlState::new(0),
            log: Vec::new(),
            swaps_down: 0,
            swaps_up: 0,
            last_buckets: Vec::new(),
            tick: 0,
        })
    }

    /// One live tick: observe the window since the previous tick, decide,
    /// and hot-swap the engine if the decision says so.
    pub fn tick(&mut self, engine: &Engine) -> crate::Result<Decision> {
        let buckets = engine.latency_buckets();
        let delta: Vec<u64> = if self.last_buckets.is_empty() {
            buckets.clone()
        } else {
            buckets
                .iter()
                .zip(self.last_buckets.iter())
                .map(|(now, then)| now.saturating_sub(*then))
                .collect()
        };
        self.last_buckets = buckets;
        let w = Window {
            p99: quantile_from_counts(&delta, 0.99),
            queue_depth: engine.queued_samples(),
        };
        let budgets: Vec<f64> = self.frontier.iter().map(|s| s.budget_frac).collect();
        let d = decide(&self.thresholds, &budgets, &self.state, &w);
        if let Some(to) = d.target() {
            let step = &self.frontier[to];
            engine.swap(step.ckpt.clone(), step.bits.clone(), step.budget_frac, &step.label())?;
            match d {
                Decision::Down { .. } => self.swaps_down += 1,
                Decision::Up { .. } => self.swaps_up += 1,
                Decision::Hold(_) => unreachable!(),
            }
        }
        apply(&mut self.state, &d, self.thresholds.cooldown_ticks);
        let rec = DecisionRecord {
            tick: self.tick,
            queue_depth: w.queue_depth,
            p99: w.p99,
            decision: d,
            level: self.state.level,
            epoch: engine.current_epoch(),
        };
        if let Some(sink) = engine.trace() {
            sink.ctl_event(
                rec.tick,
                rec.queue_depth,
                rec.p99,
                &rec.decision.render(),
                rec.level,
                rec.epoch,
            );
        }
        crate::debug!(
            "tick {} q={} p99={:?} {} level={} epoch={}",
            rec.tick,
            rec.queue_depth,
            rec.p99,
            rec.decision.render(),
            rec.level,
            rec.epoch
        );
        self.log.push(rec);
        self.tick += 1;
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn th() -> SloThresholds {
        SloThresholds {
            slo_p99: 6.0,
            recover_frac: 0.5,
            queue_high: 64,
            queue_low: 8,
            cooldown_ticks: 3,
            floor_budget: 0.0,
        }
    }

    const BUDGETS: [f64; 3] = [0.95, 0.7, 0.5];

    #[test]
    fn overload_steps_down_and_calm_steps_up() {
        let st = CtlState::new(0);
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: 7.0, queue_depth: 0 });
        assert_eq!(d, Decision::Down { from: 0, to: 1 });
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: f64::NAN, queue_depth: 65 });
        assert_eq!(d, Decision::Down { from: 0, to: 1 });
        let st = CtlState::new(2);
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: 1.0, queue_depth: 0 });
        assert_eq!(d, Decision::Up { from: 2, to: 1 });
        // No latency signal + empty queue also recovers.
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: f64::NAN, queue_depth: 0 });
        assert_eq!(d, Decision::Up { from: 2, to: 1 });
    }

    /// The no-flap guarantee: load sitting **exactly on** a threshold is
    /// neither overload (strict >) nor calm (strict < / <=), so the
    /// config holds steady at any level, tick after tick.
    #[test]
    fn exact_threshold_load_never_flaps() {
        let t = th();
        for level in 0..BUDGETS.len() {
            let mut st = CtlState::new(level);
            for _ in 0..50 {
                // p99 exactly on the SLO, queue exactly on queue_high.
                let d = decide(&t, &BUDGETS, &st, &Window { p99: 6.0, queue_depth: 64 });
                assert_eq!(d, Decision::Hold(HoldReason::Steady));
                // In the hysteresis band: below SLO but above recovery.
                let d2 = decide(&t, &BUDGETS, &st, &Window { p99: 3.0, queue_depth: 0 });
                assert_eq!(d2, Decision::Hold(HoldReason::Steady));
                // Queue above queue_low blocks recovery even when calm-fast.
                let d3 = decide(&t, &BUDGETS, &st, &Window { p99: 1.0, queue_depth: 9 });
                assert_eq!(d3, Decision::Hold(HoldReason::Steady));
                apply(&mut st, &d, t.cooldown_ticks);
                assert_eq!(st.level, level, "level moved under exact-threshold load");
            }
        }
    }

    #[test]
    fn cooldown_blocks_consecutive_swaps_then_releases() {
        let t = th();
        let mut st = CtlState::new(0);
        let overload = Window { p99: 50.0, queue_depth: 500 };
        let d = decide(&t, &BUDGETS, &st, &overload);
        assert_eq!(d, Decision::Down { from: 0, to: 1 });
        apply(&mut st, &d, t.cooldown_ticks);
        for _ in 0..t.cooldown_ticks {
            let d = decide(&t, &BUDGETS, &st, &overload);
            assert_eq!(d, Decision::Hold(HoldReason::Cooldown));
            apply(&mut st, &d, t.cooldown_ticks);
        }
        let d = decide(&t, &BUDGETS, &st, &overload);
        assert_eq!(d, Decision::Down { from: 1, to: 2 });
    }

    #[test]
    fn floor_budget_and_frontier_ends_clamp_the_walk() {
        let t = SloThresholds { floor_budget: 0.6, ..th() };
        // Level 1 (0.7) is the cheapest level the 0.6 floor allows.
        let st = CtlState::new(1);
        let d = decide(&t, &BUDGETS, &st, &Window { p99: 50.0, queue_depth: 500 });
        assert_eq!(d, Decision::Hold(HoldReason::AtFloor));
        // Bottom of the frontier clamps even without a floor.
        let st = CtlState::new(2);
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: 50.0, queue_depth: 500 });
        assert_eq!(d, Decision::Hold(HoldReason::AtFloor));
        // Top clamps recovery.
        let st = CtlState::new(0);
        let d = decide(&th(), &BUDGETS, &st, &Window { p99: 0.5, queue_depth: 0 });
        assert_eq!(d, Decision::Hold(HoldReason::AtTop));
    }

    #[test]
    fn profiles_parse_and_accumulate_fractional_rates() {
        for name in ["quiet", "ramp", "spike"] {
            let p = SimProfile::named(name).unwrap();
            assert!(!p.arrivals_per_tick().is_empty(), "{name}");
        }
        let p = SimProfile::named("4x0.5,2x3").unwrap();
        assert_eq!(p.arrivals_per_tick(), vec![0, 1, 0, 1, 3, 3]);
        assert!(SimProfile::named("nope").is_err());
        assert!(SimProfile::named("0x1").is_err());
        assert!(SimProfile::named("3x-1").is_err());
    }

    #[test]
    fn decision_log_rendering_is_stable() {
        let log = vec![
            DecisionRecord {
                tick: 0,
                queue_depth: 3,
                p99: f64::NAN,
                decision: Decision::Hold(HoldReason::Steady),
                level: 0,
                epoch: 0,
            },
            DecisionRecord {
                tick: 1,
                queue_depth: 80,
                p99: 12.0,
                decision: Decision::Down { from: 0, to: 1 },
                level: 1,
                epoch: 1,
            },
        ];
        assert_eq!(
            render_log(&log),
            "tick=0 q=3 p99=NaN hold:steady level=0 epoch=0\n\
             tick=1 q=80 p99=12.0 down:0->1 level=1 epoch=1\n"
        );
        // Structured form: keys sorted, NaN p99 -> null, integral f64s
        // rendered without a fraction.  Pinned byte-for-byte — the degrade
        // determinism contract extends to --decision-log output.
        assert_eq!(
            decisions_jsonl(&log),
            "{\"decision\":\"hold:steady\",\"epoch\":0,\"level\":0,\"p99\":null,\
             \"queue_depth\":3,\"tick\":0}\n\
             {\"decision\":\"down:0->1\",\"epoch\":1,\"level\":1,\"p99\":12,\
             \"queue_depth\":80,\"tick\":1}\n"
        );
    }
}
