//! Checkpoint substrate: the `MPQCKPT1` binary format shared with the
//! Python build path (`python/compile/aot.py::write_ckpt`).
//!
//! Layout (little-endian):
//! ```text
//! magic   8 bytes  "MPQCKPT1"
//! count   u32
//! record: name_len u32, name bytes, ndim u32, dims u32[ndim],
//!         byte_len u64, f32 data
//! ```
//! Tensor order is the JAX pytree flatten order recorded in the manifest;
//! names are `/`-joined pytree paths (e.g. `s0b0/conv1/w`).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"MPQCKPT1";

/// A named, ordered collection of f32 tensors (model params or momenta).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Checkpoint {
    pub fn new(names: Vec<String>, tensors: Vec<Tensor>) -> Checkpoint {
        assert_eq!(names.len(), tensors.len());
        let index = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        Checkpoint {
            names,
            tensors,
            index,
        }
    }

    /// All-zeros checkpoint with the same structure (momentum init).
    pub fn zeros_like(&self) -> Checkpoint {
        Checkpoint::new(
            self.names.clone(),
            self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect(),
        )
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        let i = *self.index.get(name)?;
        Some(&mut self.tensors[i])
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    // -- io ------------------------------------------------------------------

    pub fn load(path: &Path) -> crate::Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| crate::err!("opening {}: {e}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        crate::ensure!(&magic == MAGIC, "bad checkpoint magic in {}", path.display());
        let count = read_u32(&mut f)? as usize;
        crate::ensure!(count < 1_000_000, "implausible tensor count {count}");
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let byte_len = read_u64(&mut f)? as usize;
            crate::ensure!(
                byte_len == 4 * shape.iter().product::<usize>(),
                "byte length mismatch for tensor"
            );
            let mut bytes = vec![0u8; byte_len];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            names.push(String::from_utf8(name)?);
            tensors.push(Tensor::from_f32(&shape, data));
        }
        Ok(Checkpoint::new(names, tensors))
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        // Serialize into one buffer, single write (perf pass §3: the
        // per-f32 write_all loop cost ~150 ms for a 0.27M-param model;
        // buffering brings the save to single-digit ms).
        let total: usize = self
            .tensors
            .iter()
            .map(|t| 24 + 4 * t.shape.len() + 4 * t.len())
            .sum::<usize>()
            + self.names.iter().map(|n| n.len()).sum::<usize>()
            + 16;
        let mut buf = Vec::with_capacity(total);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.names.len() as u32).to_le_bytes());
        for (name, t) in self.names.iter().zip(&self.tensors) {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            let data = t.f32s();
            buf.extend_from_slice(&((4 * data.len()) as u64).to_le_bytes());
            for &x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path)
            .map_err(|e| crate::err!("creating {}: {e}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }
}

fn read_u32<R: Read>(r: &mut R) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> crate::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint::new(
            vec!["a/w".into(), "a/sw".into(), "b/w".into()],
            vec![
                Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4., 5., 6.5]),
                Tensor::from_f32(&[], vec![0.05]),
                Tensor::from_f32(&[4], vec![0.0, 1.0, -1.0, 2.0]),
            ],
        )
    }

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("mpq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.names, ck.names);
        for (a, b) in back.tensors.iter().zip(&ck.tensors) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn lookup_and_zeros_like() {
        let ck = sample();
        assert_eq!(ck.get("a/sw").unwrap().item(), 0.05);
        assert!(ck.get("missing").is_none());
        let z = ck.zeros_like();
        assert_eq!(z.total_params(), ck.total_params());
        assert!(z.tensors.iter().all(|t| t.f32s().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("mpq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC\x00\x00\x00\x00").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
