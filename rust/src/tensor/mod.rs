//! Host tensor substrate: a small nd-array of f32/i32 used to marshal data
//! between the coordinator and PJRT literals.
//!
//! Deliberately minimal — all heavy math runs inside the AOT-compiled XLA
//! executables; the host only generates batches, shuffles, accumulates
//! metrics, and performs the (cheap) metric/knapsack computations.

/// Element type of a [`Tensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_numpy(name: &str) -> crate::Result<DType> {
        match name {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => crate::bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Data::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Data::I32(vec![0; shape.iter().product()]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Data::F32(data),
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Data::I32(data),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: Data::F32(vec![v]),
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Scalar extraction (len-1 tensors of any rank).
    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on tensor of len {}", self.len());
        match &self.data {
            Data::F32(v) => v[0],
            Data::I32(v) => v[0] as f32,
        }
    }

    /// L2 norm (f32 tensors).
    pub fn norm2(&self) -> f64 {
        self.f32s().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.f32s().iter().map(|&x| x as f64).sum::<f64>() / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.f32s()[4], 5.0);
        assert!((t.mean() - 3.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(3.5).item(), 3.5);
        let t = Tensor::from_i32(&[1], vec![7]);
        assert_eq!(t.item(), 7.0);
    }

    #[test]
    fn norm() {
        let t = Tensor::from_f32(&[2], vec![3.0, 4.0]);
        assert!((t.norm2() - 5.0).abs() < 1e-9);
    }
}
