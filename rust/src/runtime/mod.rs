//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them
//! from the coordinator hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.
//!
//! Executables are compiled once per (model, entry) and cached.  The
//! lowered graphs return a single tuple (`return_tuple=True`), which we
//! decompose on the host; fine-tune state (params + momenta) lives in
//! [`TrainState`] as host tensors between steps.

pub mod manifest;

use std::collections::HashMap;
use std::path::PathBuf;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::ckpt::Checkpoint;
use crate::tensor::{Data, Tensor};
pub use manifest::{EntrySpec, Manifest, Task, TensorSpec};

/// A loaded model: PJRT client + manifest + lazily compiled entry points.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    artifacts: PathBuf,
    exes: HashMap<String, PjRtLoadedExecutable>,
    /// Cumulative executions per entry (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

/// Mutable fine-tune state: parameters and SGD momenta, in manifest order.
#[derive(Clone)]
pub struct TrainState {
    pub params: Checkpoint,
    pub mom: Checkpoint,
}

impl TrainState {
    pub fn new(params: Checkpoint) -> TrainState {
        let mom = params.zeros_like();
        TrainState { params, mom }
    }
}

impl Runtime {
    /// Load a model's manifest and create a CPU PJRT client.  Entry points
    /// compile lazily on first use (compilation is seconds per entry).
    pub fn load(artifacts: &std::path::Path, model: &str) -> crate::Result<Runtime> {
        let manifest = Manifest::load(artifacts, model)?;
        let client = PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Runtime {
            client,
            manifest,
            artifacts: artifacts.to_path_buf(),
            exes: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Load the model's AOT-emitted initial checkpoint (seed 0).
    pub fn init_checkpoint(&self) -> crate::Result<Checkpoint> {
        Checkpoint::load(&self.artifacts.join(format!("{}_init.ckpt", self.manifest.model)))
    }

    fn exe(&mut self, entry: &str) -> crate::Result<&PjRtLoadedExecutable> {
        if !self.exes.contains_key(entry) {
            let spec = self.manifest.entry(entry)?.clone();
            let path = self.artifacts.join(&spec.file);
            let proto = HloModuleProto::from_text_file(&path).map_err(to_anyhow)?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_anyhow)?;
            self.exes.insert(entry.to_string(), exe);
        }
        Ok(&self.exes[entry])
    }

    /// Force-compile an entry (for startup-cost measurement / warmup).
    pub fn compile_entry(&mut self, entry: &str) -> crate::Result<()> {
        self.exe(entry).map(|_| ())
    }

    // -- marshaling ----------------------------------------------------------

    fn literal_of(&self, t: &Tensor) -> crate::Result<Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        let lit = match &t.data {
            Data::F32(v) => Literal::vec1(v.as_slice()),
            Data::I32(v) => Literal::vec1(v.as_slice()),
        };
        lit.reshape(&dims).map_err(to_anyhow)
    }

    fn tensor_of(&self, lit: &Literal) -> crate::Result<Tensor> {
        let shape = lit.array_shape().map_err(to_anyhow)?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::from_f32(
                &dims,
                lit.to_vec::<f32>().map_err(to_anyhow)?,
            )),
            xla::ElementType::S32 => Ok(Tensor::from_i32(
                &dims,
                lit.to_vec::<i32>().map_err(to_anyhow)?,
            )),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }

    /// Execute an entry point with host tensors; returns decomposed outputs.
    pub fn execute(&mut self, entry: &str, args: &[&Tensor]) -> crate::Result<Vec<Tensor>> {
        let mut literals = Vec::with_capacity(args.len());
        for t in args {
            literals.push(self.literal_of(t)?);
        }
        *self.exec_counts.entry(entry.to_string()).or_insert(0) += 1;
        let exe = self.exe(entry)?;
        let result = exe.execute::<Literal>(&literals).map_err(to_anyhow)?;
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // return_tuple=True → single tuple output; decompose.
        let parts = out.to_tuple().map_err(to_anyhow)?;
        let mut tensors = Vec::with_capacity(parts.len());
        for lit in &parts {
            tensors.push(self.tensor_of(lit)?);
        }
        Ok(tensors)
    }

    // -- typed entry points ----------------------------------------------------

    /// One fused SGD fine-tune step.  Updates `state` in place and returns
    /// (loss, train metric).
    pub fn train_step(
        &mut self,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        lr: f32,
        wd: f32,
        bits: &[f32],
    ) -> crate::Result<(f32, f32)> {
        let n = self.manifest.n_params();
        let lr_t = Tensor::scalar(lr);
        let wd_t = Tensor::scalar(wd);
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let mut args: Vec<&Tensor> = Vec::with_capacity(2 * n + 5);
        args.extend(state.params.tensors.iter());
        args.extend(state.mom.tensors.iter());
        args.extend([x, y, &lr_t, &wd_t, &bits_t]);
        let mut out = self.execute("train_step", &args)?;
        anyhow::ensure!(out.len() == 2 * n + 2, "train_step output arity");
        let metric = out.pop().unwrap().item();
        let loss = out.pop().unwrap().item();
        let mom_new = out.split_off(n);
        state.params = Checkpoint::new(state.params.names.clone(), out);
        state.mom = Checkpoint::new(state.mom.names.clone(), mom_new);
        Ok((loss, metric))
    }

    /// Evaluation step: returns (mean loss over batch, task-specific
    /// accumulator tensor — see [`Task`]).
    pub fn eval_step(
        &mut self,
        params: &Checkpoint,
        x: &Tensor,
        y: &Tensor,
        bits: &[f32],
    ) -> crate::Result<(f32, Tensor)> {
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let mut args: Vec<&Tensor> = Vec::with_capacity(params.tensors.len() + 3);
        args.extend(params.tensors.iter());
        args.extend([x, y, &bits_t]);
        let mut out = self.execute("eval_step", &args)?;
        anyhow::ensure!(out.len() == 2, "eval_step output arity");
        let evalout = out.pop().unwrap();
        let loss = out.pop().unwrap().item();
        Ok((loss, evalout))
    }

    /// One Hutchinson sample: per-layer v·Hv vector (HAWQ-v3 trace).
    pub fn vhv_step(
        &mut self,
        params: &Checkpoint,
        x: &Tensor,
        y: &Tensor,
        bits: &[f32],
        seed: i32,
    ) -> crate::Result<Vec<f32>> {
        let bits_t = Tensor::from_f32(&[bits.len()], bits.to_vec());
        let seed_t = Tensor::from_i32(&[1], vec![seed]);
        let mut args: Vec<&Tensor> = Vec::with_capacity(params.tensors.len() + 4);
        args.extend(params.tensors.iter());
        args.extend([x, y, &bits_t, &seed_t]);
        let out = self.execute("vhv_step", &args)?;
        anyhow::ensure!(out.len() == 1, "vhv_step output arity");
        Ok(out[0].f32s().to_vec())
    }

    /// Per-layer EAGL entropies computed by the L1 Pallas histogram kernel
    /// (cross-check path for the native rust implementation).
    ///
    /// The lowering prunes parameters the entropy graph never reads, so
    /// only each layer's `w` and `sw` survive in the executable signature
    /// (in the original flatten order) — marshal exactly those.
    pub fn eagl_step(&mut self, params: &Checkpoint) -> crate::Result<Vec<f32>> {
        let args: Vec<&Tensor> = params
            .names
            .iter()
            .zip(&params.tensors)
            .filter(|(n, _)| n.ends_with("/w") || n.ends_with("/sw"))
            .map(|(_, t)| t)
            .collect();
        let out = self.execute("eagl_step", &args)?;
        anyhow::ensure!(out.len() == 1, "eagl_step output arity");
        Ok(out[0].f32s().to_vec())
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}
