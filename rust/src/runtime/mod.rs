//! Backward-compatibility shim: execution moved behind the pluggable
//! [`crate::backend`] abstraction.
//!
//! The old `runtime::Runtime` (PJRT + AOT artifacts) is now
//! `backend::PjrtBackend` (compiled with `--features pjrt`); the hermetic
//! default is `backend::SimBackend`.  The manifest types and
//! [`TrainState`] live in [`crate::backend`] and are re-exported here so
//! existing `crate::runtime::{Task, Manifest, TrainState}` paths keep
//! working.

pub use crate::backend::manifest;
pub use crate::backend::{Backend, EntrySpec, Manifest, Task, TensorSpec, TrainState};

#[cfg(feature = "pjrt")]
pub use crate::backend::PjrtBackend;

/// Historical alias: `runtime::Runtime` was the PJRT artifact runtime.
#[cfg(feature = "pjrt")]
pub type Runtime = PjrtBackend;
