//! Error substrate (offline environment — no `anyhow`).
//!
//! A single string-carrying error type plus the three macros the crate
//! uses everywhere: [`err!`](crate::err) builds an [`Error`] from a format
//! string, [`bail!`](crate::bail) returns it, and
//! [`ensure!`](crate::ensure) bails when a condition fails.  `?` works on
//! the std error types the crate actually encounters (io, UTF-8).

use std::fmt;

/// Crate-wide error: a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

/// Bail unless a condition holds: `ensure!(a == b, "mismatch {a} {b}")`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        crate::ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_build_and_propagate() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        let e2 = crate::err!("x = {}", 3);
        assert_eq!(format!("{e2}"), "x = 3");
        assert_eq!(format!("{e2:?}"), "x = 3");
    }

    #[test]
    fn io_errors_convert() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }
}
