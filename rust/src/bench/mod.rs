//! Benchmark harness substrate (offline environment — no criterion).
//!
//! Criterion-style measurement: warmup, timed iterations, mean/std/p50/p95
//! plus throughput, with plain-text reporting.  Each `rust/benches/*.rs`
//! target (one per paper table/figure) uses this harness with
//! `harness = false`.

use std::time::Instant;

use crate::jsonio::Json;

/// Timing summary over n iterations.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>10}  n={}",
            self.name,
            fmt_s(self.mean_s),
            fmt_s(self.std_s),
            fmt_s(self.p50_s),
            fmt_s(self.p95_s),
            fmt_s(self.p99_s),
            self.iters
        );
    }

    /// items-per-second at the mean latency.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    /// Machine-readable form for the `BENCH_*.json` perf records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("std_s", Json::num(self.std_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("p99_s", Json::num(self.p99_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

/// Collects a bench target's measurements and writes them as a
/// machine-readable `BENCH_<name>.json` (via [`crate::jsonio`]), so perf
/// claims are checked against a recorded baseline instead of lore.
/// `make bench-quick` writes `BENCH_hotpath.json` at the repo root;
/// re-running prints each measurement's speedup against the recorded
/// file (see [`load_baseline`]).
pub struct BenchSink {
    pub bench: String,
    pub measurements: Vec<Measurement>,
}

impl BenchSink {
    pub fn new(bench: &str) -> BenchSink {
        BenchSink {
            bench: bench.to_string(),
            measurements: Vec::new(),
        }
    }

    /// Output path: the `MPQ_BENCH_OUT` override wins (the Makefile sets
    /// it to the repo root), else `BENCH_<bench>.json` under the cwd.
    pub fn out_path(bench: &str) -> std::path::PathBuf {
        match std::env::var_os("MPQ_BENCH_OUT") {
            Some(p) => std::path::PathBuf::from(p),
            None => std::path::PathBuf::from(format!("BENCH_{bench}.json")),
        }
    }

    pub fn record(&mut self, m: Measurement) {
        self.measurements.push(m);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str(&self.bench)),
            ("quick", Json::Bool(quick())),
            (
                "measurements",
                Json::Arr(self.measurements.iter().map(Measurement::to_json).collect()),
            ),
        ])
    }

    pub fn write(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }
}

/// Read a previously written `BENCH_*.json` into (measurement name →
/// mean seconds) for printing speedups against the recorded baseline.
///
/// `None` means "no comparison — print absolute numbers": the file is
/// absent, unparseable, or carries **no usable rows** (the committed
/// seed record ships with `measurements: []` until the first
/// `make bench-quick` on a machine with a toolchain).  Rows with a
/// missing/non-finite/non-positive mean are dropped individually, so a
/// speedup ratio is never emitted against an absent or degenerate row.
pub fn load_baseline(path: &std::path::Path) -> Option<std::collections::BTreeMap<String, f64>> {
    let v = crate::jsonio::parse_file(path).ok()?;
    let mut out = std::collections::BTreeMap::new();
    for m in v.at(&["measurements"]).as_arr()? {
        let (Some(name), Some(mean)) = (m.at(&["name"]).as_str(), m.at(&["mean_s"]).as_f64())
        else {
            continue;
        };
        if mean.is_finite() && mean > 0.0 {
            out.insert(name.to_string(), mean);
        }
    }
    if out.is_empty() {
        return None;
    }
    Some(out)
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Print the standard header for measurement tables.
pub fn header() {
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "std", "p50", "p95", "p99"
    );
    println!("{}", "-".repeat(103));
}

/// Measure `f` with `warmup` + `iters` runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &times)
}

/// Measure a fallible operation, propagating the first error.
pub fn try_measure<F: FnMut() -> crate::Result<()>>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> crate::Result<Measurement> {
    for _ in 0..warmup {
        f()?;
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(summarize(name, &times))
}

fn summarize(name: &str, times: &[f64]) -> Measurement {
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = crate::stats::mean(times);
    Measurement {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean,
        std_s: crate::stats::std_dev(times),
        p50_s: percentile(&sorted, 0.50),
        p95_s: percentile(&sorted, 0.95),
        p99_s: percentile(&sorted, 0.99),
        min_s: sorted.first().copied().unwrap_or(f64::NAN),
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Quick-mode switch shared by all bench targets: `MPQ_BENCH_QUICK=1`
/// shrinks workloads so the full suite completes on the CI box.
pub fn quick() -> bool {
    std::env::var("MPQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Open a coordinator for `model` on the auto-resolved backend, or print
/// a skip line and return `None` when the model isn't runnable in this
/// build/checkout (e.g. artifact models without `make artifacts` or a
/// non-pjrt build).  Bench targets use this so the hermetic parts of the
/// suite always run.
pub fn coordinator_or_skip(
    model: &str,
    data_seed: u64,
) -> Option<crate::coordinator::Coordinator<Box<dyn crate::backend::Backend>>> {
    match crate::coordinator::Coordinator::open_auto(model, data_seed) {
        Ok(co) => Some(co),
        Err(e) => {
            println!("skipping {model}: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = measure("noop-ish", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(m.iters, 20);
        assert!(m.mean_s >= 0.0 && m.mean_s.is_finite());
        assert!(m.p50_s <= m.p95_s + 1e-12);
        assert!(m.p95_s <= m.p99_s + 1e-12);
        assert!(m.min_s <= m.mean_s + 1e-12);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_s(2e-9).ends_with("ns"));
        assert!(fmt_s(2e-6).ends_with("µs"));
        assert!(fmt_s(2e-3).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
    }

    #[test]
    fn try_measure_propagates() {
        let r = try_measure("fails", 0, 3, || crate::bail!("no"));
        assert!(r.is_err());
    }

    #[test]
    fn bench_sink_round_trips_through_jsonio() {
        let mut sink = BenchSink::new("unit");
        sink.record(Measurement {
            name: "alpha".into(),
            iters: 3,
            mean_s: 0.25,
            std_s: 0.01,
            p50_s: 0.24,
            p95_s: 0.27,
            p99_s: 0.28,
            min_s: 0.23,
        });
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mpq_bench_sink_{}.json", std::process::id()));
        sink.write(&path).unwrap();
        // The written file must parse back through jsonio...
        let v = crate::jsonio::parse_file(&path).unwrap();
        assert_eq!(v.at(&["bench"]).as_str(), Some("unit"));
        // ...and load_baseline must recover the means by name.
        let base = load_baseline(&path).unwrap();
        assert!((base.get("alpha").copied().unwrap() - 0.25).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_baseline_absent_file_is_none() {
        assert!(load_baseline(std::path::Path::new("/no/such/BENCH.json")).is_none());
    }

    #[test]
    fn load_baseline_empty_or_degenerate_measurements_mean_no_comparison() {
        let dir = std::env::temp_dir();
        // The committed seed shape: measurements is an empty array.  A
        // Some(empty map) here would print "comparing against recorded
        // baseline" and then compare against nothing — it must be None.
        let empty = dir.join(format!("mpq_bench_empty_{}.json", std::process::id()));
        std::fs::write(&empty, r#"{"bench":"hotpath","quick":true,"measurements":[]}"#).unwrap();
        assert!(
            load_baseline(&empty).is_none(),
            "an empty baseline must mean 'no comparison', not a partial match"
        );
        // Rows without a usable mean (null from a NaN, zero, negative)
        // are dropped; a baseline made only of them is also None.
        let degen = dir.join(format!("mpq_bench_degen_{}.json", std::process::id()));
        std::fs::write(
            &degen,
            r#"{"bench":"hotpath","measurements":[
                {"name":"a","mean_s":null},
                {"name":"b","mean_s":0.0},
                {"name":"c"}
            ]}"#,
        )
        .unwrap();
        assert!(load_baseline(&degen).is_none());
        // A usable row among degenerate ones survives alone.
        let mixed = dir.join(format!("mpq_bench_mixed_{}.json", std::process::id()));
        std::fs::write(
            &mixed,
            r#"{"bench":"hotpath","measurements":[
                {"name":"a","mean_s":null},
                {"name":"ok","mean_s":0.5}
            ]}"#,
        )
        .unwrap();
        let base = load_baseline(&mixed).unwrap();
        assert_eq!(base.len(), 1);
        assert!((base.get("ok").copied().unwrap() - 0.5).abs() < 1e-12);
        for p in [&empty, &degen, &mixed] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn throughput_inverse_of_latency() {
        let m = Measurement {
            name: "t".into(),
            iters: 1,
            mean_s: 0.5,
            std_s: 0.0,
            p50_s: 0.5,
            p95_s: 0.5,
            p99_s: 0.5,
            min_s: 0.5,
        };
        assert!((m.throughput(10.0) - 20.0).abs() < 1e-12);
    }
}
