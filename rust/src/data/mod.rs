//! Synthetic workload generators (DESIGN.md §4).
//!
//! The paper's datasets (ImageNet, Cityscapes, SQuAD1.1) are not available
//! in this environment, so each task is replaced by a procedural generator
//! that preserves what the selection methods actually exploit: a non-trivial
//! learnable mapping whose difficulty is spread heterogeneously across
//! network depth.  Generation is deterministic per (seed, split, index) —
//! every batch is reproducible regardless of execution order, and train and
//! eval streams are disjoint by construction.
//!
//!  * [`Dataset::textures`]  — 10-class oriented-grating classification
//!    (ImageNet stand-in for qresnet).
//!  * [`Dataset::shapes`]    — 5-class shape segmentation (Cityscapes
//!    stand-in for qsegnet).
//!  * [`Dataset::needle`]    — marker-anchored span extraction (SQuAD
//!    stand-in for qbert).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::rng::Pcg32;
use crate::backend::Task;
use crate::tensor::Tensor;

/// Generated-batch memo capacity.  Sized to cover the repeated streams
/// that actually recur — ALPS re-runs the same seed-1 fine-tune stream
/// (default 40 steps) once per group, and every evaluation replays eval
/// batches 0..n — while bounding worst-case memory (entries are one
/// (x, y) tensor pair; ~200 KB for a cls train batch).
const BATCH_MEMO_CAP: usize = 64;

/// Shared memo of generated batches keyed by (split, index, batch).
///
/// Generation is deterministic, so a hit returns exactly what
/// regeneration would produce — bit-identical, just without the
/// procedural noise synthesis.  Clones of a [`Dataset`] share one memo
/// (`Arc`), so worker threads of a parallel sweep reuse each other's
/// generation work; FIFO eviction at [`BATCH_MEMO_CAP`].
#[derive(Clone, Default)]
struct BatchMemo(Arc<Mutex<VecDeque<((u8, u64, usize), Arc<(Tensor, Tensor)>)>>>);

impl std::fmt::Debug for BatchMemo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("BatchMemo")
    }
}

impl BatchMemo {
    fn get(&self, key: (u8, u64, usize)) -> Option<(Tensor, Tensor)> {
        // Only the Arc bump happens under the lock; the deep clone of the
        // tensor data runs outside it, so concurrent sweep workers never
        // serialize on a hit's memcpy.
        let hit = {
            let q = self.0.lock().unwrap();
            q.iter().find(|(k, _)| *k == key).map(|(_, pair)| Arc::clone(pair))
        };
        hit.map(|pair| (pair.0.clone(), pair.1.clone()))
    }

    fn put(&self, key: (u8, u64, usize), x: &Tensor, y: &Tensor) {
        // Clone before taking the lock (same reasoning as `get`).
        let pair = Arc::new((x.clone(), y.clone()));
        let mut q = self.0.lock().unwrap();
        if q.iter().any(|(k, _)| *k == key) {
            return;
        }
        if q.len() >= BATCH_MEMO_CAP {
            q.pop_front();
        }
        q.push_back((key, pair));
    }
}

/// Train or eval stream (disjoint RNG streams).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x7261696e,
            Split::Eval => 0x6576616c,
        }
    }
}

/// A deterministic infinite dataset for one task.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub task: Task,
    pub seed: u64,
    pub image: usize,
    pub num_classes: usize,
    pub seq: usize,
    pub vocab: usize,
    memo: BatchMemo,
}

impl Dataset {
    pub fn for_task(task: Task, seed: u64) -> Dataset {
        Dataset {
            task,
            seed,
            image: 32,
            num_classes: if task == Task::Seg { 5 } else { 10 },
            seq: 32,
            vocab: 32,
            memo: BatchMemo::default(),
        }
    }

    fn rng(&self, split: Split, index: u64) -> Pcg32 {
        Pcg32::new(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15), split.stream())
    }

    /// Batch `index` of the given split: (x, y) host tensors with the
    /// shapes the model artifacts expect.  Generation is deterministic
    /// per (seed, split, index, batch), so repeated requests — ALPS
    /// replaying one fine-tune stream per group, eval loops replaying
    /// eval batches — come from the [`BatchMemo`] instead of re-running
    /// the procedural synthesis; hits are bit-identical clones.
    pub fn batch(&self, split: Split, index: u64, batch: usize) -> (Tensor, Tensor) {
        let key = (split as u8, index, batch);
        if let Some(hit) = self.memo.get(key) {
            return hit;
        }
        let out = self.generate(split, index, batch);
        self.memo.put(key, &out.0, &out.1);
        out
    }

    /// Uncached generation path (the pre-memo `batch`).
    fn generate(&self, split: Split, index: u64, batch: usize) -> (Tensor, Tensor) {
        match self.task {
            Task::Cls => self.textures(split, index, batch),
            Task::Seg => self.shapes(split, index, batch),
            Task::Span => self.needle(split, index, batch),
        }
    }

    // -- textures: oriented-grating classification ---------------------------

    fn textures(&self, split: Split, index: u64, batch: usize) -> (Tensor, Tensor) {
        let n = self.image;
        let mut rng = self.rng(split, index);
        let mut xs = vec![0f32; batch * n * n * 3];
        let mut ys = vec![0i32; batch];
        for b in 0..batch {
            let class = rng.below(self.num_classes as u32) as usize;
            // class = orientation (5, 36° apart) × frequency (2, close
            // pair) — deliberately low-SNR so precision actually matters:
            // a 2-bit activation path (4 levels) visibly degrades here
            // while 8-bit stays clean.
            let (theta, freq) = texture_class_params(class);
            let phase = rng.range(0.0, std::f32::consts::TAU);
            let amp = rng.range(0.18, 0.30);
            let (st, ct) = theta.sin_cos();
            // Second, fixed-orientation carrier multiplies the grating so
            // single-layer linear filters are insufficient.
            let phase2 = rng.range(0.0, std::f32::consts::TAU);
            for i in 0..n {
                for j in 0..n {
                    let u = (i as f32 - n as f32 / 2.0) / n as f32;
                    let v = (j as f32 - n as f32 / 2.0) / n as f32;
                    let t = (u * ct + v * st) * freq * std::f32::consts::TAU;
                    let carrier = ((u - v) * 3.0 * std::f32::consts::TAU + phase2).sin();
                    let val = 0.5 + amp * (t + phase).sin() * (0.6 + 0.4 * carrier);
                    for c in 0..3 {
                        let jitter = 0.20 * rng.normal();
                        xs[((b * n + i) * n + j) * 3 + c] = (val + jitter).clamp(0.0, 1.0);
                    }
                }
            }
            ys[b] = class as i32;
        }
        (
            Tensor::from_f32(&[batch, n, n, 3], xs),
            Tensor::from_i32(&[batch], ys),
        )
    }

    // -- shapes: segmentation -------------------------------------------------

    fn shapes(&self, split: Split, index: u64, batch: usize) -> (Tensor, Tensor) {
        let n = self.image;
        let mut rng = self.rng(split, index);
        let mut xs = vec![0f32; batch * n * n * 3];
        let mut ys = vec![0i32; batch * n * n];
        for b in 0..batch {
            // Noisy background.
            for i in 0..n * n {
                let v = 0.35 + 0.08 * rng.normal();
                for c in 0..3 {
                    xs[(b * n * n + i) * 3 + c] = (v + 0.03 * rng.normal()).clamp(0.0, 1.0);
                }
            }
            // 2-4 shapes; label classes 1..=4 (0 = background).
            let k = 2 + rng.below(3) as usize;
            for _ in 0..k {
                let class = 1 + rng.below((self.num_classes - 1) as u32) as usize;
                let cx = rng.below(n as u32) as i32;
                let cy = rng.below(n as u32) as i32;
                let r = 3 + rng.below(6) as i32;
                // Per-class appearance: brightness + texture frequency.
                let base = 0.45 + 0.12 * class as f32;
                let tex_f = class as f32 * 1.7;
                for i in 0..n as i32 {
                    for j in 0..n as i32 {
                        let inside = match class % 2 {
                            0 => (i - cx).abs() <= r && (j - cy).abs() <= r, // square
                            _ => (i - cx).pow(2) + (j - cy).pow(2) <= r * r, // disc
                        };
                        if inside {
                            let idx = b * n * n + (i as usize) * n + j as usize;
                            let tex = 0.1
                                * ((i + j) as f32 * tex_f / n as f32 * std::f32::consts::TAU)
                                    .sin();
                            for c in 0..3 {
                                let v = base + tex + 0.04 * rng.normal()
                                    - 0.01 * (c as f32 - 1.0) * (class as f32 - 2.5);
                                xs[idx * 3 + c] = v.clamp(0.0, 1.0);
                            }
                            ys[idx] = class as i32;
                        }
                    }
                }
            }
        }
        (
            Tensor::from_f32(&[batch, n, n, 3], xs),
            Tensor::from_i32(&[batch, n, n], ys),
        )
    }

    // -- needle: span extraction ----------------------------------------------

    /// Token ids: 1 = marker, 2..4 = span body alphabet, 4.. = distractors.
    fn needle(&self, split: Split, index: u64, batch: usize) -> (Tensor, Tensor) {
        let s = self.seq;
        let mut rng = self.rng(split, index);
        let mut toks = vec![0i32; batch * s];
        let mut spans = vec![0i32; batch * 2];
        for b in 0..batch {
            for t in 0..s {
                toks[b * s + t] = 4 + rng.below((self.vocab - 4) as u32) as i32;
            }
            let span_len = 1 + rng.below(4) as usize;
            let marker = rng.below((s - span_len - 2) as u32) as usize;
            let start = marker + 1;
            let end = start + span_len - 1;
            toks[b * s + marker] = 1;
            for t in start..=end {
                toks[b * s + t] = 2 + rng.below(2) as i32;
            }
            spans[b * 2] = start as i32;
            spans[b * 2 + 1] = end as i32;
        }
        (
            Tensor::from_i32(&[batch, s], toks),
            Tensor::from_i32(&[batch, 2], spans),
        )
    }
}

/// (orientation θ, spatial frequency) of one texture class's grating —
/// the generator's class definition, shared with the sim backend's
/// matched-filter featurizer so the two can never drift apart.
pub fn texture_class_params(class: usize) -> (f32, f32) {
    let theta = std::f32::consts::PI * (class % 5) as f32 / 5.0;
    let freq = if class < 5 { 3.0 } else { 4.5 };
    (theta, freq)
}

/// SQuAD-style token-overlap F1 between predicted and gold spans.
pub fn span_f1(pred: &[(i32, i32)], gold: &[(i32, i32)]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let mut total = 0.0;
    for (&(ps, pe), &(gs, ge)) in pred.iter().zip(gold) {
        let pred_len = (pe - ps + 1).max(0) as f64;
        let gold_len = (ge - gs + 1).max(0) as f64;
        let overlap = (pe.min(ge) - ps.max(gs) + 1).max(0) as f64;
        if pred_len <= 0.0 || overlap <= 0.0 {
            continue;
        }
        let p = overlap / pred_len;
        let r = overlap / gold_len;
        total += 2.0 * p * r / (p + r);
    }
    total / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let ds = Dataset::for_task(Task::Cls, 7);
        let (x1, y1) = ds.batch(Split::Train, 3, 8);
        let (x2, y2) = ds.batch(Split::Train, 3, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn batch_memo_is_transparent_and_shared_across_clones() {
        let ds = Dataset::for_task(Task::Cls, 7);
        let (x1, y1) = ds.batch(Split::Train, 3, 8); // generated + memoized
        let clone = ds.clone();
        // The clone shares the Arc'd memo, so this hit must return the
        // exact tensors; and either way the content is bit-identical to
        // an uncached regeneration.
        let (x2, y2) = clone.batch(Split::Train, 3, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, y3) = ds.generate(Split::Train, 3, 8);
        assert_eq!(x1, x3);
        assert_eq!(y1, y3);
    }

    #[test]
    fn batch_memo_evicts_fifo_without_changing_results() {
        let ds = Dataset::for_task(Task::Cls, 9);
        let (x_first, _) = ds.batch(Split::Train, 0, 2);
        // Push well past capacity so index 0 is evicted...
        for i in 1..(super::BATCH_MEMO_CAP as u64 + 8) {
            ds.batch(Split::Train, i, 2);
        }
        // ...and regeneration still reproduces it exactly.
        let (x_again, _) = ds.batch(Split::Train, 0, 2);
        assert_eq!(x_first, x_again);
    }

    #[test]
    fn splits_disjoint() {
        let ds = Dataset::for_task(Task::Cls, 7);
        let (x1, _) = ds.batch(Split::Train, 0, 4);
        let (x2, _) = ds.batch(Split::Eval, 0, 4);
        assert_ne!(x1, x2);
    }

    #[test]
    fn texture_shapes_and_ranges() {
        let ds = Dataset::for_task(Task::Cls, 1);
        let (x, y) = ds.batch(Split::Train, 0, 16);
        assert_eq!(x.shape, vec![16, 32, 32, 3]);
        assert_eq!(y.shape, vec![16]);
        assert!(x.f32s().iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(y.i32s().iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn all_classes_appear() {
        let ds = Dataset::for_task(Task::Cls, 1);
        let (_, y) = ds.batch(Split::Train, 0, 256);
        let mut seen = [false; 10];
        for &c in y.i32s() {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn seg_labels_valid() {
        let ds = Dataset::for_task(Task::Seg, 2);
        let (x, y) = ds.batch(Split::Eval, 5, 4);
        assert_eq!(x.shape, vec![4, 32, 32, 3]);
        assert_eq!(y.shape, vec![4, 32, 32]);
        assert!(y.i32s().iter().all(|&c| (0..5).contains(&c)));
        // Non-degenerate: some foreground exists.
        assert!(y.i32s().iter().any(|&c| c > 0));
    }

    #[test]
    fn needle_spans_consistent() {
        let ds = Dataset::for_task(Task::Span, 3);
        let (x, y) = ds.batch(Split::Train, 2, 8);
        let toks = x.i32s();
        let spans = y.i32s();
        for b in 0..8 {
            let (s, e) = (spans[b * 2] as usize, spans[b * 2 + 1] as usize);
            assert!(s <= e && e < 32);
            // Marker immediately precedes the span.
            assert_eq!(toks[b * 32 + s - 1], 1);
            for t in s..=e {
                assert!((2..4).contains(&toks[b * 32 + t]));
            }
        }
    }

    #[test]
    fn f1_exact_match_is_one() {
        let spans = vec![(3, 5), (10, 10)];
        assert!((span_f1(&spans, &spans) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn f1_no_overlap_is_zero() {
        assert_eq!(span_f1(&[(0, 2)], &[(5, 8)]), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred [2,5] (len 4) vs gold [4,7] (len 4): overlap 2, p=r=0.5 → 0.5.
        assert!((span_f1(&[(2, 5)], &[(4, 7)]) - 0.5).abs() < 1e-12);
    }
}
